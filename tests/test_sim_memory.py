"""Jaxpr/memory regression tests for the simulator's segmented-min
arbitration (ISSUE 4): the winner reduce used to broadcast every queue
slot's priority key onto an (N, 2nQ, 2n) one-hot candidate tensor — the
largest per-slot intermediate of the whole program.  These tests pin its
absence at the jaxpr level (no intermediate of that shape, and no
per-slot intermediate at or above its element count) and at the compiled
level (cost_analysis bytes-accessed budget through the
`repro.parallel._compat` dict surface), so the blowup cannot silently
return.
"""
import jax
import numpy as np
import pytest

import repro.parallel  # noqa: F401 — installs the _compat adapters
from repro.core import Scenario, Torus
from repro.core.simulation import (_get_runner, _init_state, _make_ctx,
                                   _make_slot_step_batched, _make_traffic,
                                   build_tables)

# n=3 (P=6) so the forbidden (N, PQ, P) tensor is strictly bigger than the
# legitimate (N, PQ, n) record view — the size bound below separates them
G = Torus(8, 8, 8)
N, P, Q = G.order, 6, 4
PQ = P * Q
SLOTS = 32


def _slot_step_jaxpr(scenario=None):
    t = build_tables(G)
    ctx = _make_ctx(t, G, "uniform", 0, Q, scenario)
    step = _make_slot_step_batched(ctx, warmup=8)
    state = _init_state(ctx, 0.5, "batched", SLOTS)
    tr = _make_traffic(ctx, state, jax.random.PRNGKey(0), SLOTS)
    tr1 = jax.tree_util.tree_map(lambda a: a[0], tr)
    return jax.make_jaxpr(step)(state, tr1)


def _all_eqn_shapes(jaxpr):
    """Shapes of every intermediate of a jaxpr, descending into sub-jaxprs
    (scan bodies, pjit calls)."""
    shapes = []

    def walk(jx):
        for e in jx.eqns:
            for v in e.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    shapes.append(tuple(aval.shape))
            for p in e.params.values():
                sub = getattr(p, "jaxpr", None)
                if sub is not None:
                    walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub)

    walk(jaxpr.jaxpr)
    return shapes


@pytest.mark.parametrize("scen", [None, Scenario.random_link_faults(
    G, 4, seed=1, policy="adaptive")], ids=["trivial", "faulted"])
def test_slot_step_has_no_candidate_tensor(scen):
    """No per-slot intermediate is shaped (N, 2nQ, 2n) — in any axis
    order — and none reaches its element count: the segmented min keeps
    the largest winner-phase tensor at O(N·2nQ)."""
    shapes = _all_eqn_shapes(_slot_step_jaxpr(scen))
    blowup = tuple(sorted((N, PQ, P)))
    offenders = [s for s in shapes if tuple(sorted(s)) == blowup]
    assert not offenders, offenders
    # rec state is (N, P, Q, n) = N·PQ·n elements; the blowup was N·PQ·2n.
    # everything in the slot program must stay strictly below it.
    too_big = [s for s in shapes if int(np.prod(s)) >= N * PQ * P]
    assert not too_big, too_big


def test_compiled_bytes_accessed_pinned():
    """Budget pin on the compiled slot program via the jax-version-adapted
    dict cost_analysis (repro.parallel._compat): re-introducing the
    (N, 2nQ, 2n) candidate tensor adds ≥ slots·N·PQ·P·2 bytes of traffic,
    which blows this budget."""
    t = build_tables(G)
    ctx = _make_ctx(t, G, "uniform", 0, Q)
    runner = _get_runner(t, ctx, slots=SLOTS, warmup=8, impl="batched",
                         n_loads=1)
    state = _init_state(ctx, 0.5, "batched", SLOTS)
    comp = runner.lower(state, jax.random.PRNGKey(17)).compile()
    ca = comp.cost_analysis()
    assert isinstance(ca, dict), "expected the _compat dict surface"
    accessed = ca.get("bytes accessed")
    if accessed is None:  # backend didn't report it — don't silently pass
        pytest.skip("cost_analysis has no 'bytes accessed' on this backend")
    # measured ≈8.0 MB on jax 0.4.37 CPU for this shape; the candidate
    # tensor alone would add SLOTS·N·PQ·P·2 B ≈ 9.4 MB of accesses
    budget = 12e6
    assert accessed < budget, (accessed, budget)
