"""Error-feedback gradient compression for the cross-pod DP all-reduce.

At 1000+-node scale the inter-pod links (the 'pod' mesh axis) are the
scarcest bandwidth; int8 quantization with error feedback cuts that traffic
4× (vs fp32) while provably keeping SGD convergence (the residual carries
the quantization error into the next step).  Applied only to the DP
reduction — TP/EP collectives stay exact.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: object      # pytree like grads, fp32


def init_state(grads_like) -> CompressionState:
    return CompressionState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress(grads, state: CompressionState):
    """grads (+residual) → (int8 pytree, scales pytree, new state)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, scale = _quantize_int8(x)
        err = x - _dequantize(q, scale)
        return q, scale, err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual)
    qs, scales, errs = zip(*[one(g, r) for g, r in zip(flat_g, flat_r)])
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            CompressionState(residual=jax.tree.unflatten(treedef, errs)))


def decompress(qs, scales):
    return jax.tree.map(_dequantize, qs, scales)


def compressed_psum(grads, state: CompressionState, axis_name: str):
    """Compress → psum(int32 accumulate) → dequantize.  Used inside
    shard_map for the cross-pod reduction."""
    qs, scales, state = compress(grads, state)

    def reduce_one(q, scale):
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        # scales differ per pod: use the max for a conservative dequant
        s = jax.lax.pmax(scale, axis_name)
        return acc.astype(jnp.float32) * s / n

    return jax.tree.map(reduce_one, qs, scales), state
