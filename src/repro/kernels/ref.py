"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

These are the ground truth the interpret-mode kernels are allclose-tested
against, and the `impl="xla"` path the dry-run roofline reads."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) *
            weight.astype(jnp.float32)).astype(x.dtype)


def flash_attention(q, k, v, causal: bool = True):
    """q, k, v: (BH, S, hd) — multi-head folded into the leading dim."""
    BH, S, hd = q.shape
    scores = jnp.einsum("bqh,bkh->bqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]
        scores = jnp.where(mask[None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", w.astype(q.dtype), v)


def decode_attention(q, k, v, position):
    """q: (BH, 1, hd); k, v: (BH, S_max, hd); slots > position are masked."""
    BH, S, hd = k.shape
    scores = jnp.einsum("bqh,bkh->bqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    valid = jnp.arange(S)[None, None, :] <= position
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", w.astype(q.dtype), v)


def ssd_intra_chunk(xdt, Adt, Bm, Cm):
    """Intra-chunk SSD: per (BH, chunk): y_diag, per-chunk end state, and the
    chunk's total log-decay.

    xdt: (BH, nc, Q, P); Adt: (BH, nc, Q); Bm, Cm: (BH, nc, Q, N)
    Returns y_diag (BH, nc, Q, P), states (BH, nc, P, N), chunk_sum (BH, nc)."""
    A_cum = jnp.cumsum(Adt.astype(jnp.float32), axis=-1)          # (BH,nc,Q)
    Q = Adt.shape[-1]
    diff = A_cum[..., :, None] - A_cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(mask, diff, -jnp.inf))
    scores = jnp.einsum("bcqn,bckn->bcqk", Cm, Bm).astype(jnp.float32)
    y_diag = jnp.einsum("bcqk,bckp->bcqp", (scores * L).astype(xdt.dtype), xdt)
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)               # (BH,nc,Q)
    states = jnp.einsum("bckn,bck,bckp->bcpn", Bm,
                        decay_states.astype(xdt.dtype), xdt)
    return y_diag, states, A_cum[..., -1]
