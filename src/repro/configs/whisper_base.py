"""Whisper-base [arXiv:2212.04356]: enc-dec; the conv frame frontend is a
stub — input_specs() provides precomputed frame embeddings (B, 1500, 512)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,            # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    encoder_layers=6,
    encoder_seq_len=1500,
    tie_embeddings=True,
)
