"""Heterogeneous links (ISSUE 8 tentpole): weighted latencies, pillar
Z-connectivity and express channels, validated through every simulator
layer.

The contracts pinned here:

  * **bitwise weight-1 contract** — `links=LinkSpec()` (and any spec with
    `is_trivial`) compiles the EXACT pre-heterogeneous program: all six
    PR 7 golden cells (counters bit for bit, float for float) plus the
    24-bin FCC2 histogram reproduce under the trivial spec;
  * **weighted differential** — batched and reference implement the same
    multi-slot channel-hold physics: accepted load agrees within ±5% at
    every load point of a weighted sweep;
  * **express acceptance** — a span-2 express overlay on the long axis of
    the mixed-radix T(8,4) measurably raises routed saturation (above
    the analytic mixed-radix ceiling, closing most of the gap to the
    same-order BCC(2) lattice peer) and lowers simulated latency;
  * **pillar masks** — non-pillar Z-channels are structurally dead:
    `link_use` audits zero crossings, conservation holds, and the mask
    composes with `FaultSchedule` epochs (per-slot dead-crossing audit);
  * **composition** — weights × vcs≥2, weights × FaultSchedule, and the
    fused-impl rejection of non-trivial specs.
"""
import numpy as np
import pytest

from repro.core import (BCC, FaultSchedule, LinkSpec, Scenario, SimConfig,
                        Torus, channel_load_stats, distance_stats,
                        saturation, weighted_distance_matrix)
from repro.core.distances import faulted_distance_matrix
from repro.core.simulation import build_tables, simulate

# the pre-PR goldens live with the VC-router bitwise contract; the
# trivial-LinkSpec program must reproduce every one of them
from test_vc_router import _FCC2_HIST, _GOLDEN_CELLS, _GOLDENS


# ---------------------------------------------------------------------------
# bitwise weight-1 contract (satellite: golden pin)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell", sorted(_GOLDEN_CELLS))
def test_trivial_linkspec_bitwise_matches_goldens(cell):
    """`links=LinkSpec()` IS `links=None`: all pre-PR goldens reproduce
    bit for bit (ints and floats compared exactly, not approximately)."""
    g, pattern, load, kw, scen = _GOLDEN_CELLS[cell]
    r = simulate(g, pattern, load,
                 config=SimConfig(scenario=scen, links=LinkSpec(), **kw))
    for k, v in _GOLDENS[cell].items():
        got = getattr(r, k)
        if isinstance(v, float):
            assert got == v, (cell, k, got, v)
        else:
            assert int(got) == v, (cell, k, got, v)
    if "hist_bins" in kw:
        np.testing.assert_array_equal(r.latency_hist, _FCC2_HIST)


def test_weight1_spec_is_trivial_and_uniform_weights_too():
    assert LinkSpec().is_trivial
    assert LinkSpec(dim_weights=(1, 1, 1)).is_trivial
    assert LinkSpec(pillar_dim=2, pillar_every=1).is_trivial
    assert not LinkSpec(dim_weights=(1, 2)).is_trivial
    assert not LinkSpec(pillar_dim=2, pillar_every=2).is_trivial
    assert not LinkSpec(express=((0, 2, 1),)).is_trivial
    # trivial specs share the None fingerprint: one compile-cache entry
    assert LinkSpec().fingerprint() is None
    assert LinkSpec(dim_weights=(1, 1)).fingerprint() is None


# ---------------------------------------------------------------------------
# weighted differential: batched ≡ reference within ±5% per load point
# ---------------------------------------------------------------------------

def test_weighted_differential_batched_vs_reference():
    g = Torus(4, 4)
    t = build_tables(g)
    ls = LinkSpec(dim_weights=(1, 2))
    for load in (0.2, 0.4, 0.6):
        runs = {}
        for impl in ("batched", "reference"):
            r = runs[impl] = simulate(
                g, "uniform", load,
                config=SimConfig(slots=160, warmup=0, seed=3, impl=impl,
                                 links=ls, tables=t))
            # exact conservation at warmup=0, weighted or not
            assert r.delivered + r.in_flight + r.dropped == r.injected
        a, b = runs["batched"], runs["reference"]
        assert a.accepted_load == pytest.approx(b.accepted_load, rel=0.05), \
            (load, a.accepted_load, b.accepted_load)


def test_weights_slow_the_fabric_monotonically():
    """Same run, heavier Z: average latency rises monotonically, and at
    a saturating offered load the weight-4 fabric accepts measurably
    less than the uniform one — the weight axis reaches the physics."""
    g = Torus(4, 4, 4)
    t = build_tables(g)
    lat = []
    acc = []
    for wz in (1, 2, 4):
        r = simulate(g, "uniform", 0.8,
                     config=SimConfig(slots=160, warmup=32, seed=1,
                                      links=LinkSpec(dim_weights=(1, 1, wz)),
                                      tables=t))
        lat.append(r.avg_latency_cycles)
        acc.append(r.accepted_load)
    assert lat[0] < lat[1] < lat[2], lat
    assert acc[2] < 0.9 * acc[0], acc


# ---------------------------------------------------------------------------
# express channels (acceptance: mixed-radix torus vs lattice peer)
# ---------------------------------------------------------------------------

def test_express_port_geometry_invariants():
    """Extended ports keep the two structural invariants the whole
    simulator relies on: opp(p) == p ^ 1 and nbr[nbr[u, p], p ^ 1] == u."""
    g = Torus(8, 4)
    ls = LinkSpec(express=((0, 2, 1), (0, 4, 2)))
    nbr = ls.extended_neighbors(g)
    P = ls.num_ports(g.n)
    assert nbr.shape == (g.order, P) and P == 2 * g.n + 4
    for p in range(P):
        back = nbr[nbr[:, p], p ^ 1]
        np.testing.assert_array_equal(back, np.arange(g.order))
    # span-2 express really lands 2 hops away along dim 0
    lab = np.asarray(g.labels)
    np.testing.assert_array_equal(
        lab[nbr[:, 2 * g.n]][:, 0], (lab[:, 0] + 2) % 8)


def test_express_raises_mixed_radix_saturation_toward_lattice_peer():
    """The acceptance cell: T(8,4) is capacity-limited by its long axis
    (analytic ceiling Δ/(n·k̄_max) = 1.0 phit/cycle/node).  A span-2
    express overlay on that axis lifts routed saturation ABOVE the
    ceiling, closing more than half the gap to the same-order (32-node)
    BCC(2) lattice peer measured with the identical methodology."""
    g = Torus(8, 4)
    base = saturation(g, links=LinkSpec(dim_weights=(1, 1)), pairs=20_000)
    ex = saturation(g, links=LinkSpec(express=((0, 2, 1),)), pairs=20_000)
    peer = saturation(BCC(2), links=LinkSpec(dim_weights=(1, 1, 1)),
                      pairs=20_000)
    assert ex > 1.5 * base, (base, ex)
    assert ex > 1.0                    # beats the analytic mixed ceiling
    assert peer > base
    assert (ex - base) / (peer - base) > 0.5, (base, ex, peer)


def test_express_lowers_simulated_latency_both_impls():
    g = Torus(8, 4)
    t = build_tables(g)
    ls = LinkSpec(express=((0, 2, 1),))
    for impl in ("batched", "reference"):
        cfg = SimConfig(slots=160, warmup=32, seed=0, impl=impl, tables=t)
        r0 = simulate(g, "uniform", 0.3, config=cfg)
        r1 = simulate(g, "uniform", 0.3, config=cfg.replace(links=ls))
        assert r1.avg_latency_cycles < 0.9 * r0.avg_latency_cycles, \
            (impl, r0.avg_latency_cycles, r1.avg_latency_cycles)
        assert r1.delivered > 0


def test_express_shortens_weighted_distances():
    g = Torus(8, 4)
    d0 = weighted_distance_matrix(g, LinkSpec(dim_weights=(1, 1)))
    d1 = weighted_distance_matrix(g, LinkSpec(express=((0, 2, 1),)))
    assert (d1 <= d0).all()
    assert (d1 < d0).any()
    # antipodal along dim 0: 4 base hops collapse onto 2 express hops
    u = int(g.label_to_index(np.array([0, 0])))
    v = int(g.label_to_index(np.array([4, 0])))
    assert d0[u, v] == 4 and d1[u, v] == 2


# ---------------------------------------------------------------------------
# pillar Z-connectivity
# ---------------------------------------------------------------------------

def test_pillar_mask_structure():
    g = Torus(4, 4, 4)
    ls = LinkSpec(pillar_dim=2, pillar_every=2)
    m = ls.structural_mask(g)
    lab = np.asarray(g.labels)
    pillar = (lab[:, 0] % 2 == 0) & (lab[:, 1] % 2 == 0)
    np.testing.assert_array_equal(m[:, 4], pillar)
    np.testing.assert_array_equal(m[:, 5], pillar)
    assert m[:, :4].all()              # in-plane links untouched
    # symmetric: u and its Z-neighbour agree, so no half-dead channels
    nbr = np.asarray(g.neighbor_indices)
    np.testing.assert_array_equal(m[:, 4], m[nbr[:, 4], 5])


def test_pillar_kills_nonpillar_z_crossings_and_conserves():
    g = Torus(4, 4, 4)
    ls = LinkSpec(pillar_dim=2, pillar_every=2)
    mask = ls.structural_mask(g)
    for impl in ("batched", "reference"):
        r = simulate(g, "uniform", 0.4,
                     config=SimConfig(slots=128, warmup=0, seed=4, impl=impl,
                                      links=ls,
                                      scenario=Scenario(policy="adaptive")))
        assert r.delivered + r.in_flight + r.dropped == r.injected
        assert r.delivered > 0
        assert r.link_use is not None
        assert int(r.link_use[~mask].sum()) == 0, impl   # the audit
        assert int(r.link_use[:, 4:6][mask[:, 4:6]].sum()) > 0


def test_pillar_composes_with_fault_schedule():
    """Epoch link_ok stacks AND in the static pillar mask: a mid-run
    link flap on an in-plane channel coexists with the pillar holes,
    per-slot conservation and the dead-crossing audit stay exact."""
    g = Torus(4, 4, 4)
    ls = LinkSpec(pillar_dim=2, pillar_every=2)
    sched = FaultSchedule.link_flap((1, 0), down_at=24, up_at=60,
                                    policy="adaptive")
    r = simulate(g, "uniform", 0.5,
                 config=SimConfig(slots=96, warmup=0, seed=2, links=ls,
                                  schedule=sched))
    tl = r.timeline
    assert tl is not None
    assert tl.conservation_ok(), tl.conservation_violations()
    assert tl.dead_crossings.sum() == 0
    mask = ls.structural_mask(g)
    assert int(r.link_use[~mask].sum()) == 0


def test_pillar_disconnection_is_detected_not_silent():
    """pillar_every=4 on T(4,4,4) leaves a single pillar column; routing
    the weighted tables still reaches everything through it (finite
    distances), but a ring schedule that needs an unreachable edge under
    a *disconnecting* mask raises rather than emitting a bogus path."""
    g = Torus(4, 4, 4)
    ls = LinkSpec(pillar_dim=2, pillar_every=4)
    d = weighted_distance_matrix(g, ls)
    assert (d >= 0).all()              # single pillar still connects
    assert d.max() > int(g.diameter)   # ...at a real detour cost


# ---------------------------------------------------------------------------
# composition: vcs ≥ 2, schedules, fused rejection
# ---------------------------------------------------------------------------

def test_weights_compose_with_vc_router():
    g = Torus(4, 4)
    ls = LinkSpec(dim_weights=(1, 3))
    for impl in ("batched", "reference"):
        r = simulate(g, "uniform", 0.4,
                     config=SimConfig(slots=128, warmup=0, seed=6, impl=impl,
                                      vcs=2, links=ls))
        assert r.delivered + r.in_flight + r.dropped == r.injected
        assert r.delivered > 0
        assert int(np.asarray(r.vc_delivered).sum()) == r.delivered


def test_weights_compose_with_fault_schedule_every_slot():
    g = Torus(4, 4)
    sched = FaultSchedule(events=((16, "link_down", (1, 0)),
                                  (48, "link_up", (1, 0))),
                          base=Scenario(policy="adaptive"))
    r = simulate(g, "uniform", 0.6,
                 config=SimConfig(slots=96, warmup=0, seed=5,
                                  links=LinkSpec(dim_weights=(2, 1)),
                                  schedule=sched))
    tl = r.timeline
    assert tl.conservation_ok(), tl.conservation_violations()
    assert tl.dead_crossings.sum() == 0


def test_fused_rejects_nontrivial_spec():
    g = Torus(4, 4)
    with pytest.raises(ValueError, match="fused"):
        SimConfig(impl="fused", links=LinkSpec(dim_weights=(1, 2)))
    # the trivial spec is fine — it IS the weight-1 program
    r = simulate(g, "uniform", 0.3,
                 config=SimConfig(slots=64, warmup=0, seed=0, impl="fused",
                                  links=LinkSpec()))
    assert r.delivered > 0


def test_express_config_guards():
    # ISSUE 9 lifted the pristine-fabric and vcs=1-only guards: express
    # now composes with VCs and with fault scenarios/schedules.  The one
    # remaining exclusion is the V=1 adaptive/escape heuristics, whose
    # port scoring is base-lattice-only.
    assert SimConfig(vcs=2, links=LinkSpec(express=((0, 2, 1),))).vcs == 2
    assert SimConfig(links=LinkSpec(express=((0, 2, 1),)),
                     scenario=Scenario(dead_links=((0, 0),))).links.express
    with pytest.raises(ValueError, match="greedy"):
        SimConfig(links=LinkSpec(express=((0, 2, 1),)),
                  scenario=Scenario(dead_links=((0, 0),),
                                    policy="adaptive"))
    with pytest.raises(ValueError):
        LinkSpec(express=((0, 2, 1),), pillar_dim=2, pillar_every=2)
    with pytest.raises(ValueError):
        LinkSpec(express=((0, 1, 1),))          # span-1 is a base link
    with pytest.raises(ValueError):
        LinkSpec(dim_weights=(0, 1))


# ---------------------------------------------------------------------------
# analytic layer exactness
# ---------------------------------------------------------------------------

def test_trivial_weighted_distances_equal_hop_distances():
    g = Torus(4, 4, 4)
    dw = weighted_distance_matrix(g, LinkSpec(dim_weights=(1, 1, 1)))
    dh = faulted_distance_matrix(g, Scenario())
    np.testing.assert_array_equal(dw, dh)


def test_uniform_weight_scaling_doubles_costs_exactly():
    g = Torus(4, 4)
    d1 = weighted_distance_matrix(g, LinkSpec(dim_weights=(1, 1)))
    d2 = weighted_distance_matrix(g, LinkSpec(dim_weights=(2, 2)))
    np.testing.assert_array_equal(d2, 2 * d1)
    a1 = distance_stats(
        g, links=LinkSpec(dim_weights=(1, 1)))["average_distance"]
    a2 = distance_stats(
        g, links=LinkSpec(dim_weights=(2, 2)))["average_distance"]
    assert a2 == pytest.approx(2 * a1)


def test_weighted_channel_load_shapes_and_saturation():
    g = Torus(4, 4)
    ls = LinkSpec(dim_weights=(1, 2))
    stats = channel_load_stats(g, links=ls, pairs=5_000, seed=1)
    load = stats["load"]
    assert load.shape == (g.order, 4)
    w = ls.port_weights(g.n)
    theta = saturation(g, links=ls, pairs=5_000, seed=1)
    assert theta == pytest.approx(1.0 / float((load * w[None, :]).max()))
    assert stats["saturation"] == pytest.approx(theta)
    # heavier dim-1 channels cap saturation below the uniform fabric's
    theta1 = saturation(g, links=LinkSpec(dim_weights=(1, 1)),
                        pairs=5_000, seed=1)
    assert theta < theta1
