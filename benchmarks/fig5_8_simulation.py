"""Paper Figures 5–8: simulated throughput peaks + latency for the crystal
lattices vs the BlueGene-style mixed-radix tori.

Each (graph, pattern) load curve is ONE device program: `simulate_sweep`
vmaps the port-batched simulator over the offered-load axis, so the sweep
compiles once and runs with no host round-trips between load points.

Full mode runs the paper's exact networks (T(16,8,8,8) vs 4D-FCC(8),
T(8,8,8,4) vs 4D-BCC(4)); quick mode runs the small pair only.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import FourD_BCC, FourD_FCC, SimConfig, Torus
from repro.core.simulation import build_tables, simulate_sweep

from .util import emit

PATTERNS = ("uniform", "randompairings", "antipodal", "centralsymmetric")

# paper-reported throughput-peak gains (crystal vs torus), Figures 5 & 6
PAPER_GAINS = {
    ("small", "uniform"): 1.26, ("small", "randompairings"): 1.16,
    ("small", "antipodal"): 1.62, ("small", "centralsymmetric"): 1.45,
    ("large", "uniform"): 1.50, ("large", "randompairings"): 1.02,
    ("large", "antipodal"): 1.75, ("large", "centralsymmetric"): 1.23,
}


def peak(g, tables, pattern, loads, slots, warmup, seed=3, seeds=None,
         hist_bins=0):
    """Throughput peak over the load sweep.  With `seeds` the sweep gains
    the multi-seed axis (one device program) and the peak comes back as
    mean ± CI half-width over the seed axis — the Figs 5–8 error bars.
    With `hist_bins` the sweep also collects latency histograms and the
    fourth return is the exact p99 latency (cycles, seed-pooled) at the
    peak load (NaN without hist_bins)."""
    cfg = SimConfig(slots=slots, warmup=warmup, tables=tables, seed=seed,
                    hist_bins=hist_bins)
    if seeds is None:
        res = simulate_sweep(g, pattern, loads, config=cfg)
        best = max(res, key=lambda r: r.accepted_load)
        p99 = best.latency_p99 if hist_bins else float("nan")
        return best.accepted_load, 0.0, best.avg_latency_cycles, p99
    st = simulate_sweep(g, pattern, loads, config=cfg, seeds=seeds)
    mean = st.accepted_mean()
    i = int(np.argmax(mean))
    p99 = float(st.latency_p99()[i]) if hist_bins else float("nan")
    return float(mean[i]), float(st.accepted_ci()[i]), \
        float(st.latency_mean()[i]), p99


def run_pair(tag: str, torus, crystal, loads, slots, warmup, seeds=None,
             hist_bins=0):
    t_tab = build_tables(torus)
    c_tab = build_tables(crystal)
    for pattern in PATTERNS:
        t0 = time.perf_counter()
        pt, et, lt, qt = peak(torus, t_tab, pattern, loads, slots, warmup,
                              seeds=seeds, hist_bins=hist_bins)
        pc_, ec, lc, qc = peak(crystal, c_tab, pattern, loads, slots,
                               warmup, seeds=seeds, hist_bins=hist_bins)
        us = (time.perf_counter() - t0) * 1e6
        gain = pc_ / max(pt, 1e-9)
        row = (f"torus_peak={pt:.3f};crystal_peak={pc_:.3f};"
               f"gain={gain:.2f};"
               f"paper_gain={PAPER_GAINS[(tag, pattern)]};"
               f"torus_ci={et:.3f};crystal_ci={ec:.3f};"
               f"torus_lat={lt:.0f};crystal_lat={lc:.0f}")
        if hist_bins:
            row += f";torus_p99={qt:.0f};crystal_p99={qc:.0f}"
        emit(f"fig5_8/{tag}/{pattern}", us, row)


def main(quick: bool = False) -> None:
    loads = np.array([0.3, 0.6, 1.0]) if quick else \
        np.array([0.2, 0.4, 0.6, 0.8, 1.0])
    slots = 192 if quick else 288
    warmup = 48 if quick else 64
    # full mode: 2-seed error bars + exact p99 tail columns from the
    # in-carry histograms (quick CI smoke stays single-seed, no hist)
    seeds = None if quick else 2
    bins = 0 if quick else 64
    run_pair("small", Torus(8, 8, 8, 4), FourD_BCC(4), loads, slots, warmup,
             seeds=seeds, hist_bins=bins)
    if not quick:
        run_pair("large", Torus(16, 8, 8, 8), FourD_FCC(8), loads, slots,
                 warmup, seeds=seeds, hist_bins=bins)


if __name__ == "__main__":
    main()
