"""Latency-telemetry tests (ISSUE 6): the three accounting bugfixes
(warmup birth bias, zero-delivered NaN, delivered-weighted sweep means),
the in-carry age histogram across all three slot_step implementations,
cycle-exact percentiles against the reference per-packet oracle, and the
post-repair recovery metric.

Property strategies stay inside the `tests/_propcheck.py` shim subset
(`integers`, `sampled_from`, `@given`, `@settings`), so this module runs
offline in CI exactly as with real hypothesis.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import BCC, FCC, PC, RTT, FaultSchedule, Scenario, Torus
from repro.core.simulation import (PACKET_PHITS, SimResult, SimTimeline,
                                   SweepStats, build_tables,
                                   reference_latency_samples,
                                   schedule_recovery_slots, simulate,
                                   simulate_schedule_sweep, simulate_sweep)

# shared run shape + bucket count → one compile per (graph, impl) across
# all examples (hist_bins is part of the runner cache key)
SLOTS, WARMUP, BINS = 160, 40, 64

_GRAPHS = {
    "BCC2": BCC(2),          # 32 nodes
    "PC2": PC(2),            # 8 nodes
    "T442": Torus(4, 4, 2),  # 32 nodes, mixed-radix
}
_TABLES = {k: build_tables(g) for k, g in _GRAPHS.items()}
IMPLS = ("batched", "fused", "reference")


def _run(name, load, seed, impl="batched", pattern="uniform", **kw):
    g = _GRAPHS[name]
    kw.setdefault("slots", SLOTS)
    kw.setdefault("warmup", WARMUP)
    return simulate(g, pattern, load, seed=seed, tables=_TABLES[name],
                    impl=impl, **kw)


# ---------------------------------------------------------------- bugfixes
@pytest.mark.parametrize("impl", IMPLS)
def test_warmup_bias_no_measured_packets_is_nan(impl):
    """Regression (warmup birth bias): with warmup = slots−1 no packet can
    be BORN in the measured window and also deliver, so the measured
    population is empty and the mean must be NaN.  Pre-fix the mean
    averaged warmup-era births delivered in the last slot — a finite,
    inflated number."""
    r = _run("BCC2", 0.6, seed=3, impl=impl, warmup=SLOTS - 1)
    assert r.delivered > 0          # the window itself saw deliveries
    assert r.lat_count == 0
    assert np.isnan(r.avg_latency_cycles), (impl, r.avg_latency_cycles)


def test_warmup_bias_oracle_mean_is_measured_population():
    """Regression (warmup birth bias), exact form: the reported mean
    equals the per-packet mean over packets BORN at/after warmup — and
    provably differs from the pre-fix population (packets DELIVERED after
    warmup regardless of birth) at high load, where warmup-era births
    carry inflated queue-buildup ages."""
    r, s = reference_latency_samples(
        _GRAPHS["BCC2"], "uniform", 1.0, slots=SLOTS, warmup=WARMUP,
        seed=1, tables=_TABLES["BCC2"], hist_bins=BINS)
    measured, window = s["measured"], s["window"]
    assert measured.size == r.lat_count
    assert np.isclose(r.avg_latency_cycles,
                      PACKET_PHITS * measured.mean(), atol=1e-9)
    # the bias is real at saturation: the old population is strictly
    # larger and strictly slower on average
    assert window.size > measured.size
    assert window.mean() > measured.mean()


@pytest.mark.parametrize("impl", IMPLS)
def test_zero_delivered_reports_nan_not_zero(impl):
    """Regression (max(delivered, 1) bug): a run that delivers nothing
    must report NaN latency, not a fake 0.0 cycles."""
    r = _run("PC2", 0.0, seed=0, impl=impl, slots=64, warmup=16)
    assert r.delivered == 0
    assert np.isnan(r.avg_latency_cycles)


def _fake_result(mean, count):
    return SimResult(accepted_load=0.0, avg_latency_cycles=mean,
                     delivered=count, injected=count, slots=SLOTS,
                     lat_count=count)


def test_sweepstats_latency_mean_is_delivered_weighted():
    """Regression (unweighted seed mean): a starved seed (few measured
    deliveries) must not drag the per-load mean with full weight."""
    stats = SweepStats(
        loads=(0.5, 0.9), seeds=(0, 1),
        results=((_fake_result(10.0, 900), _fake_result(20.0, 100)),
                 (_fake_result(30.0, 0), _fake_result(50.0, 400))))
    m = stats.latency_mean()
    # load 0: weighted (10·900 + 20·100)/1000 = 11, NOT the unweighted 15
    assert np.isclose(m[0], 11.0), m
    # load 1: the zero-count NaN seed drops out entirely
    assert np.isclose(m[1], 50.0), m


def test_sweepstats_latency_mean_all_nan_load_is_nan():
    stats = SweepStats(loads=(0.1,), seeds=(0, 1),
                       results=((_fake_result(float("nan"), 0),
                                 _fake_result(float("nan"), 0)),))
    assert np.isnan(stats.latency_mean()[0])


def test_sweep_end_to_end_weighted_mean_matches_manual():
    """The weighted mean through a real multi-seed sweep equals the
    hand-pooled per-seed sums."""
    st_ = simulate_sweep(_GRAPHS["PC2"], "uniform", [0.3, 0.7],
                         slots=SLOTS, warmup=WARMUP, seed=0, seeds=3,
                         tables=_TABLES["PC2"], hist_bins=BINS)
    for li in range(2):
        row = st_.results[li]
        tot = sum(r.lat_count for r in row)
        pooled = sum(r.avg_latency_cycles * r.lat_count for r in row) / tot
        assert np.isclose(st_.latency_mean()[li], pooled)
        # pooled histogram mass == pooled count
        assert st_.latency_hist()[li].sum() == tot


# ------------------------------------------------------- property tests
@settings(max_examples=6)
@given(name=st.sampled_from(sorted(_GRAPHS)),
       load=st.sampled_from([0.1, 0.4, 0.8]),
       seed=st.integers(0, 4),
       impl=st.sampled_from(["batched", "reference"]))
def test_hist_total_equals_measured_count(name, load, seed, impl):
    """Histogram mass == lat_count in every cell; with warmup=0 every
    delivery is measured, so both equal `delivered`."""
    r = _run(name, load, seed, impl=impl, hist_bins=BINS)
    assert int(r.latency_hist.sum()) == r.lat_count
    r0 = _run(name, load, seed, impl=impl, warmup=0, hist_bins=BINS)
    assert int(r0.latency_hist.sum()) == r0.lat_count == r0.delivered


@settings(max_examples=6)
@given(name=st.sampled_from(sorted(_GRAPHS)),
       seed=st.integers(0, 4),
       pattern=st.sampled_from(["uniform", "antipodal"]))
def test_min_latency_at_least_routed_distance(name, seed, pattern):
    """Below saturation the youngest delivery still pays its route: one
    injection slot + one slot per hop, so the smallest occupied bucket is
    ≥ min routed distance + 1 (uniform) / diameter + 1 (antipodal — every
    pair of these point-symmetric lattices sits at max distance)."""
    g = _GRAPHS[name]
    r = _run(name, 0.15, seed, pattern=pattern, hist_bins=BINS)
    nz = np.flatnonzero(r.latency_hist)
    assert nz.size > 0
    d = g.distances_from_origin
    bound = (g.diameter if pattern == "antipodal"
             else int(d[d > 0].min())) + 1
    assert nz.min() >= bound, (nz.min(), bound)


_SCENARIOS = {
    "trivial": None,
    "links_dor": Scenario.random_link_faults(_GRAPHS["BCC2"], 3, seed=7),
    "links_adapt": Scenario.random_link_faults(_GRAPHS["BCC2"], 3, seed=8,
                                               policy="adaptive"),
}


@settings(max_examples=6)
@given(load=st.sampled_from([0.3, 0.8]),
       seed=st.integers(0, 4),
       scen=st.sampled_from(sorted(_SCENARIOS)),
       pattern=st.sampled_from(["uniform", "randompairings"]))
def test_batched_fused_histograms_bitwise_equal(load, seed, scen, pattern):
    """The fused Pallas wrapper reconstructs birth from the kernel's lat
    output — its histogram must equal the batched one bit for bit, like
    every other counter."""
    kw = dict(pattern=pattern, scenario=_SCENARIOS[scen], hist_bins=BINS)
    rb = _run("BCC2", load, seed, impl="batched", **kw)
    rf = _run("BCC2", load, seed, impl="fused", **kw)
    assert np.array_equal(rb.latency_hist, rf.latency_hist)
    assert rb.lat_count == rf.lat_count
    assert (np.isnan(rb.avg_latency_cycles)
            and np.isnan(rf.avg_latency_cycles)) \
        or rb.avg_latency_cycles == rf.avg_latency_cycles


@settings(max_examples=4)
@given(seed=st.integers(0, 3),
       scen=st.sampled_from(["links_dor", "links_adapt"]))
def test_e1_schedule_hist_equals_static_scenario(seed, scen):
    """A degenerate single-epoch schedule is bitwise the static scenario
    run — including the histogram, and its timeline's cumulative
    histogram must end at the run total."""
    scenario = _SCENARIOS[scen]
    rs = _run("BCC2", 0.5, seed, scenario=scenario, hist_bins=BINS)
    rt = _run("BCC2", 0.5, seed,
              schedule=FaultSchedule.from_scenario(scenario),
              hist_bins=BINS)
    assert np.array_equal(rs.latency_hist, rt.latency_hist)
    assert np.array_equal(rt.timeline.lat_hist[-1], rt.latency_hist)
    # cumulative: monotone non-decreasing per bucket
    assert (np.diff(rt.timeline.lat_hist, axis=0) >= 0).all()


# ----------------------------------------------- percentile oracle (exact)
_CELLS = {
    "T4444": Torus(4, 4, 4, 4),     # the acceptance 4-ary 4-cube
    "RTT2": RTT(2),
    "FCC2": FCC(2),
    "BCC2": BCC(2),
}


@pytest.mark.parametrize("cell", sorted(_CELLS))
def test_percentiles_cycle_exact_vs_oracle(cell):
    """Nearest-rank percentiles read off the bucketed histogram equal the
    ones computed from the oracle's per-packet ages EXACTLY (hist_bins
    exceeds any possible age, so no overflow truncation)."""
    g = _CELLS[cell]
    slots, warmup = 96, 24
    r, s = reference_latency_samples(g, "uniform", 0.3, slots=slots,
                                     warmup=warmup, seed=0,
                                     hist_bins=slots + 2)
    m = s["measured"]
    assert m.size == r.lat_count == int(r.latency_hist.sum())
    assert m.size > 0
    for q in (0.5, 0.99, 0.999):
        rank = min(m.size, max(1, int(np.ceil(q * m.size))))
        assert r.latency_percentile(q) == PACKET_PHITS * int(m[rank - 1]), \
            (cell, q)
    assert r.latency_p50 <= r.latency_p99 <= r.latency_p999
    # the mean agrees with the per-packet mean too
    assert np.isclose(r.avg_latency_cycles, PACKET_PHITS * m.mean())


def test_percentile_edge_cases():
    h = np.zeros(8, np.int64)
    r = SimResult(accepted_load=0.0, avg_latency_cycles=float("nan"),
                  delivered=0, injected=0, slots=1, latency_hist=h)
    assert np.isnan(r.latency_p99)                    # empty hist
    h2 = h.copy()
    h2[-1] = 5                                        # all mass overflows
    r2 = SimResult(accepted_load=0.0, avg_latency_cycles=0.0, delivered=5,
                   injected=5, slots=1, lat_count=5, latency_hist=h2)
    assert r2.latency_p50 == float("inf")
    with pytest.raises(ValueError):
        r2.latency_percentile(1.5)
    rnone = SimResult(accepted_load=0.0, avg_latency_cycles=0.0,
                      delivered=0, injected=0, slots=1)
    with pytest.raises(ValueError):
        rnone.latency_percentile(0.99)


# ------------------------------------------------------ recovery metric
def _synthetic_timeline(per_slot_hists):
    cum = np.cumsum(per_slot_hists, axis=0)
    z = np.zeros(len(per_slot_hists), np.int64)
    return SimTimeline(delivered=z, injected=z, dropped=z, in_flight=z,
                       dead_crossings=z, lat_hist=cum)


def test_recovery_slots_synthetic_deterministic():
    """Hand-built timeline: steady age-1 traffic, ages jump to 6 during
    the fault epoch [3, 5], back to 1 from slot 6.  With window=2 the
    windowed p99 stays elevated at the repair slot (its window still
    contains fault-era deliveries) and recovers exactly one slot later."""
    B = 8
    per = []
    for s in range(10):
        h = np.zeros(B, np.int64)
        h[6 if 3 <= s <= 5 else 1] = 5
        per.append(h)
    tl = _synthetic_timeline(per)
    assert tl.recovery_slots(3, 6, q=0.99, window=2) == 1
    # a wide-enough slack accepts the still-polluted repair-slot window
    assert tl.recovery_slots(3, 6, q=0.99, window=2,
                             slack_cycles=5 * PACKET_PHITS) == 0
    # percentile trace: elevated exactly while fault deliveries are in
    tr = tl.latency_percentile_trace(q=0.99, window=1)
    assert tr[2] == PACKET_PHITS and tr[4] == 6 * PACKET_PHITS
    with pytest.raises(ValueError):
        tl.recovery_slots(0, 5)         # fault_slot must be > 0
    with pytest.raises(ValueError):
        tl.recovery_slots(5, 3)         # repair before fault


def test_recovery_never_reached_is_none():
    per = [np.array([0, 5, 0, 0], np.int64) for _ in range(3)]
    per += [np.array([0, 0, 0, 5], np.int64) for _ in range(5)]
    tl = _synthetic_timeline(per)
    assert tl.recovery_slots(3, 4, q=0.99, window=2) is None


def test_schedule_recovery_slots_end_to_end():
    """A link flap on a real run: the metric comes back a non-negative
    int (or None on a run too short to recover), and the helper rejects
    schedules with no fault/repair pair and results without a
    timeline."""
    g = _GRAPHS["BCC2"]
    flap = FaultSchedule.link_flap((0, 0), 80, 120, policy="adaptive")
    r = simulate(g, "uniform", 0.6, slots=400, warmup=WARMUP, seed=5,
                 tables=_TABLES["BCC2"], schedule=flap, hist_bins=BINS)
    rec = schedule_recovery_slots(r, flap, q=0.99, window=48,
                                  slack_cycles=2 * PACKET_PHITS)
    assert rec is None or (isinstance(rec, int) and rec >= 0)
    with pytest.raises(ValueError):
        schedule_recovery_slots(r, FaultSchedule())
    plain = _run("BCC2", 0.6, 5, hist_bins=BINS)
    with pytest.raises(ValueError):
        schedule_recovery_slots(plain, flap)


def test_schedule_sweep_carries_histograms():
    """K×L×S schedule sweep: every lane's SimResult carries its histogram
    and the E=1 lane equals the static run bit for bit."""
    g = _GRAPHS["PC2"]
    scen = Scenario.random_link_faults(g, 2, seed=4, policy="adaptive")
    flap = FaultSchedule.link_flap((0, 0), 60, 100, policy="adaptive")
    out = simulate_schedule_sweep(g, "uniform", [scen, flap], [0.4, 0.8],
                                  slots=SLOTS, warmup=WARMUP, seed=1,
                                  tables=_TABLES["PC2"], hist_bins=BINS)
    for lane in out:
        for r in lane:
            assert r.latency_hist is not None
            assert int(r.latency_hist.sum()) == r.lat_count
            assert np.array_equal(r.timeline.lat_hist[-1], r.latency_hist)
    static = simulate(g, "uniform", 0.4, slots=SLOTS, warmup=WARMUP,
                      seed=1, tables=_TABLES["PC2"], scenario=scen,
                      hist_bins=BINS, fold=0)
    assert np.array_equal(out[0][0].latency_hist, static.latency_hist)
