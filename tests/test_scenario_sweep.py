"""Traced scenario masks + `simulate_scenario_sweep` (ISSUE 4).

The fault masks of the batched/fused simulator are traced inputs: K fault
patterns of one structure (policy × dead-node-ness) share a single
trace/compile, a changed mask never retraces, and the K-scenario sweep is
ONE vmapped device program whose per-scenario lanes are bitwise-equal to
single-scenario runs (the key grid is shared — common random numbers).
`repro.core.simulation.TRACE_COUNTS` counts runner-body executions, which
happen exactly once per jit trace.
"""
import pytest

from repro.core import Scenario, Torus
from repro.core.simulation import (TRACE_COUNTS, _RUNNER_CACHE, build_tables,
                                   simulate, simulate_scenario_sweep,
                                   simulate_sweep)

G = Torus(4, 4)
TABLES = build_tables(G)
KW = dict(slots=96, warmup=0, seed=2, tables=TABLES)


def link_scens(ks, policy="adaptive"):
    return [Scenario.random_link_faults(G, k, seed=10 + k, policy=policy)
            for k in ks]


def test_k4_patterns_compile_once():
    """K=4 distinct fault patterns through `simulate_scenario_sweep`
    trigger exactly ONE trace of the batched runner."""
    _RUNNER_CACHE.clear()
    n0 = TRACE_COUNTS["batched"]
    res = simulate_scenario_sweep(G, "uniform", link_scens((1, 2, 3, 4)),
                                  loads=(0.6,), **KW)
    assert TRACE_COUNTS["batched"] - n0 == 1
    assert len(res) == 4
    for scen, rl in zip(link_scens((1, 2, 3, 4)), res):
        for r in rl:
            assert r.delivered + r.in_flight + r.dropped == r.injected
            assert int(r.link_use[~scen.link_ok(G)].sum()) == 0


def test_changed_mask_does_not_retrace():
    """Sequential single runs with different fault patterns of the same
    structure reuse one compiled runner — masks are traced, not baked."""
    _RUNNER_CACHE.clear()
    a, b = link_scens((2, 5))
    simulate(G, "uniform", 0.6, scenario=a, **KW)
    n0 = TRACE_COUNTS["batched"]
    rb = simulate(G, "uniform", 0.6, scenario=b, **KW)
    assert TRACE_COUNTS["batched"] == n0          # no retrace
    assert len(_RUNNER_CACHE) == 1
    # and the traced masks really took effect (not a stale pattern)
    assert int(rb.link_use[~b.link_ok(G)].sum()) == 0
    # a structural change (policy) DOES trace a new program
    simulate(G, "uniform", 0.6, scenario=b.with_policy("escape"), **KW)
    assert TRACE_COUNTS["batched"] == n0 + 1


def test_sweep_lane_bitwise_equals_single_scenario_sweep():
    """Scenario lane k of the vmapped sweep == the single-scenario sweep
    with the same loads/seeds, counter for counter (shared key grid)."""
    scens = link_scens((1, 3))
    res = simulate_scenario_sweep(G, "uniform", scens, loads=(0.3, 0.8),
                                  **KW)
    for scen, rl in zip(scens, res):
        single = simulate_sweep(G, "uniform", (0.3, 0.8), scenario=scen,
                                **KW)
        assert [r.delivered for r in rl] == [r.delivered for r in single]
        assert [r.injected for r in rl] == [r.injected for r in single]


def test_sweep_supports_seed_axis_and_dead_nodes():
    """(K scenarios × loads × seeds) in one program, dead-node patterns
    included (traced live-destination tables of per-scenario length)."""
    scens = [Scenario(dead_nodes=(5,), policy="adaptive"),
             Scenario(dead_nodes=(2, 9), policy="adaptive")]
    res = simulate_scenario_sweep(G, "uniform", scens, loads=(0.4, 0.9),
                                  seeds=2, **KW)
    for scen, st in zip(scens, res):
        assert st.accepted().shape == (2, 2)
        for row in st.results:
            for r in row:
                assert r.delivered + r.in_flight + r.dropped == r.injected
                assert int(r.link_use[~scen.link_ok(G)].sum()) == 0
        # the dead node really is masked in every lane
        assert all(int(r.link_use[scen.dead_nodes[0]].sum()) == 0
                   for row in st.results for r in row)


def test_trivial_scenario_rides_the_traced_program():
    """A None/pristine entry runs on the traced-mask program with all-live
    masks — adopting the sweep's policy, since every policy routes the
    minimal DOR port on an all-live graph — and reproduces the dedicated
    pristine program's throughput within stochastic tolerance (same
    seeds, one arbitration stream)."""
    base = simulate(G, "uniform", 0.5, **KW)
    for policy in ("dor", "adaptive"):   # mixed None + non-dor must work
        res = simulate_scenario_sweep(
            G, "uniform",
            [None, Scenario.random_link_faults(G, 2, seed=3, policy=policy)],
            loads=(0.5,), **KW)
        pristine = res[0][0]
        assert pristine.delivered + pristine.in_flight == pristine.injected
        assert abs(pristine.accepted_load - base.accepted_load) <= \
            max(0.05 * base.accepted_load, 0.03), policy


def test_pristine_lane_rides_dead_node_sweep():
    """[None, dead-node-faulted] is the canonical degraded-vs-baseline
    comparison: the pristine lane adopts the dead-node program structure
    (live-table sampling over all N nodes) and conserves exactly."""
    scens = [None, Scenario(dead_nodes=(5, 10), policy="adaptive")]
    res = simulate_scenario_sweep(G, "uniform", scens, loads=(0.6,), **KW)
    for rl in res:
        r = rl[0]
        assert r.delivered + r.in_flight + r.dropped == r.injected
    # the pristine lane delivers at least as much as the degraded one
    assert res[0][0].delivered >= res[1][0].delivered
    # and its dead-channel audit is trivially clean (no dead channels)
    assert int(res[1][0].link_use[~scens[1].link_ok(G)].sum()) == 0


def test_single_scenario_sweep_degenerates_cleanly():
    """K=1 has no scenario vmap axis — the sweep must still run and equal
    the plain single-scenario sweep (leading-axis normalization
    regression)."""
    scen = link_scens((2,))[0]
    res = simulate_scenario_sweep(G, "uniform", [scen], loads=(0.5,), **KW)
    single = simulate_sweep(G, "uniform", (0.5,), scenario=scen, **KW)
    assert len(res) == 1
    assert res[0][0].delivered == single[0].delivered
    st = simulate_scenario_sweep(G, "uniform", [scen], loads=(0.3, 0.8),
                                 seeds=2, **KW)[0]
    assert st.accepted().shape == (2, 2)


def test_mixed_structure_rejected():
    with pytest.raises(ValueError, match="polic"):
        simulate_scenario_sweep(
            G, "uniform",
            [Scenario(policy="adaptive", dead_links=((1, 0),)),
             Scenario(policy="escape", dead_links=((1, 0),))], **KW)
    with pytest.raises(ValueError, match="dead-node"):
        simulate_scenario_sweep(
            G, "uniform",
            [Scenario(dead_nodes=(3,), policy="adaptive"),
             Scenario(dead_links=((1, 0),), policy="adaptive")], **KW)
    with pytest.raises(ValueError, match="traced-mask"):
        simulate_scenario_sweep(G, "uniform", link_scens((1,)),
                                impl="reference", **KW)
    with pytest.raises(ValueError, match=">= 1"):
        simulate_scenario_sweep(G, "uniform", [], **KW)
