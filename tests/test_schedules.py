"""Lattice-routing-derived collective schedules (topology.schedules)."""
import numpy as np
import pytest

from repro.core import BCC, PC, Torus
from repro.topology.placement import best_embedding
from repro.topology.schedules import (effective_ring_bandwidth, ring_schedule,
                                      verify_contention_free)
from test_distribution import run_in_subprocess


def test_ring_schedule_paths_are_valid_walks():
    g = PC(4)
    # a simple dimension-0 ring
    labels = np.zeros((4, 3), dtype=np.int64)
    labels[:, 0] = np.arange(4)
    sched = ring_schedule(g, labels)
    assert sched.dilation == 1.0
    stats = verify_contention_free(sched)
    assert stats["contention_free"]
    # wrap edge uses the +e1 link of node 3 (DOR minimal: one hop)
    assert all(len(p) == 1 for p in sched.edge_paths)


def test_bcc_embedding_rings_near_contention_free():
    g = BCC(4)
    be = best_embedding(g, (16, 16))
    coords = be["embedding"].coords
    # axis 1 (model): rings across the second logical axis
    sched = ring_schedule(g, coords[0, :, :])
    stats = verify_contention_free(sched)
    assert stats["dilation"] <= 2.0
    assert stats["max_link_use"] <= 2
    assert effective_ring_bandwidth(sched) >= 25e9


def test_torus_axis_ring_is_dilation_one():
    g = Torus(8, 8, 4)
    labels = np.zeros((8, 3), dtype=np.int64)
    labels[:, 1] = np.arange(8)
    sched = ring_schedule(g, labels)
    assert sched.dilation == 1.0
    assert verify_contention_free(sched)["contention_free"]


def test_ring_schedule_routes_around_dead_link():
    from repro.core import Scenario
    g = Torus(8, 8)
    labels = np.zeros((8, 2), dtype=np.int64)
    labels[:, 0] = np.arange(8)           # a dimension-0 ring
    pristine = ring_schedule(g, labels)
    assert pristine.dilation == 1.0
    # kill the +x link of chip (0,0): the 0 -> 1 logical edge must detour
    scen = Scenario(dead_links=((0, 0),))
    faulted = ring_schedule(g, labels, scenario=scen)
    assert faulted.dilation > 1.0
    dead = {(0, 0), (g.neighbor_indices[0, 0], 1)}
    for path in faulted.edge_paths:
        assert not dead & set(path)
    # every path still ends at its logical destination
    order = faulted.node_order
    for t, path in enumerate(faulted.edge_paths):
        pos = int(order[t])
        for u, p in path:
            assert u == pos
            pos = int(g.neighbor_indices[u, p])
        assert pos == int(order[(t + 1) % len(order)])


def test_ring_schedule_dead_chip_and_disconnect_raise():
    from repro.core import Scenario
    g = Torus(8)
    labels = np.arange(4, dtype=np.int64)[:, None] * 2
    with pytest.raises(ValueError, match="dead in scenario"):
        ring_schedule(g, labels, scenario=Scenario(dead_nodes=(2,)))
    # cutting both arcs between chips 0 and 2 disconnects the ring
    cut = Scenario(dead_links=((0, 0), (7, 0)))
    with pytest.raises(ValueError, match="unreachable"):
        ring_schedule(g, labels, scenario=cut)


def test_ppermute_ring_allreduce_equals_psum():
    out = run_in_subprocess("""
        from repro.topology.schedules import ppermute_ring_allreduce
        from jax.sharding import PartitionSpec as P
        mesh = jax.make_mesh((8,), ("ring",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        k = 8

        def local(seed):
            r = jax.lax.axis_index("ring")
            x = jax.random.normal(jax.random.fold_in(seed, r), (32, 16))
            ring = ppermute_ring_allreduce(x, "ring", k)
            ref = jax.lax.psum(x, "ring")
            return jnp.abs(ring - ref).max()

        with mesh:
            err = jax.jit(jax.shard_map(
                local, mesh=mesh, in_specs=P(), out_specs=P(),
                check_vma=False))(jax.random.PRNGKey(0))
        assert float(err) < 1e-5, float(err)
        print("RING_OK", float(err))
    """)
    assert "RING_OK" in out


def test_grad_ring_allreduce_matches_psum():
    out = run_in_subprocess("""
        from repro.topology.schedules import grad_ring_allreduce
        from jax.sharding import PartitionSpec as P
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)

        def local(seed):
            r = jax.lax.axis_index("data")
            grads = {"w": jax.random.normal(jax.random.fold_in(seed, r), (33,)),
                     "b": jax.random.normal(jax.random.fold_in(seed, r + 100), (7, 3))}
            ring = grad_ring_allreduce(grads, mesh, axis="data")
            ref = jax.tree.map(lambda g: jax.lax.psum(g, "data"), grads)
            return jnp.stack([jnp.abs(a - b).max()
                              for a, b in zip(jax.tree.leaves(ring),
                                              jax.tree.leaves(ref))]).max()

        with mesh:
            err = jax.jit(jax.shard_map(
                local, mesh=mesh, in_specs=P(), out_specs=P(),
                check_vma=False))(jax.random.PRNGKey(1))
        assert float(err) < 1e-5, float(err)
        print("GRAD_RING_OK", float(err))
    """)
    assert "GRAD_RING_OK" in out
