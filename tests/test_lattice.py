"""LatticeGraph structural invariants (hypothesis property tests)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (LatticeGraph, Torus, bcc_matrix, fcc_matrix,
                        symmetric_throughput_bound,
                        mixed_torus_throughput_bound, channel_load,
                        route_bcc, route_fcc)
from repro.core import intmat


def small_nonsingular(n=3, lo=-4, hi=4, max_det=300):
    return (
        st.lists(st.lists(st.integers(lo, hi), min_size=n, max_size=n),
                 min_size=n, max_size=n)
        .map(lambda rows: np.array(rows, dtype=np.int64))
        .filter(lambda M: 0 < abs(intmat.det(M)) <= max_det)
    )


@given(small_nonsingular())
@settings(max_examples=25, deadline=None)
def test_order_and_degree(M):
    g = LatticeGraph(M)
    assert g.order == abs(intmat.det(M))
    assert g.neighbor_indices.shape == (g.order, 2 * 3)
    # adjacency is an involution: +e_i then -e_i returns home
    nbr = g.neighbor_indices
    for i in range(3):
        fwd = nbr[:, 2 * i]
        back = nbr[fwd, 2 * i + 1]
        assert np.array_equal(back, np.arange(g.order))


@given(small_nonsingular())
@settings(max_examples=20, deadline=None)
def test_vertex_transitivity_of_distances(M):
    """Cayley graph: the multiset of distances from u equals that from 0."""
    g = LatticeGraph(M)
    if not g.is_connected():
        return
    d0 = np.sort(g.distances_from_origin)
    rng = np.random.default_rng(0)
    u = g.labels[rng.integers(0, g.order)]
    du = np.sort(g.distances_from_origin[g.label_to_index(g.labels - u)])
    assert np.array_equal(d0, du)


@given(small_nonsingular())
@settings(max_examples=20, deadline=None)
def test_triangle_inequality_and_symmetry(M):
    g = LatticeGraph(M)
    if not g.is_connected():
        return
    rng = np.random.default_rng(1)
    for _ in range(10):
        u, v, w = (g.labels[rng.integers(0, g.order)] for _ in range(3))
        duv, dvw, duw = g.distance(u, v), g.distance(v, w), g.distance(u, w)
        assert duw <= duv + dvw
        assert duv == g.distance(v, u)  # undirected


def test_distance_distribution_sums_to_order():
    g = LatticeGraph(fcc_matrix(3))
    assert g.distance_distribution().sum() == g.order


# ---------------------------------------------------------------------------
# throughput bounds (§3.4)
# ---------------------------------------------------------------------------

def test_throughput_gains_fcc_vs_torus():
    """FCC(a) vs T(2a,a,a): ≈71% gain under uniform traffic (paper §3.4)."""
    a = 8
    from repro.core import FCC
    gain = symmetric_throughput_bound(FCC(a)) / mixed_torus_throughput_bound(2 * a, a, a)
    assert gain == pytest.approx(1.71, abs=0.06)


def test_throughput_gains_bcc_vs_torus():
    """BCC(a) vs T(2a,2a,a): ≈37% gain (paper §3.4)."""
    a = 8
    from repro.core import BCC
    gain = symmetric_throughput_bound(BCC(a)) / mixed_torus_throughput_bound(2 * a, 2 * a, a)
    assert gain == pytest.approx(1.37, abs=0.06)


def test_channel_load_symmetric_graph_is_balanced():
    """Edge-symmetric + minimal routing with random sources → near-uniform
    directional link loads; mixed-radix torus → 2x imbalance across dims."""
    from repro.core import BCC
    g = BCC(2)
    rng = np.random.default_rng(3)
    pairs = 4000
    v = g.labels[rng.integers(0, g.order, pairs)] - g.labels[rng.integers(0, g.order, pairs)]
    rec = route_bcc(2, v, rng=rng)  # Remark 30: randomized tie-breaking
    load = channel_load(g, rec)
    per_dim = load.reshape(g.order, 3, 2).mean(axis=(0, 2))
    assert per_dim.max() / per_dim.min() < 1.25

    t = Torus(4, 2, 2)
    labels = t.labels
    v = labels[rng.integers(0, t.order, pairs)] - labels[rng.integers(0, t.order, pairs)]
    from repro.core import route_torus
    rec = route_torus((4, 2, 2), v)
    load = channel_load(t, rec)
    per_dim = load.reshape(t.order, 3, 2).mean(axis=(0, 2))
    assert per_dim.max() / per_dim.min() > 1.5  # long dimension dominates
