"""Paper Table 2: higher-dimensional lifts and hybrid lattice graphs."""
from __future__ import annotations

import time

from repro.core import (FourD_BCC, FourD_FCC, LatticeGraph, Lip, bcc_matrix,
                        boxplus, fcc_matrix, pc_matrix, rtt_matrix,
                        torus_matrix)

from .util import emit

# (name, matrix builder, paper diameter coeff, paper k̄ coeff) — values are
# asymptotic in a; measured values approach them as a grows
ROWS = [
    ("T(2a,2a)⊞RTT(a)", lambda a: boxplus(torus_matrix(2 * a, 2 * a), rtt_matrix(a)), 2.0, 1.14877),
    ("4D-FCC(a)", lambda a: None, 2.0, 1.10396),
    ("4D-BCC(a)", lambda a: None, 2.0, 1.5379),
    ("Lip(a)", lambda a: None, 3.0, 1.815),
    ("PC(2a)⊞BCC(a)", lambda a: boxplus(pc_matrix(2 * a), bcc_matrix(a)), 2.5, 1.59715),
    ("PC(2a)⊞FCC(a)", lambda a: boxplus(pc_matrix(2 * a), fcc_matrix(a)), 3.5, 1.87856),
    ("BCC(a)⊞FCC(a)", lambda a: boxplus(bcc_matrix(a), fcc_matrix(a)), 2.5, 1.52522),
]


def build(name: str, a: int) -> LatticeGraph:
    if name == "4D-FCC(a)":
        return FourD_FCC(a)
    if name == "4D-BCC(a)":
        return FourD_BCC(a)
    if name == "Lip(a)":
        return Lip(a)
    for n, fn, *_ in ROWS:
        if n == name:
            return LatticeGraph(fn(a))
    raise KeyError(name)


def main(quick: bool = False) -> None:
    for name, _, d_coef, k_coef in ROWS:
        a = 2 if name in ("Lip(a)", "PC(2a)⊞FCC(a)", "BCC(a)⊞FCC(a)") else 3
        if not quick and name in ("4D-FCC(a)", "4D-BCC(a)"):
            a = 4
        t0 = time.perf_counter()
        g = build(name, a)
        d, k = g.diameter, g.average_distance
        us = (time.perf_counter() - t0) * 1e6
        emit(f"table2/{name}[a={a}]", us,
             f"dim={g.n};N={g.order};D={d}(paper~{d_coef}a={d_coef*a:.1f});"
             f"kbar={k:.4f}(paper~{k_coef}a={k_coef*a:.3f})")


if __name__ == "__main__":
    main()
