"""Fused RMSNorm kernel: one pass over rows, mean-square + scale in VMEM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ._compat import CompilerParams


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # (rows, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) *
                  w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, weight, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = True):
    """x: (..., D) → same shape; rows processed in VMEM tiles."""
    orig_shape = x.shape
    D = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= int(s)
    x2 = x.reshape(rows, D)
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        block_rows = 1
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, weight)
    return out.reshape(orig_shape)
