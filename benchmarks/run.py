"""Benchmark driver: one section per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1,...]
                                            [--json out.json]

Prints `name,us_per_call,derived` CSV rows (benchmarks.util contract);
with --json the same rows are also written machine-readable (the schema
consumed by `benchmarks.check_regression` and committed as
BENCH_baseline.json — see docs/ci.md for the regression-gate policy).
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import traceback

from . import (compose_matrix, explore_bench, fig5_8_simulation,
               hetero_links, latency_telemetry, roofline,
               routing_throughput, scenario_sim, sim_throughput,
               table1_distances, table2_lattices, throughput_bounds,
               topology_collectives, transient_sim, util, vc_router)
from .util import header

SECTIONS = {
    "table1": table1_distances.main,
    "table2": table2_lattices.main,
    "routing": routing_throughput.main,
    "throughput": throughput_bounds.main,
    "sim": sim_throughput.main,
    "scenarios": scenario_sim.main,
    "transient": transient_sim.main,
    "latency": latency_telemetry.main,
    "vc": vc_router.main,
    "hetero": hetero_links.main,
    "compose": compose_matrix.main,
    "explore": explore_bench.main,
    "fig5_8": fig5_8_simulation.main,
    "topology": topology_collectives.main,
    "roofline": roofline.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of sections")
    ap.add_argument("--json", default="", metavar="OUT",
                    help="also write rows as JSON (bench-regression gate)")
    args = ap.parse_args()
    names = [s for s in args.only.split(",") if s] or list(SECTIONS)
    # validate section names upfront: a typo must be a clear one-line
    # error, not a generic "section failed" from the broad except below
    unknown = [n for n in names if n not in SECTIONS]
    if unknown:
        sys.exit(f"unknown section(s): {', '.join(unknown)}; "
                 f"choose from: {', '.join(SECTIONS)}")
    header()
    failed = []
    for name in names:
        try:
            SECTIONS[name](quick=args.quick)
        except Exception as e:  # noqa: BLE001 — finish remaining sections
            failed.append((name, e))
            traceback.print_exc()
    if args.json:
        doc = util.rows_as_json()
        doc["meta"] = {
            "quick": args.quick,
            "sections": names,
            "python": platform.python_version(),
            "machine": platform.machine(),
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {len(doc['rows'])} rows to {args.json}",
              file=sys.stderr)
    if failed:
        sys.exit(f"benchmark sections failed: {[n for n, _ in failed]}")


if __name__ == "__main__":
    main()
