"""VC credit-flow router cost + the saturation win over escape misrouting.

Two committed records of the ISSUE 7 router:

  * `vc/overhead` — the SAME cell run with the single-FIFO V=1 batched
    step and with the (N, 2n, V, Q) credit-flow router at `vcs=2`,
    interleaved best-of-`REPS`.  `vc_slots_per_s` gates the absolute VC
    throughput; `overhead_ratio` (v1_time / v2_time) is the committed
    price of the credit machinery (≈0.5 means V=2 costs 2× per slot —
    expected: the state is V× wider and arbitration spans (port, VC)).
    Pinned at N=512 in both modes: the quantity is per-slot router cost,
    not lattice scale.

  * `vc/ring_escape` — the n=1-ring livelock cell (T(8), one dead link,
    load 0.25).  The old `policy="escape"` misroute heuristic livelocks
    packets trapped between the fault and their destination; the VC
    router's restricted-DOR escape lane delivers them.  Both accepted
    loads are emitted with the `_sat_phits` gate suffix — deterministic
    given the seed, so the gate pins the win itself, not a timing.
"""
from __future__ import annotations

import time

from repro.core import Scenario, SimConfig, Torus
from repro.core.simulation import build_tables, simulate

from .util import emit

REPS = 3


def main(quick: bool = False) -> None:
    # ---- V=2 credit router vs V=1 single-FIFO, same cell ----
    g = Torus(8, 8, 4, 2)
    slots, warmup = 192, 48
    t = build_tables(g)
    cfg = SimConfig(slots=slots, warmup=warmup, seed=1, tables=t)

    def run(vcs):
        return simulate(g, "uniform", 0.6, config=cfg.replace(vcs=vcs))

    for v in (1, 2):                               # compile both first
        run(v)
    best = {1: float("inf"), 2: float("inf")}
    for _ in range(REPS):
        for v in (1, 2):
            t0 = time.perf_counter()
            run(v)
            best[v] = min(best[v], time.perf_counter() - t0)
    emit(f"vc/overhead/N={g.order}", best[2] * 1e6,
         f"vc_slots_per_s={slots / best[2]:.1f};"
         f"overhead_ratio={best[1] / best[2]:.3f};vcs=2")

    # ---- escape-lane saturation vs the misroute heuristic ----
    # the ROADMAP livelock cell: T(8) ring, dead link (0,0), load 0.25
    ring = Torus(8)
    rt = build_tables(ring)
    rcfg = SimConfig(slots=256, warmup=0, seed=3, tables=rt)
    esc = simulate(ring, "uniform", 0.25, config=rcfg.replace(
        scenario=Scenario(dead_links=((0, 0),), policy="escape")))
    vc = simulate(ring, "uniform", 0.25, config=rcfg.replace(
        scenario=Scenario(dead_links=((0, 0),), policy="adaptive"), vcs=2))
    emit(f"vc/ring_escape/N={ring.order}", 0.0,
         f"vc_sat_phits={vc.accepted_load:.4f};"
         f"escape_sat_phits={esc.accepted_load:.4f};"
         f"delivered_gain={vc.delivered / max(esc.delivered, 1):.2f}x;"
         f"in_flight_esc={esc.in_flight};in_flight_vc={vc.in_flight}")


if __name__ == "__main__":
    main()
