"""Step builders: the jit-able train / prefill / decode step functions that
the launcher lowers, compiles and runs.  These are shared by real training
(examples, launch/train.py) and the multi-pod dry-run.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import decode_step as model_decode
from repro.models import forward, prefill
from repro.models.common import cross_entropy
from repro.optim import adamw


def make_train_step(cfg, *, lr: float = 3e-4, remat: str = "dots",
                    impl: str = "xla", microbatch: int = 0, unroll: int = 1):
    """(params, opt_state, batch) → (params, opt_state, metrics).

    With microbatch > 0 the global batch is split and gradients accumulated
    with a lax.scan (keeps peak activation memory ∝ microbatch and lets XLA
    overlap the DP gradient reduction of step i with compute of i+1)."""

    def loss_fn(p, batch):
        logits, aux = forward(
            p, cfg, batch["tokens"],
            patch_embeds=batch.get("patch_embeds"),
            enc_frames=batch.get("enc_frames"),
            impl=impl, remat=remat, unroll=unroll)
        return cross_entropy(logits, batch["labels"]) + aux

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, batch):
        if microbatch:
            n = batch["tokens"].shape[0] // microbatch

            def slice_mb(i):
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * microbatch, microbatch, axis=0), batch)

            def body(carry, i):
                loss_acc, grads_acc = carry
                loss, grads = grad_fn(params, slice_mb(i))
                grads = jax.tree.map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero), jnp.arange(n))
            loss = loss / n
            grads = jax.tree.map(lambda g: g / n, grads)
        else:
            loss, grads = grad_fn(params, batch)
        params, opt_state = adamw.update(grads, opt_state, params, lr=lr)
        return params, opt_state, {"loss": loss}

    return train_step


def make_prefill_step(cfg, *, max_len: int, impl: str = "xla",
                      unroll: int = 1):
    """(params, batch) → (logits_last, cache)."""

    def prefill_step(params, batch):
        return prefill(
            params, cfg, batch["tokens"], max_len=max_len,
            patch_embeds=batch.get("patch_embeds"),
            enc_frames=batch.get("enc_frames"), impl=impl, unroll=unroll)

    return prefill_step


def make_decode_step(cfg, *, impl: str = "xla", sample: bool = False,
                     temperature: float = 1.0, unroll: int = 1):
    """(params, cache, token, position[, rng]) → (next_token, logits, cache).

    serve_step for the `decode_*` shape cells: one new token against a KV
    cache of seq_len."""

    def decode_fn(params, cache, token, position, rng=None):
        logits, cache = model_decode(params, cfg, token, cache, position,
                                     impl=impl, unroll=unroll)
        if sample:
            nxt = jax.random.categorical(
                rng, logits[:, -1, :].astype(jnp.float32) / temperature,
                axis=-1)
        else:
            nxt = jnp.argmax(logits[:, -1, :], axis=-1)
        return nxt.astype(jnp.int32)[:, None], logits, cache

    return decode_fn
