"""Heterogeneous-link fabric cost + the express-channel saturation win.

Two committed records of the ISSUE 8 overlay machinery:

  * `hetero/zweight` — the SAME cell run with the trivial `LinkSpec()`
    (bitwise the pre-heterogeneous program) and with 4× Z-weights,
    interleaved best-of-`REPS`.  `hetero_slots_per_s` gates the absolute
    weighted-step throughput; `overhead_ratio` (trivial_time /
    weighted_time) is the committed price of the busy/wait channel-hold
    carry entries — expected near 1 (two small countdown arrays and a
    handful of wheres on top of the V=1 step).

  * `hetero/express` — the mixed-radix acceptance cell: routed
    saturation (`channel_load_stats` Monte-Carlo, deterministic given
    the seed) of T(8,4) bare, T(8,4) with a span-2 express overlay on
    the long axis, and the same-order BCC(2) lattice peer.  All three
    carry the `_sat_phits` gate suffix, so the gate pins the express win
    itself (overlay above the analytic mixed-radix ceiling of 1.0,
    closing most of the gap to the peer), not a timing.
"""
from __future__ import annotations

import time

from repro.core import BCC, LinkSpec, SimConfig, Torus, saturation
from repro.core.simulation import build_tables, simulate

from .util import emit

REPS = 3


def main(quick: bool = False) -> None:
    # ---- weighted channel-hold step vs the trivial (weight-1) program ----
    g = Torus(8, 8, 2) if quick else Torus(8, 8, 4)
    slots, warmup = (96, 24) if quick else (192, 48)
    t = build_tables(g)
    cfg = SimConfig(slots=slots, warmup=warmup, seed=1, tables=t)
    cfgs = {
        "trivial": cfg.replace(links=LinkSpec()),
        "weighted": cfg.replace(links=LinkSpec(dim_weights=(1, 1, 4))),
    }

    def run(which):
        return simulate(g, "uniform", 0.6, config=cfgs[which])

    for which in cfgs:                             # compile both first
        run(which)
    best = {which: float("inf") for which in cfgs}
    for _ in range(REPS):
        for which in cfgs:
            t0 = time.perf_counter()
            run(which)
            best[which] = min(best[which], time.perf_counter() - t0)
    emit(f"hetero/zweight/N={g.order}", best["weighted"] * 1e6,
         f"hetero_slots_per_s={slots / best['weighted']:.1f};"
         f"overhead_ratio={best['trivial'] / best['weighted']:.3f};wz=4")

    # ---- express overlay vs the mixed-radix ceiling and the BCC peer ----
    pairs = 5_000 if quick else 20_000
    mixed = Torus(8, 4)
    base = saturation(mixed, links=LinkSpec(dim_weights=(1, 1)),
                      pairs=pairs)
    ex = saturation(mixed, links=LinkSpec(express=((0, 2, 1),)),
                    pairs=pairs)
    peer = saturation(BCC(2), links=LinkSpec(dim_weights=(1, 1, 1)),
                      pairs=pairs)
    emit(f"hetero/express/N={mixed.order}", 0.0,
         f"express_sat_phits={ex:.4f};base_sat_phits={base:.4f};"
         f"peer_sat_phits={peer:.4f};"
         f"gap_closed={(ex - base) / (peer - base):.2f}")


if __name__ == "__main__":
    main()
