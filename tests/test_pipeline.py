"""Pipeline parallelism correctness (subprocess with a 4-stage pipe mesh)."""
from test_distribution import run_in_subprocess


def test_pipeline_matches_sequential_forward_and_grad():
    out = run_in_subprocess("""
        from repro.parallel.pipeline import pipeline_apply

        L, D, B = 8, 16, 8
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (L, D, D)) * 0.2
        x = jax.random.normal(jax.random.fold_in(key, 1), (B, D))

        def layer_fn(w, h):
            return jnp.tanh(h @ w)

        def sequential(W, x):
            def body(c, w):
                return layer_fn(w, c), None
            return jax.lax.scan(body, x, W)[0]

        mesh = jax.make_mesh((4,), ("pipe",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        y_ref = sequential(W, x)
        with mesh:
            y_pp = jax.jit(lambda W, x: pipeline_apply(
                layer_fn, W, x, mesh, num_microbatches=4))(W, x)
        err = float(jnp.abs(y_ref - y_pp).max())
        assert err < 1e-5, err

        # gradients through the pipeline
        def loss_pp(W, x):
            return (pipeline_apply(layer_fn, W, x, mesh,
                                   num_microbatches=4) ** 2).sum()
        def loss_ref(W, x):
            return (sequential(W, x) ** 2).sum()
        with mesh:
            g_pp = jax.jit(jax.grad(loss_pp))(W, x)
        g_ref = jax.grad(loss_ref)(W, x)
        gerr = float(jnp.abs(g_pp - g_ref).max())
        rel = gerr / float(jnp.abs(g_ref).max())
        assert rel < 1e-4, (gerr, rel)
        print("PIPELINE_OK", err, rel)
    """)
    assert "PIPELINE_OK" in out


def test_pipeline_bubble_schedule_sizes():
    out = run_in_subprocess("""
        from repro.parallel.pipeline import pipeline_apply
        L, D, B = 4, 8, 16
        W = jnp.stack([jnp.eye(D)] * L)      # identity layers
        x = jax.random.normal(jax.random.PRNGKey(0), (B, D))
        mesh = jax.make_mesh((2,), ("pipe",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        with mesh:
            for M in (2, 4, 8):
                y = jax.jit(lambda W, x, M=M: pipeline_apply(
                    lambda w, h: h @ w, W, x, mesh,
                    num_microbatches=M))(W, x)
                assert float(jnp.abs(y - x).max()) < 1e-5
        print("SCHEDULE_OK")
    """)
    assert "SCHEDULE_OK" in out
