"""Executable collective schedules from lattice routing (paper §5 → TPU).

The paper's minimal routing records are integer hop vectors on the pod's
lattice graph.  This module turns them into *collective schedules*:

  * `ring_schedule` — orders the chips of one logical mesh axis along a ring
    embedded in the lattice (from topology.placement) and derives, for every
    logical edge, the physical ICI links its traffic crosses (DOR over the
    minimal record).  `verify_contention_free` checks that a collective step
    uses every physical link at most once — the condition for the ring
    collective to run at full link bandwidth (dilation-1 embeddings pass).

  * `ppermute_ring_allreduce` — a reduce-scatter + all-gather all-reduce
    written explicitly with `jax.lax.ppermute` (2·(k−1) neighbor hops),
    numerically equal to `psum`.  This is the deterministic, topology-aware
    collective the schedule prices; on a real pod the ppermute pairs are
    laid onto the `ring_schedule` order.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LatticeGraph
from repro.core.routing import make_router
from repro.parallel import _compat

_compat.install()     # jax<0.5: callers drive these helpers via shard_map


# ---------------------------------------------------------------------------
# physical link schedules from routing records
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RingSchedule:
    """One logical axis embedded as a ring of physical chips."""
    node_order: np.ndarray          # (k,) lattice node indices, ring order
    edge_paths: list[list[tuple[int, int]]]   # per logical edge: [(node, port)]
    dilation: float                 # mean physical hops per logical edge


def ring_schedule(g: LatticeGraph, ring_labels: np.ndarray) -> RingSchedule:
    """ring_labels: (k, n) lattice labels of the chips of one logical axis,
    in ring order.  Paths follow DOR over minimal routing records (all k
    logical edges routed in one batched engine call)."""
    router = make_router(g.matrix)
    k = ring_labels.shape[0]
    order = g.label_to_index(ring_labels)
    recs = np.asarray(router(np.roll(ring_labels, -1, axis=0) - ring_labels))
    paths: list[list[tuple[int, int]]] = []
    for t in range(k):
        src = ring_labels[t]
        rec = recs[t]
        path = []
        pos = src.copy()
        for dim in range(g.n):
            step = int(rec[dim])
            sgn = 1 if step >= 0 else -1
            for _ in range(abs(step)):
                port = 2 * dim + (0 if sgn > 0 else 1)
                path.append((int(g.label_to_index(pos)), port))
                pos = pos + sgn * np.eye(g.n, dtype=np.int64)[dim]
        paths.append(path)
    hops = [len(p) for p in paths]
    return RingSchedule(node_order=order, edge_paths=paths,
                        dilation=float(np.mean(hops)))


def verify_contention_free(sched: RingSchedule) -> dict:
    """In a ring collective step every logical edge is active simultaneously;
    full bandwidth requires each directional physical link to appear in at
    most one logical edge's path."""
    use: dict[tuple[int, int], int] = {}
    for path in sched.edge_paths:
        for link in path:
            use[link] = use.get(link, 0) + 1
    max_use = max(use.values()) if use else 0
    return {"contention_free": max_use <= 1, "max_link_use": max_use,
            "links_used": len(use), "dilation": sched.dilation}


def effective_ring_bandwidth(sched: RingSchedule, link_bw: float = 50e9) -> float:
    """Per-step ring bandwidth after contention: the busiest link serializes."""
    stats = verify_contention_free(sched)
    return link_bw / max(stats["max_link_use"], 1)


# ---------------------------------------------------------------------------
# explicit ppermute ring all-reduce (≡ psum)
# ---------------------------------------------------------------------------

def ppermute_ring_allreduce(x, axis_name: str, axis_size: int):
    """Bandwidth-optimal ring all-reduce via 2·(k−1) ppermute steps.

    Call inside shard_map.  x: any array whose leading dim is divisible by
    the ring size (the chunk dimension)."""
    k = axis_size
    if k == 1:
        return x
    chunks = jnp.stack(jnp.split(x, k, axis=0))       # (k, m/k, ...)
    perm = [(i, (i + 1) % k) for i in range(k)]
    rank = jax.lax.axis_index(axis_name)

    # reduce-scatter: after k-1 steps, chunk (rank+1) mod k is fully reduced
    def rs_step(t, buf):
        send_idx = (rank - t) % k
        piece = jnp.take(buf, send_idx, axis=0)
        received = jax.lax.ppermute(piece, axis_name, perm)
        recv_idx = (rank - t - 1) % k
        return buf.at[recv_idx].add(received)

    buf = jax.lax.fori_loop(0, k - 1, rs_step, chunks)

    # all-gather: circulate the reduced chunks
    def ag_step(t, buf):
        send_idx = (rank + 1 - t) % k
        piece = jnp.take(buf, send_idx, axis=0)
        received = jax.lax.ppermute(piece, axis_name, perm)
        recv_idx = (rank - t) % k
        return buf.at[recv_idx].set(received)

    buf = jax.lax.fori_loop(0, k - 1, ag_step, buf)
    return buf.reshape(x.shape)


def grad_ring_allreduce(grads, mesh, axis: str = "data"):
    """DP gradient all-reduce over one mesh axis using the explicit ring —
    a drop-in for psum when the collective must follow a known physical ring
    order (e.g. the `ring_schedule` embedding).  Call inside shard_map."""
    k = mesh.shape[axis]

    def one(g):
        flat = g.reshape(-1)
        pad = (-flat.shape[0]) % k
        if pad:
            flat = jnp.pad(flat, (0, pad))
        out = ppermute_ring_allreduce(flat, axis, k)
        return out[: g.size].reshape(g.shape)

    return jax.tree.map(one, grads)
