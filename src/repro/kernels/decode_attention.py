"""Single-query attention over a long KV cache (decode_32k / long_500k path).

Grid (BH, kv_blocks): one query row per batch·head, KV streamed through VMEM
in `block_k` tiles; online softmax state in scratch.  Slots beyond the
current `position` are masked (the cache is allocated at max length).  The
query is padded to 8 rows by the ops wrapper to satisfy TPU sublane tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ._compat import CompilerParams

NEG_INF = -1e30
Q_PAD = 8  # TPU sublane minimum for fp32 tiles


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                   block_k: int, scale: float):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    position = pos_ref[0]

    @pl.when(ki * block_k <= position)
    def _compute():
        q = q_ref[...].astype(jnp.float32)                 # (Q_PAD, hd)
        k = k_ref[...].astype(jnp.float32)                 # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # (Q_PAD, bk)
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(cols <= position, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)
        acc[...] = acc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ki == pl.num_programs(1) - 1)
    def _finish():
        o_ref[...] = (acc[...] / l_s[...]).astype(o_ref.dtype)


def decode_attention(q, k, v, position, *, block_k: int = 512,
                     interpret: bool = True):
    """q: (BH, Q_PAD, hd) padded query; k, v: (BH, S_max, hd); position:
    scalar int32 — returns (BH, Q_PAD, hd) (row 0 is the real query)."""
    BH, QP, hd = q.shape
    S = k.shape[1]
    block_k = min(block_k, S)
    assert S % block_k == 0
    grid = (BH, S // block_k)
    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               scale=1.0 / (hd ** 0.5))
    pos = jnp.asarray(position, jnp.int32).reshape(1)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((None, QP, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, block_k, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, hd), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, QP, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, QP, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((QP, hd), jnp.float32),
            pltpu.VMEM((QP, 1), jnp.float32),
            pltpu.VMEM((QP, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(pos, q, k, v)
