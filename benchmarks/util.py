"""Benchmark harness utilities: timing + the `name,us_per_call,derived` CSV
contract shared by every benchmark module, plus the machine-readable row
store behind `benchmarks.run --json`."""
from __future__ import annotations

import time
from contextlib import contextmanager

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def reset():
    ROWS.clear()


def parse_derived(derived: str) -> dict:
    """'a=1.5;b=2x;c=foo' → {'a': 1.5, 'b': 2.0, 'c': 'foo'} (trailing 'x'
    of speedup values is stripped; unparseable values stay strings)."""
    out: dict = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            try:
                out[k] = float(v.rstrip("x"))
            except ValueError:
                out[k] = v
    return out


def rows_as_json() -> dict:
    """The run's rows in the schema consumed by benchmarks.check_regression
    (and committed as BENCH_baseline.json)."""
    return {
        "schema": 1,
        "rows": [
            {"name": n, "us_per_call": us, "derived": parse_derived(d)}
            for n, us, d in ROWS
        ],
    }


@contextmanager
def timed(name: str, derived_fn=lambda: ""):
    t0 = time.perf_counter()
    yield
    emit(name, (time.perf_counter() - t0) * 1e6, derived_fn())


def header():
    print("name,us_per_call,derived", flush=True)
