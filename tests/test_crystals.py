"""Paper §3 (Table 1) and §4 (Table 2) reproduction tests."""
import numpy as np
import pytest

from repro.core import (BCC, FCC, PC, RTT, FourD_BCC, FourD_FCC, LatticeGraph,
                        Lip, Torus, bcc_average_distance, bcc_diameter,
                        bcc_matrix, boxplus, crystal_for_order, direct_sum,
                        fcc_average_distance, fcc_diameter, fcc_matrix,
                        mixed_torus_diameter, pc_average_distance, pc_diameter,
                        pc_matrix, rtt_matrix, torus_average_distance,
                        torus_matrix, upgrade_path)
from repro.core import intmat


# ---------------------------------------------------------------------------
# orders (determinants)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("a", [1, 2, 3, 4, 5])
def test_crystal_orders(a):
    assert PC(a).order == a**3
    assert FCC(a).order == 2 * a**3
    assert BCC(a).order == 4 * a**3
    assert RTT(a).order == 2 * a**2
    assert FourD_FCC(a).order == 2 * a**4
    assert FourD_BCC(a).order == 8 * a**4
    assert Lip(a).order == 16 * a**4


def test_degree_regularity():
    g = BCC(3)
    nbr = g.neighbor_indices
    assert nbr.shape == (g.order, 6)


# ---------------------------------------------------------------------------
# Table 1: diameters and average distances
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("a", [2, 3, 4, 5, 6])
def test_table1_pc(a):
    g = PC(a)
    assert g.diameter == pc_diameter(a) == 3 * (a // 2)
    assert g.average_distance == pytest.approx(pc_average_distance(a), rel=1e-12)


@pytest.mark.parametrize("a", [2, 3, 4, 5, 6])
def test_table1_fcc(a):
    g = FCC(a)
    assert g.diameter == fcc_diameter(a) == (3 * a) // 2
    assert g.average_distance == pytest.approx(fcc_average_distance(a), rel=1e-12)


@pytest.mark.parametrize("a", [2, 3, 4, 5, 6])
def test_table1_bcc(a):
    g = BCC(a)
    assert g.diameter == bcc_diameter(a) == (3 * a) // 2
    assert g.average_distance == pytest.approx(bcc_average_distance(a), rel=1e-12)


@pytest.mark.parametrize("a", [2, 3, 4])
def test_table1_mixed_tori(a):
    t1 = Torus(2 * a, a, a)
    assert t1.order == 2 * a**3
    assert t1.diameter == mixed_torus_diameter(2 * a, a, a) == a + 2 * (a // 2)
    assert t1.average_distance == pytest.approx(
        torus_average_distance(2 * a, a, a), rel=1e-12)
    t2 = Torus(2 * a, 2 * a, a)
    assert t2.order == 4 * a**3
    assert t2.diameter == 2 * a + a // 2


def test_crystals_beat_equal_size_tori():
    """The crux of §3.4: crystals have strictly better k̄ and diameter than
    the same-size mixed-radix tori."""
    for a in (2, 4, 6):
        assert FCC(a).average_distance < Torus(2 * a, a, a).average_distance
        assert FCC(a).diameter <= Torus(2 * a, a, a).diameter
        assert BCC(a).average_distance < Torus(2 * a, 2 * a, a).average_distance
        assert BCC(a).diameter < Torus(2 * a, 2 * a, a).diameter


# ---------------------------------------------------------------------------
# projections (Lemmas 13, 14, 16; Propositions 17, 18)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("a", [2, 3, 4])
def test_projections(a):
    assert intmat.right_equivalent(PC(a).projection().matrix, torus_matrix(a, a))
    assert intmat.right_equivalent(FCC(a).projection().matrix, rtt_matrix(a))
    assert intmat.right_equivalent(BCC(a).projection().matrix, torus_matrix(2 * a, 2 * a))
    assert intmat.right_equivalent(FourD_FCC(a).projection().matrix, fcc_matrix(a))
    assert intmat.right_equivalent(FourD_BCC(a).projection().matrix,
                                   torus_matrix(2 * a, 2 * a, 2 * a))


def test_lip_projection_is_fcc_2a():
    """Proposition 19: Lip(a) is a lift of FCC(2a)."""
    a = 2
    assert intmat.right_equivalent(Lip(a).projection().matrix, fcc_matrix(2 * a))


def test_projection_node_count_identity():
    """|G(M)| = |G(B)| * side (paper §2)."""
    for g in (FCC(3), BCC(3), FourD_FCC(2), Lip(2)):
        assert g.order == g.projection().order * g.side


# ---------------------------------------------------------------------------
# Theorem 5: torus == diagonal lattice graph
# ---------------------------------------------------------------------------

def test_torus_is_lattice_graph():
    g = Torus(4, 3, 2)
    assert g.order == 24
    # distance from origin equals separable ring distance
    for v in g.labels:
        ring = sum(min(int(c) % s, s - int(c) % s) for c, s in zip(v, (4, 3, 2)))
        assert g.distance(np.zeros(3, dtype=np.int64), v) == ring


# ---------------------------------------------------------------------------
# Example 10: G([[4,0,0],[0,4,2],[0,0,4]])
# ---------------------------------------------------------------------------

def test_example_10():
    M = np.array([[4, 0, 0], [0, 4, 2], [0, 0, 4]])
    g = LatticeGraph(M)
    assert g.order == 64
    # projection is T(4, 4); e_3 generates a cycle of length 8
    assert intmat.right_equivalent(g.projection().matrix, torus_matrix(4, 4))
    assert g.order_of([0, 0, 1]) == 8
    # 8 / side = 2 vertices of the cycle per copy
    assert g.order_of([0, 0, 1]) // g.side == 2


# ---------------------------------------------------------------------------
# Theorem 24: boxplus common lifts (Example 25)
# ---------------------------------------------------------------------------

def test_example25_pc_bcc():
    out = boxplus(pc_matrix(2 * 2), bcc_matrix(2))
    a = 2
    expect = np.array([
        [2 * a, 0, 0, a],
        [0, 2 * a, 0, a],
        [0, 0, 2 * a, 0],
        [0, 0, 0, a]])
    assert np.array_equal(out, expect)


def test_example25_pc_fcc_is_5d():
    out = boxplus(pc_matrix(4), fcc_matrix(2))
    assert out.shape == (5, 5)
    assert abs(intmat.det(out)) == 8 * 2**5


def test_example25_bcc_fcc_is_5d():
    out = boxplus(bcc_matrix(2), fcc_matrix(2))
    assert out.shape == (5, 5)
    assert abs(intmat.det(out)) == 4 * 2**5


def test_boxplus_projections_recover_both():
    """Theorem 24 i): both operands appear as projections of the common lift."""
    M = boxplus(pc_matrix(4), bcc_matrix(2))
    g = LatticeGraph(M)
    # project away dim 4 -> PC(4); project away dim 3 (swap first) -> BCC-like
    assert intmat.right_equivalent(g.projection().matrix, pc_matrix(4))


def test_boxplus_no_common_columns_is_direct_sum():
    M1, M2 = torus_matrix(3, 3), torus_matrix(5, 5)
    assert np.array_equal(boxplus(M1, M2), direct_sum(M1, M2))


# ---------------------------------------------------------------------------
# §3.4 upgrade path
# ---------------------------------------------------------------------------

def test_upgrade_path_powers_of_two():
    path = upgrade_path(64, 6)  # 64,128,256,512,1024,2048,4096
    orders = [g.order for g in path]
    assert orders == [64, 128, 256, 512, 1024, 2048, 4096]
    kinds = [g.n for g in path]
    assert all(k == 3 for k in kinds)
    # 256-chip pod is BCC(4); 512 is PC(8); 1024 is FCC(8)
    assert np.array_equal(crystal_for_order(256).matrix, bcc_matrix(4))
    assert np.array_equal(crystal_for_order(512).matrix, pc_matrix(8))
    assert np.array_equal(crystal_for_order(1024).matrix, fcc_matrix(8))


def test_upgrade_path_diameter_monotone_vs_torus():
    """Doubling along the crystal path keeps diameter growth below the
    mixed-radix torus alternative."""
    for a in (2, 4):
        assert FCC(a).diameter <= Torus(2 * a, a, a).diameter
        assert BCC(a).diameter <= Torus(2 * a, 2 * a, a).diameter
