"""Language models over the assigned architecture families.

One parameter pytree layout shared by all decoder-only families
(dense / moe / ssm / hybrid / vlm) plus an encoder-decoder variant (audio).
Layers are stacked along a leading axis and executed with `lax.scan` so HLO
size is O(1) in depth (hybrid models unroll: their shared attention block
makes layers heterogeneous, see DESIGN.md).

Entry points: `init_params`, `forward` (train), `prefill`, `decode_step`,
`init_cache`.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from . import attention as attn_mod
from . import mlp as mlp_mod
from . import ssm as ssm_mod
from .attention import (AttnParams, attend_cross, attend_decode,
                        attend_prefill, attend_train, cross_kv, init_attn)
from .common import (cast_compute, dense_init, embed_init, make_norm,
                     norm_param, sinusoidal_positions)
from .mlp import init_mlp, init_moe, mlp, moe
from .ssm import (MambaCache, init_mamba, init_mamba_cache, mamba_decode,
                  mamba_train)

CACHE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_dense_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    layer = {
        "attn": init_attn(k1, cfg),
        "norm1": norm_param(cfg, cfg.d_model),
        "norm2": norm_param(cfg, cfg.d_model),
    }
    layer["ffn"] = init_moe(k2, cfg) if cfg.moe is not None else \
        init_mlp(k2, cfg.d_model, cfg.d_ff)
    return layer


def _init_mamba_layer(key, cfg):
    return {
        "mamba": init_mamba(key, cfg),
        "norm1": norm_param(cfg, cfg.d_model),
    }


def _init_encdec_layer(key, cfg, cross: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    layer = {
        "attn": init_attn(k1, cfg),
        "ffn": init_mlp(k2, cfg.d_model, cfg.d_ff),
        "norm1": norm_param(cfg, cfg.d_model),
        "norm2": norm_param(cfg, cfg.d_model),
    }
    if cross:
        layer["cross"] = init_attn(k3, cfg)
        layer["norm3"] = norm_param(cfg, cfg.d_model)
    return layer


def init_params(cfg, key) -> dict[str, Any]:
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": norm_param(cfg, cfg.d_model),
    }
    lkeys = jax.random.split(keys[1], cfg.num_layers)
    if cfg.is_encdec:
        ekeys = jax.random.split(keys[2], cfg.encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_encdec_layer(k, cfg, cross=False))(ekeys),
            "final_norm": norm_param(cfg, cfg.d_model),
        }
        params["layers"] = jax.vmap(
            lambda k: _init_encdec_layer(k, cfg, cross=True))(lkeys)
    elif cfg.family in ("dense", "moe", "vlm"):
        params["layers"] = jax.vmap(lambda k: _init_dense_layer(k, cfg))(lkeys)
    elif cfg.family in ("ssm", "hybrid"):
        params["layers"] = jax.vmap(lambda k: _init_mamba_layer(k, cfg))(lkeys)
    else:
        raise ValueError(cfg.family)
    if cfg.family == "hybrid":
        k1, k2 = jax.random.split(keys[3])
        params["shared_attn"] = {
            "attn": init_attn(k1, cfg),
            "ffn": init_mlp(k2, cfg.d_model, cfg.d_ff),
            "norm1": norm_param(cfg, cfg.d_model),
            "norm2": norm_param(cfg, cfg.d_model),
        }
    if cfg.family == "vlm":
        params["vision_proj"] = dense_init(keys[4], cfg.d_model, cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[5], cfg.d_model, cfg.vocab_size, scale=0.02)
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def hybrid_attn_layers(cfg) -> list[int]:
    """Layer indices after which the shared attention block runs."""
    p = cfg.hybrid_attn_period
    return [i for i in range(cfg.num_layers) if i % p == p - 1]


def _hybrid_groups(cfg):
    p = cfg.hybrid_attn_period
    G = cfg.num_layers // p
    return G, p, cfg.num_layers - G * p


def _split_hybrid_params(layers, G: int, p: int):
    grouped = jax.tree.map(
        lambda a: a[: G * p].reshape(G, p, *a.shape[1:]), layers)
    tail = jax.tree.map(lambda a: a[G * p:], layers)
    return grouped, tail


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _bf16_grad_identity(x):
    return x


_bf16_grad_identity.defvjp(
    lambda x: (x, None),
    lambda _, g: (g.astype(jnp.bfloat16),))


def _block_out(x):
    """Pin the residual stream at the block boundary.  With the
    `_bf16_barrier` rule on, the backward cotangent is cast to bf16 here
    (Megatron-style bf16 activation grads): the cross-entropy's fp32
    cotangent otherwise propagates fp32 through every residual hop, so the
    TP partial-sum all-reduces move 2× the bytes (measured: 1443 GB/step/
    device on command-r-plus — §Perf)."""
    from repro.parallel.sharding import current_rules
    rules = current_rules()
    if rules is not None and rules.get("_bf16_barrier"):
        return _bf16_grad_identity(constrain(x, "hidden"))
    return constrain(x, "hidden")


def _dense_block(layer, cfg, x, positions, impl):
    norm = make_norm(cfg)
    h = norm(x, layer["norm1"])
    h = constrain(h, "hidden")
    a = attend_train(layer["attn"], cfg, h, positions, impl=impl)
    if cfg.parallel_block:
        # Cohere-style: attention and FFN read the same normed input
        if cfg.moe is not None:
            f, aux = moe(layer["ffn"], cfg, h)
        else:
            f, aux = mlp(layer["ffn"], h), 0.0
        return _block_out(x + a + f), aux
    x = x + a
    h2 = norm(x, layer["norm2"])
    if cfg.moe is not None:
        f, aux = moe(layer["ffn"], cfg, h2)
    else:
        f, aux = mlp(layer["ffn"], h2), 0.0
    return _block_out(x + f), aux


def _mamba_block(layer, cfg, x, impl):
    norm = make_norm(cfg)
    h = norm(x, layer["norm1"])
    return constrain(x + mamba_train(layer["mamba"], cfg, h, impl=impl), "hidden")


def _shared_attn_block(shared, cfg, x, positions, impl):
    norm = make_norm(cfg)
    h = norm(x, shared["norm1"])
    x = x + attend_train(shared["attn"], cfg, h, positions, impl=impl)
    h = norm(x, shared["norm2"])
    return constrain(x + mlp(shared["ffn"], h), "hidden")


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(remat)


# ---------------------------------------------------------------------------
# forward (train / eval over a full sequence)
# ---------------------------------------------------------------------------

def forward(params, cfg, tokens, *, patch_embeds=None, enc_frames=None,
            impl: str = "xla", remat: str = "none", unroll: int = 1):
    """tokens: (B, S) int32 → (logits (B, S, V), aux_loss scalar)."""
    B, S = tokens.shape
    tokens = constrain(tokens, "tokens")
    x = cast_compute(params["embed"])[tokens]
    x = constrain(x, "hidden")
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    if cfg.family == "vlm":
        pe = cast_compute(patch_embeds) @ cast_compute(params["vision_proj"])
        x = jax.lax.dynamic_update_slice(x, pe.astype(x.dtype), (0, 0, 0))

    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params["encoder"], cfg, enc_frames, impl, remat, unroll)
        x = x + cast_compute(sinusoidal_positions(S, cfg.d_model))[None]

    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe", "vlm") and not cfg.is_encdec:
        def body(carry, layer):
            h, aux = _dense_block(layer, cfg, carry, positions, impl)
            return h, aux
        body = _maybe_remat(body, remat)
        x, auxes = jax.lax.scan(body, x, params["layers"], unroll=unroll)
        aux_total = aux_total + jnp.sum(auxes)

    elif cfg.is_encdec:
        def body(carry, layer):
            h = _encdec_decoder_block(layer, cfg, carry, positions, enc_out, impl)
            return h, 0.0
        body = _maybe_remat(body, remat)
        x, _ = jax.lax.scan(body, x, params["layers"], unroll=unroll)

    elif cfg.family == "ssm":
        def body(carry, layer):
            return _mamba_block(layer, cfg, carry, impl), 0.0
        body = _maybe_remat(body, remat)
        x, _ = jax.lax.scan(body, x, params["layers"], unroll=unroll)

    elif cfg.family == "hybrid":
        if unroll == 1:
            # grouped scan: [period × mamba + shared attn] per group; the
            # shared block's weights are scan-invariant (the Zamba trick)
            G, pperiod, tail = _hybrid_groups(cfg)
            grouped, tail_layers = _split_hybrid_params(params["layers"], G, pperiod)

            def group_body(carry, grp):
                h = carry

                def inner(c, lay):
                    return _mamba_block(lay, cfg, c, impl), None

                h, _ = jax.lax.scan(inner, h, grp)
                h = _shared_attn_block(params["shared_attn"], cfg, h,
                                       positions, impl)
                return h, None

            body = _maybe_remat(group_body, remat)
            x, _ = jax.lax.scan(body, x, grouped)
            for i in range(tail):
                layer = jax.tree.map(lambda a: a[i], tail_layers)
                x = _mamba_block(layer, cfg, x, impl)
        else:
            attn_after = set(hybrid_attn_layers(cfg))
            for i in range(cfg.num_layers):
                layer = jax.tree.map(lambda a: a[i], params["layers"])
                blk = _maybe_remat(
                    lambda h, l=layer: _mamba_block(l, cfg, h, impl), remat)
                x = blk(x)
                if i in attn_after:
                    sab = _maybe_remat(
                        lambda h: _shared_attn_block(
                            params["shared_attn"], cfg, h, positions, impl),
                        remat)
                    x = sab(x)
    else:
        raise ValueError(cfg.family)

    norm = make_norm(cfg)
    x = norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ cast_compute(head)
    return constrain(logits, "logits"), aux_total


def _encode(enc_params, cfg, frames, impl, remat, unroll: int = 1):
    """Whisper-style encoder over precomputed (stub) frame embeddings."""
    x = cast_compute(frames)
    B, S, D = x.shape
    x = x + cast_compute(sinusoidal_positions(S, cfg.d_model))[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    norm = make_norm(cfg)

    def body(carry, layer):
        h = norm(carry, layer["norm1"])
        h = attend_train(layer["attn"], cfg, h, positions, causal=False,
                         impl=impl, rope=False)
        x2 = carry + h
        h2 = norm(x2, layer["norm2"])
        return x2 + mlp(layer["ffn"], h2), 0.0

    body = _maybe_remat(body, remat)
    x, _ = jax.lax.scan(body, x, enc_params["layers"], unroll=unroll)
    return norm(x, enc_params["final_norm"])


def _encdec_decoder_block(layer, cfg, x, positions, enc_out, impl):
    norm = make_norm(cfg)
    h = norm(x, layer["norm1"])
    x = x + attend_train(layer["attn"], cfg, h, positions, impl=impl, rope=False)
    h = norm(x, layer["norm3"])
    kv = cross_kv(layer["cross"], cfg, enc_out)
    x = x + attend_cross(layer["cross"], cfg, h, kv, impl=impl)
    h = norm(x, layer["norm2"])
    return constrain(x + mlp(layer["ffn"], h), "hidden")


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int) -> dict[str, Any]:
    hd = cfg.resolved_head_dim
    kv_shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
    if cfg.is_encdec:
        cross_shape = (cfg.num_layers, batch, cfg.encoder_seq_len,
                       cfg.num_kv_heads, hd)
        return {
            "k": jnp.zeros(kv_shape, CACHE_DTYPE),
            "v": jnp.zeros(kv_shape, CACHE_DTYPE),
            "cross_k": jnp.zeros(cross_shape, CACHE_DTYPE),
            "cross_v": jnp.zeros(cross_shape, CACHE_DTYPE),
        }
    if cfg.family in ("dense", "moe", "vlm"):
        return {"k": jnp.zeros(kv_shape, CACHE_DTYPE),
                "v": jnp.zeros(kv_shape, CACHE_DTYPE)}
    if cfg.family == "ssm":
        single = init_mamba_cache(cfg, batch, CACHE_DTYPE)
        return {"mamba": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(),
            single)}
    if cfg.family == "hybrid":
        single = init_mamba_cache(cfg, batch, CACHE_DTYPE)
        n_inv = len(hybrid_attn_layers(cfg))
        akv = (n_inv, batch, max_len, cfg.num_kv_heads, hd)
        return {
            "mamba": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(),
                single),
            "k": jnp.zeros(akv, CACHE_DTYPE),
            "v": jnp.zeros(akv, CACHE_DTYPE),
        }
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill(params, cfg, tokens, max_len: int, *, patch_embeds=None,
            enc_frames=None, impl="xla", remat: str = "none", unroll: int = 1):
    """Run the model over a prompt, returning (last-position logits, cache).

    The cache is allocated at max_len and filled in [0, S)."""
    B, S = tokens.shape
    x = cast_compute(params["embed"])[tokens]
    x = constrain(x, "hidden")
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    cache = init_cache(cfg, B, max_len)

    if cfg.family == "vlm" and patch_embeds is not None:
        pe = cast_compute(patch_embeds) @ cast_compute(params["vision_proj"])
        x = jax.lax.dynamic_update_slice(x, pe.astype(x.dtype), (0, 0, 0))

    def pad_kv(kv):
        k, v = kv
        pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
        return (jnp.pad(k.astype(CACHE_DTYPE), pad),
                jnp.pad(v.astype(CACHE_DTYPE), pad))

    if cfg.is_encdec:
        enc_out = _encode(params["encoder"], cfg, enc_frames, impl, remat, unroll)
        x = x + cast_compute(sinusoidal_positions(S, cfg.d_model))[None]

        def body(carry, layer):
            h = carry
            norm = make_norm(cfg)
            hn = norm(h, layer["norm1"])
            a, kv = attend_prefill(layer["attn"], cfg, hn, positions,
                                   impl=impl, rope=False)
            h = h + a
            hn = norm(h, layer["norm3"])
            ckv = cross_kv(layer["cross"], cfg, enc_out)
            h = h + attend_cross(layer["cross"], cfg, hn, ckv, impl=impl)
            hn = norm(h, layer["norm2"])
            h = h + mlp(layer["ffn"], hn)
            k, v = pad_kv(kv)
            return h, (k, v, ckv[0].astype(CACHE_DTYPE), ckv[1].astype(CACHE_DTYPE))

        body = _maybe_remat(body, remat)
        x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["layers"],
                                             unroll=unroll)
        cache = {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs}

    elif cfg.family in ("dense", "moe", "vlm"):
        def body(carry, layer):
            h = carry
            norm = make_norm(cfg)
            hn = norm(h, layer["norm1"])
            hn = constrain(hn, "hidden")
            a, kv = attend_prefill(layer["attn"], cfg, hn, positions, impl=impl)
            if cfg.parallel_block:
                f = moe(layer["ffn"], cfg, hn)[0] if cfg.moe is not None \
                    else mlp(layer["ffn"], hn)
                k, v = pad_kv(kv)
                return constrain(h + a + f, "hidden"), (k, v)
            h = h + a
            hn = norm(h, layer["norm2"])
            if cfg.moe is not None:
                f, _ = moe(layer["ffn"], cfg, hn)
            else:
                f = mlp(layer["ffn"], hn)
            k, v = pad_kv(kv)
            return constrain(h + f, "hidden"), (k, v)

        body = _maybe_remat(body, remat)
        x, (ks, vs) = jax.lax.scan(body, x, params["layers"], unroll=unroll)
        cache = {"k": ks, "v": vs}

    elif cfg.family == "ssm":
        def body(carry, layer):
            norm = make_norm(cfg)
            hn = norm(carry, layer["norm1"])
            sc = cfg.ssm
            y, state = _mamba_prefill(layer["mamba"], cfg, hn, impl)
            return constrain(carry + y, "hidden"), state

        body = _maybe_remat(body, remat)
        x, states = jax.lax.scan(body, x, params["layers"], unroll=unroll)
        cache = {"mamba": states}

    elif cfg.family == "hybrid" and unroll == 1:
        G, pperiod, tail = _hybrid_groups(cfg)
        grouped, tail_layers = _split_hybrid_params(params["layers"], G, pperiod)

        def group_body(carry, grp):
            h = carry
            norm = make_norm(cfg)

            def inner(c, lay):
                hn = norm(c, lay["norm1"])
                y, state = _mamba_prefill(lay["mamba"], cfg, hn, impl)
                return constrain(c + y, "hidden"), state

            h, states = jax.lax.scan(inner, h, grp)
            shared = params["shared_attn"]
            hn = norm(h, shared["norm1"])
            a, kv = attend_prefill(shared["attn"], cfg, hn, positions, impl=impl)
            h = h + a
            hn = norm(h, shared["norm2"])
            h = constrain(h + mlp(shared["ffn"], hn), "hidden")
            k, v = pad_kv(kv)
            return h, (states, k, v)

        x, (g_states, ks, vs) = jax.lax.scan(group_body, x, grouped)
        m_states = jax.tree.map(
            lambda a: a.reshape(G * pperiod, *a.shape[2:]), g_states)
        tail_states = []
        norm = make_norm(cfg)
        for i in range(tail):
            layer = jax.tree.map(lambda a: a[i], tail_layers)
            hn = norm(x, layer["norm1"])
            y, state = _mamba_prefill(layer["mamba"], cfg, hn, impl)
            x = constrain(x + y, "hidden")
            tail_states.append(state)
        if tail_states:
            tail_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *tail_states)
            m_states = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b]), m_states, tail_stack)
        cache = {"mamba": m_states, "k": ks, "v": vs}

    elif cfg.family == "hybrid":
        attn_after = set(hybrid_attn_layers(cfg))
        m_states, akv = [], []
        for i in range(cfg.num_layers):
            layer = jax.tree.map(lambda a: a[i], params["layers"])
            norm = make_norm(cfg)
            hn = norm(x, layer["norm1"])
            y, state = _mamba_prefill(layer["mamba"], cfg, hn, impl)
            x = constrain(x + y, "hidden")
            m_states.append(state)
            if i in attn_after:
                shared = params["shared_attn"]
                hn = norm(x, shared["norm1"])
                a, kv = attend_prefill(shared["attn"], cfg, hn, positions, impl=impl)
                x = x + a
                hn = norm(x, shared["norm2"])
                x = constrain(x + mlp(shared["ffn"], hn), "hidden")
                akv.append(pad_kv(kv))
        cache = {
            "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *m_states),
            "k": jnp.stack([k for k, _ in akv]),
            "v": jnp.stack([v for _, v in akv]),
        }
    else:
        raise ValueError(cfg.family)

    norm = make_norm(cfg)
    x_last = norm(x[:, -1:, :], params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x_last @ cast_compute(head)
    return constrain(logits, "logits"), cache


def _mamba_prefill(p, cfg, u, impl):
    """Like mamba_train but also returns the final cache (conv tail + state)."""
    sc = cfg.ssm
    d_inner, H, conv_ch = ssm_mod.dims(cfg)
    proj = u @ cast_compute(p.in_proj)
    z, xBC, dt = ssm_mod._split_proj(cfg, proj)
    conv_tail = xBC[:, -(sc.conv_kernel - 1):, :].astype(CACHE_DTYPE)
    xBC = ssm_mod._causal_conv(xBC, p.conv_w, p.conv_b)
    gn = sc.ngroups * sc.state_size
    x, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + gn], axis=-1)
    B_, S_ = u.shape[0], u.shape[1]
    x = x.reshape(B_, S_, H, sc.head_dim)
    Bm = Bm.reshape(B_, S_, sc.ngroups, sc.state_size)
    Cm = Cm.reshape(B_, S_, sc.ngroups, sc.state_size)
    dt_ = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)
    A = -jnp.exp(p.A_log)
    xdt = x * dt_[..., None].astype(x.dtype)
    Adt = dt_ * A
    if impl == "pallas":
        from repro.kernels import ops as kops
        y, final = kops.ssd(xdt, Adt, Bm, Cm, chunk=sc.chunk_size)
    else:
        y, final = ssm_mod.ssd_chunked(xdt, Adt, Bm, Cm, chunk=sc.chunk_size)
    y = y + x * cast_compute(p.D_skip)[None, None, :, None]
    y = y.reshape(B_, S_, d_inner) * jax.nn.silu(z)
    y = ssm_mod.rms_norm(y, p.out_norm, cfg.norm_eps)
    out = y @ cast_compute(p.out_proj)
    return out, MambaCache(conv_tail, final.astype(CACHE_DTYPE))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_step(params, cfg, token, cache, position, *, impl="xla",
                unroll: int = 1):
    """token: (B, 1) int32; position: scalar int32 — index of the new token.
    Returns (logits (B, 1, V), updated cache)."""
    B = token.shape[0]
    x = cast_compute(params["embed"])[token]
    norm = make_norm(cfg)

    if cfg.is_encdec:
        from .common import sinusoidal_at
        x = x + cast_compute(sinusoidal_at(position, cfg.d_model))[None, None]

        def body(carry, scanned):
            layer, k, v, ck, cv = scanned
            h = carry
            hn = norm(h, layer["norm1"])
            a, (k2, v2) = attend_decode(layer["attn"], cfg, hn, (k, v),
                                        position, impl=impl, rope=False)
            h = h + a
            hn = norm(h, layer["norm3"])
            h = h + attend_cross(layer["cross"], cfg, hn, (ck, cv), impl=impl)
            hn = norm(h, layer["norm2"])
            h = h + mlp(layer["ffn"], hn)
            return h, (k2, v2)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]), unroll=unroll)
        cache = dict(cache, k=ks, v=vs)

    elif cfg.family in ("dense", "moe", "vlm"):
        def body(carry, scanned):
            layer, k, v = scanned
            h = carry
            hn = norm(h, layer["norm1"])
            a, (k2, v2) = attend_decode(layer["attn"], cfg, hn, (k, v),
                                        position, impl=impl)
            if cfg.parallel_block:
                f = moe(layer["ffn"], cfg, hn)[0] if cfg.moe is not None \
                    else mlp(layer["ffn"], hn)
                return h + a + f, (k2, v2)
            h = h + a
            hn = norm(h, layer["norm2"])
            if cfg.moe is not None:
                f, _ = moe(layer["ffn"], cfg, hn)
            else:
                f = mlp(layer["ffn"], hn)
            return h + f, (k2, v2)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]),
            unroll=unroll)
        cache = {"k": ks, "v": vs}

    elif cfg.family == "ssm":
        def body(carry, scanned):
            layer, mc = scanned
            hn = norm(carry, layer["norm1"])
            y, mc2 = mamba_decode(layer["mamba"], cfg, hn, mc)
            return carry + y, mc2

        x, states = jax.lax.scan(body, x, (params["layers"], cache["mamba"]),
                                 unroll=unroll)
        cache = {"mamba": states}

    elif cfg.family == "hybrid" and unroll == 1:
        G, pperiod, tail = _hybrid_groups(cfg)
        grouped, tail_layers = _split_hybrid_params(params["layers"], G, pperiod)
        g_mcache, tail_mcache = _split_hybrid_params(cache["mamba"], G, pperiod)

        def group_body(carry, scanned):
            h = carry
            grp, mc, k, v = scanned

            def inner(c, lay_mc):
                lay, m = lay_mc
                hn = norm(c, lay["norm1"])
                y, m2 = mamba_decode(lay["mamba"], cfg, hn, m)
                return c + y, m2

            h, mc2 = jax.lax.scan(inner, h, (grp, mc))
            shared = params["shared_attn"]
            hn = norm(h, shared["norm1"])
            a, (k2, v2) = attend_decode(shared["attn"], cfg, hn, (k, v),
                                        position, impl=impl)
            h = h + a
            hn = norm(h, shared["norm2"])
            h = h + mlp(shared["ffn"], hn)
            return h, (mc2, k2, v2)

        x, (g_mc2, ks, vs) = jax.lax.scan(
            group_body, x, (grouped, g_mcache, cache["k"], cache["v"]))
        m_states = jax.tree.map(
            lambda a: a.reshape(G * pperiod, *a.shape[2:]), g_mc2)
        tail_states = []
        for i in range(tail):
            layer = jax.tree.map(lambda a: a[i], tail_layers)
            mc = jax.tree.map(lambda a: a[i], tail_mcache)
            hn = norm(x, layer["norm1"])
            y, mc2 = mamba_decode(layer["mamba"], cfg, hn, mc)
            x = x + y
            tail_states.append(mc2)
        if tail_states:
            tail_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *tail_states)
            m_states = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b]), m_states, tail_stack)
        cache = {"mamba": m_states, "k": ks, "v": vs}

    elif cfg.family == "hybrid":
        attn_after = set(hybrid_attn_layers(cfg))
        new_states, new_k, new_v = [], [], []
        inv = 0
        for i in range(cfg.num_layers):
            layer = jax.tree.map(lambda a: a[i], params["layers"])
            mc = jax.tree.map(lambda a: a[i], cache["mamba"])
            hn = norm(x, layer["norm1"])
            y, mc2 = mamba_decode(layer["mamba"], cfg, hn, mc)
            x = x + y
            new_states.append(mc2)
            if i in attn_after:
                shared = params["shared_attn"]
                hn = norm(x, shared["norm1"])
                a, (k2, v2) = attend_decode(
                    shared["attn"], cfg, hn,
                    (cache["k"][inv], cache["v"][inv]), position, impl=impl)
                x = x + a
                hn = norm(x, shared["norm2"])
                x = x + mlp(shared["ffn"], hn)
                new_k.append(k2)
                new_v.append(v2)
                inv += 1
        cache = {
            "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_states),
            "k": jnp.stack(new_k),
            "v": jnp.stack(new_v),
        }
    else:
        raise ValueError(cfg.family)

    x = norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ cast_compute(head)
    return constrain(logits, "logits"), cache
