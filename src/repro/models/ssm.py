"""Mamba2 (SSD — state-space duality) blocks: chunked training path, single
step decode path, and caches.

Shapes: d_inner = expand·d_model, H = d_inner / head_dim heads, state N,
groups G (B/C shared across heads within a group).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import cast_compute, dense_init, rms_norm


class MambaParams(NamedTuple):
    in_proj: jax.Array     # (D, 2*d_inner + 2*G*N + H)
    conv_w: jax.Array      # (k, d_conv_ch)  depthwise causal conv
    conv_b: jax.Array      # (d_conv_ch,)
    A_log: jax.Array       # (H,)
    D_skip: jax.Array      # (H,)
    dt_bias: jax.Array     # (H,)
    out_norm: jax.Array    # (d_inner,)
    out_proj: jax.Array    # (d_inner, D)


def dims(cfg):
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    H = d_inner // sc.head_dim
    conv_ch = d_inner + 2 * sc.ngroups * sc.state_size
    return d_inner, H, conv_ch


def init_mamba(key, cfg) -> MambaParams:
    sc = cfg.ssm
    d_inner, H, conv_ch = dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in_all = 2 * d_inner + 2 * sc.ngroups * sc.state_size + H
    return MambaParams(
        in_proj=dense_init(k1, cfg.d_model, d_in_all),
        conv_w=jax.random.normal(k2, (sc.conv_kernel, conv_ch), jnp.float32) * 0.1,
        conv_b=jnp.zeros((conv_ch,), jnp.float32),
        A_log=jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        D_skip=jnp.ones((H,), jnp.float32),
        dt_bias=jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))),
        out_norm=jnp.ones((d_inner,), jnp.float32),
        out_proj=dense_init(k4, d_inner, cfg.d_model))


def _split_proj(cfg, proj):
    sc = cfg.ssm
    d_inner, H, _ = dims(cfg)
    gn = sc.ngroups * sc.state_size
    z, xBC, dt = jnp.split(proj, [d_inner, d_inner + d_inner + 2 * gn], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, conv_w, conv_b):
    """Depthwise causal conv along time.  xBC: (B, S, C)."""
    k = conv_w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xBC.shape[1], :] * cast_compute(conv_w[i])[None, None]
              for i in range(k))
    return jax.nn.silu(out + cast_compute(conv_b))


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{k in (j, i]} x[..., k],
    lower-triangular, -inf above the diagonal.  x: (..., Q)."""
    Q = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xdt, Adt, Bm, Cm, chunk: int):
    """Chunked SSD (Mamba2 paper, discrete form).

    xdt: (B, S, H, P) inputs pre-multiplied by dt
    Adt: (B, S, H)    log-decay per step (dt · A, negative)
    Bm, Cm: (B, S, G, N)
    Returns y: (B, S, H, P) and final state (B, H, P, N)."""
    B, S, H, P = xdt.shape
    G, N = Bm.shape[2], Bm.shape[3]
    if S % chunk:
        # pad the tail with identity steps (x=0, decay=1): outputs beyond S
        # are discarded and the final state is unaffected
        pad = chunk - S % chunk
        padt = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        y, final = ssd_chunked(padt(xdt), padt(Adt), padt(Bm), padt(Cm), chunk)
        return y[:, :S], final
    nc = S // chunk
    rep = H // G
    x_ = xdt.reshape(B, nc, chunk, H, P)
    A_ = Adt.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2)  # (B, H, nc, Q)
    B_ = Bm.reshape(B, nc, chunk, G, N)
    C_ = Cm.reshape(B, nc, chunk, G, N)

    A_cum = jnp.cumsum(A_, axis=-1)                          # (B, H, nc, Q)
    L = jnp.exp(_segsum(A_))                                 # (B, H, nc, Q, Q)

    # intra-chunk (quadratic) term
    Bh = jnp.repeat(B_, rep, axis=3)                         # (B, nc, Q, H, N)
    Ch = jnp.repeat(C_, rep, axis=3)
    scores = jnp.einsum("bcqhn,bckhn->bhcqk", Ch, Bh).astype(jnp.float32)
    y_diag = jnp.einsum("bhcqk,bckhp->bcqhp",
                        (scores * L).astype(xdt.dtype), x_)

    # per-chunk final states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)          # (B, H, nc, Q)
    states = jnp.einsum("bckhn,bhck,bckhp->bchpn",
                        Bh, decay_states.astype(xdt.dtype), x_)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(A_cum[..., -1])                    # (B, H, nc)

    def step(carry, inp):
        st, dec = inp                                        # (B,H,P,N), (B,H)
        new = carry * dec[..., None, None].astype(carry.dtype) + st
        return new, carry                                    # emit state *before* chunk

    init = jnp.zeros((B, H, P, N), xdt.dtype)
    final, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (B, nc, H, P, N)

    # inter-chunk contribution
    state_decay = jnp.exp(A_cum)                             # (B, H, nc, Q)
    y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp",
                       Ch, prev_states, state_decay.astype(xdt.dtype))
    y = (y_diag + y_off).reshape(B, S, H, P)
    return y, final


def ssd_reference(xdt, Adt, Bm, Cm):
    """Naive sequential recurrence (oracle for tests)."""
    B, S, H, P = xdt.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)

    def step(state, t):
        x_t, a_t, b_t, c_t = t
        state = state * jnp.exp(a_t)[..., None, None] + \
            x_t[..., :, None] * b_t[..., None, :]
        y_t = jnp.einsum("bhpn,bhn->bhp", state, c_t)
        return state, y_t

    init = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (xdt.astype(jnp.float32).transpose(1, 0, 2, 3),
          Adt.astype(jnp.float32).transpose(1, 0, 2),
          Bh.astype(jnp.float32).transpose(1, 0, 2, 3),
          Ch.astype(jnp.float32).transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(xdt.dtype), final.astype(xdt.dtype)


def mamba_train(p: MambaParams, cfg, u, impl="xla"):
    """Full-sequence Mamba2 block.  u: (B, S, D) → (B, S, D)."""
    sc = cfg.ssm
    d_inner, H, _ = dims(cfg)
    proj = u @ cast_compute(p.in_proj)
    z, xBC, dt = _split_proj(cfg, proj)
    xBC = _causal_conv(xBC, p.conv_w, p.conv_b)
    gn = sc.ngroups * sc.state_size
    x, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + gn], axis=-1)
    B_, S_ = u.shape[0], u.shape[1]
    x = x.reshape(B_, S_, H, sc.head_dim)
    Bm = Bm.reshape(B_, S_, sc.ngroups, sc.state_size)
    Cm = Cm.reshape(B_, S_, sc.ngroups, sc.state_size)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)          # (B,S,H)
    A = -jnp.exp(p.A_log)                                             # (H,)
    xdt = x * dt[..., None].astype(x.dtype)
    Adt = dt * A
    if impl == "pallas":
        from repro.kernels import ops as kops
        y, _ = kops.ssd(xdt, Adt, Bm, Cm, chunk=sc.chunk_size)
    else:
        y, _ = ssd_chunked(xdt, Adt, Bm, Cm, chunk=sc.chunk_size)
    y = y + x * cast_compute(p.D_skip)[None, None, :, None]
    y = y.reshape(B_, S_, d_inner) * jax.nn.silu(z)
    y = rms_norm(y, p.out_norm, cfg.norm_eps)
    return y @ cast_compute(p.out_proj)


class MambaCache(NamedTuple):
    conv: jax.Array    # (B, k-1, conv_ch) rolling conv inputs
    state: jax.Array   # (B, H, P, N) SSM state


def init_mamba_cache(cfg, batch: int, dtype=jnp.bfloat16) -> MambaCache:
    sc = cfg.ssm
    d_inner, H, conv_ch = dims(cfg)
    return MambaCache(
        conv=jnp.zeros((batch, sc.conv_kernel - 1, conv_ch), dtype),
        state=jnp.zeros((batch, H, sc.head_dim, sc.state_size), dtype))


def mamba_decode(p: MambaParams, cfg, u, cache: MambaCache):
    """One-token step.  u: (B, 1, D) → ((B, 1, D), cache)."""
    sc = cfg.ssm
    d_inner, H, conv_ch = dims(cfg)
    proj = u @ cast_compute(p.in_proj)
    z, xBC, dt = _split_proj(cfg, proj)                      # (B,1,·)
    # rolling conv window
    window = jnp.concatenate([cache.conv, xBC], axis=1)      # (B, k, C)
    conv_out = (window * cast_compute(p.conv_w)[None]).sum(axis=1, keepdims=True)
    xBC = jax.nn.silu(conv_out + cast_compute(p.conv_b))
    new_conv = window[:, 1:, :]
    gn = sc.ngroups * sc.state_size
    x, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + gn], axis=-1)
    B_ = u.shape[0]
    x = x.reshape(B_, H, sc.head_dim)
    Bm = jnp.repeat(Bm.reshape(B_, sc.ngroups, sc.state_size), H // sc.ngroups, axis=1)
    Cm = jnp.repeat(Cm.reshape(B_, sc.ngroups, sc.state_size), H // sc.ngroups, axis=1)
    dt_ = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p.dt_bias)   # (B,H)
    A = -jnp.exp(p.A_log)
    decay = jnp.exp(dt_ * A).astype(x.dtype)                          # (B,H)
    state = cache.state * decay[..., None, None] + \
        (x * dt_.astype(x.dtype)[..., None])[..., :, None] * Bm[..., None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, Cm)
    y = y + x * cast_compute(p.D_skip)[None, :, None]
    y = y.reshape(B_, 1, d_inner) * jax.nn.silu(z)
    y = rms_norm(y, p.out_norm, cfg.norm_eps)
    return y @ cast_compute(p.out_proj), MambaCache(new_conv, state)
