import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell against ShapeDtypeStruct stand-ins — no allocation — and record
memory_analysis / cost_analysis / collective traffic for the roofline.

Must be run as its own process (device count is locked at first jax init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import REGISTRY, get_config, get_shape, shapes_for
from repro.launch import specs as S
from repro.launch.hlo_analysis import collective_stats, top_collectives
from repro.launch.mesh import make_production_mesh
from repro.parallel import sharding as shard
from repro.runtime.steps import make_decode_step, make_prefill_step, make_train_step

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"


def _ns(mesh, tree):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape_name: str, mesh, *, fsdp: bool = True,
               remat: str = "full", impl: str = "chunked", microbatch: int = 0,
               seq_shard: bool = False, unroll: int = 0,
               bf16_barrier: bool = False):
    """Returns (step_fn, abstract_args, in_shardings, out_shardings, donate)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    pspecs = shard.make_param_specs(cfg, mesh, fsdp=fsdp)
    params = S.abstract_params(cfg)
    ax_rules = shard.make_activation_rules(
        cfg, mesh, shape.kind, shape.global_batch, fsdp=fsdp,
        seq_shard=seq_shard)
    if bf16_barrier:
        ax_rules["_bf16_barrier"] = True
    b = shard.Axes(cfg, mesh, fsdp).batch_dim(shape.global_batch)
    vocab_sh = shard.Axes(cfg, mesh, fsdp).tp_dim(cfg.vocab_size)

    n_unroll = unroll if unroll > 0 else cfg.num_layers
    if shape.kind == "train":
        from repro.optim.adamw import AdamWState
        step = make_train_step(cfg, remat=remat, impl=impl,
                               microbatch=microbatch, unroll=n_unroll)
        opt_specs = AdamWState(step=P(), m=pspecs, v=pspecs)
        batch_specs = shard.make_input_specs_tree(cfg, mesh, shape, fsdp=fsdp)
        args = (params, S.abstract_opt_state(cfg), S.input_specs(cfg, shape))
        in_sh = (_ns(mesh, pspecs), _ns(mesh, opt_specs), _ns(mesh, batch_specs))
        out_sh = (_ns(mesh, pspecs), _ns(mesh, opt_specs),
                  {"loss": NamedSharding(mesh, P())})
        donate = (0, 1)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, max_len=shape.seq_len, impl=impl,
                                 unroll=n_unroll)
        cache_specs = shard.make_cache_specs(cfg, mesh, shape.global_batch,
                                             seq_len=shape.seq_len, fsdp=fsdp)
        batch_specs = shard.make_input_specs_tree(cfg, mesh, shape, fsdp=fsdp)
        batch_specs.pop("labels", None)
        args = (params, S.input_specs(cfg, shape))
        in_sh = (_ns(mesh, pspecs), _ns(mesh, batch_specs))
        out_sh = (NamedSharding(mesh, P(b, None, vocab_sh)),
                  _ns(mesh, cache_specs))
        donate = ()
    elif shape.kind == "decode":
        step = make_decode_step(cfg, impl=impl, unroll=n_unroll)
        cache_specs = shard.make_cache_specs(cfg, mesh, shape.global_batch,
                                             seq_len=shape.seq_len, fsdp=fsdp)
        cache = S.abstract_cache(cfg, shape)
        ins = S.input_specs(cfg, shape)
        args = (params, cache, ins["token"], ins["position"])
        in_sh = (_ns(mesh, pspecs), _ns(mesh, cache_specs),
                 NamedSharding(mesh, P(b, None)), NamedSharding(mesh, P()))
        out_sh = (NamedSharding(mesh, P(b, None)),
                  NamedSharding(mesh, P(b, None, vocab_sh)),
                  _ns(mesh, cache_specs))
        donate = (1,)
    else:
        raise ValueError(shape.kind)
    return step, args, in_sh, out_sh, donate, ax_rules


def _compile_once(arch, shape_name, mesh, build_kw):
    step, args, in_sh, out_sh, donate, ax_rules = build_cell(
        arch, shape_name, mesh, **build_kw)
    fsdp_axis = "data" if build_kw.get("fsdp", True) else None
    with mesh, shard.activation_rules(ax_rules, mesh=mesh,
                                      fsdp_axis=fsdp_axis):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        return lowered.compile()


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             save: bool = True, verbose: bool = True, fast: bool = False,
             no_mem: bool = False, **build_kw) -> dict:
    """Two compiles per cell: the COST pass unrolls the layer stack so
    cost_analysis counts every layer (XLA treats a while body as one
    iteration) and per-layer collectives appear individually; the MEMORY pass
    uses the production lax.scan config (XLA:CPU, unlike the TPU backend,
    never reuses buffers across unrolled layers, so unrolled temp_bytes is a
    CPU artifact — the scanned number is the deployable one)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    if fast:
        # single scanned compile: proves the sharding config lowers+compiles
        # (the multi-pod validity pass); costs are per-while-body
        compiled = _compile_once(arch, shape_name, mesh,
                                 dict(build_kw, unroll=1))
        mem_pass = compiled
    else:
        compiled = _compile_once(
            arch, shape_name, mesh,
            dict(build_kw, unroll=build_kw.get("unroll", 0) or 0))
        if no_mem:
            mem_pass = compiled
        else:
            mem_pass = _compile_once(arch, shape_name, mesh,
                                     dict(build_kw, unroll=1))
    t_lower = 0.0
    t_compile = time.time() - t0
    mem = mem_pass.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    coll = collective_stats(hlo_text)
    top_coll = top_collectives(hlo_text)
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": int(mesh.devices.size),
        "kind": shape.kind,
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective": coll.as_dict(),
        "top_collectives": top_coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "model_flops_global": S.model_flops(cfg, shape),
        "active_params": S.active_param_count(cfg),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "options": {k: v for k, v in build_kw.items()},
    }
    if verbose:
        peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes +
                mem.output_size_in_bytes - mem.alias_size_in_bytes)
        print(f"[{arch} × {shape_name} × {mesh_name}] OK  "
              f"flops/dev={result['flops_per_device']:.3e}  "
              f"bytes/dev={result['bytes_accessed_per_device']:.3e}  "
              f"coll/dev={coll.total_bytes:.3e}B ({coll.total_count} ops)  "
              f"mem/dev≈{peak/2**30:.2f}GiB  "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s", flush=True)
    if save:
        tag = "_".join(f"{k}-{v}" for k, v in build_kw.items())
        tag = ("fast_" if fast else "") + (tag or "baseline")
        ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
        out = ARTIFACT_DIR / f"{arch}__{shape_name}__{mesh_name}__{tag}.json"
        out.write_text(json.dumps(result, indent=1))
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (or --all)")
    ap.add_argument("--shape", default=None,
                    help="train_4k|prefill_32k|decode_32k|long_500k")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × assigned shape) cell")
    ap.add_argument("--fsdp", type=int, default=1)
    ap.add_argument("--remat", default="full",
                    choices=["none", "dots", "full"])
    ap.add_argument("--impl", default="chunked",
                    choices=["xla", "chunked", "pallas"])
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--unroll", type=int, default=0,
                    help="scan unroll factor (0 = fully unroll)")
    ap.add_argument("--fast", action="store_true",
                    help="single scanned compile (validity only)")
    ap.add_argument("--no-mem", action="store_true",
                    help="skip the scanned memory pass (perf iterations)")
    ap.add_argument("--barrier", action="store_true",
                    help="bf16 barrier at block boundaries (§Perf)")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch, cfg in REGISTRY.items():
            for sh in shapes_for(cfg):
                cells.append((arch, sh.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells.append((args.arch, args.shape))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch, shape_name in cells:
        for multi_pod in meshes:
            try:
                run_cell(arch, shape_name, multi_pod=multi_pod,
                         save=not args.no_save, fast=args.fast,
                         no_mem=args.no_mem,
                         fsdp=bool(args.fsdp),
                         remat=args.remat, impl=args.impl,
                         microbatch=args.microbatch,
                         seq_shard=args.seq_shard, unroll=args.unroll,
                         bf16_barrier=args.barrier)
            except Exception as e:  # noqa: BLE001 — report all failures at end
                failures.append((arch, shape_name, multi_pod, repr(e)))
                print(f"[{arch} × {shape_name} × multi={multi_pod}] FAILED: {e}",
                      flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         + "; ".join(f"{a}×{s}" for a, s, _, _ in failures))
    print("dry-run: all requested cells compiled successfully", flush=True)


if __name__ == "__main__":
    main()
