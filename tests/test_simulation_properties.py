"""Property tests for the port-batched simulator (ISSUE 2).

All strategies stay inside the `tests/_propcheck.py` shim subset
(`integers`, `sampled_from`, `@given`, `@settings`), so this module runs
offline in CI exactly as with real hypothesis.

Invariants checked on seeded small lattices:
  * packet conservation — injected = delivered + in-flight, bounded by
    the total buffer capacity, for BOTH implementations,
  * accepted throughput never exceeds offered load (up to Bernoulli
    noise) nor the paper's Δ/k̄ capacity bound for edge-symmetric graphs,
  * the batched implementation statistically agrees with the per-port
    reference sweep (same seeds, independent arbitration streams),
  * `simulate_sweep` (one vmapped device program) reproduces per-load
    `simulate` calls exactly,
  * the device DOR link-crossing walk matches the numpy walk bitwise-ish
    (float32 accumulation) for engine-routed traffic.
"""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import BCC, PC, Torus
from repro.core.simulation import build_tables, simulate, simulate_sweep
from repro.core.throughput import (channel_load, channel_load_device,
                                   symmetric_throughput_bound)

# shared run shape → one compile per (graph, impl) across all examples
SLOTS, WARMUP = 160, 40

_GRAPHS = {
    "BCC2": BCC(2),          # 32 nodes, edge-symmetric
    "PC2": PC(2),            # 8 nodes, edge-symmetric
    "T442": Torus(4, 4, 2),  # 32 nodes, mixed-radix
}
_TABLES = {k: build_tables(g) for k, g in _GRAPHS.items()}


def _run(name, load, seed, impl="batched", pattern="uniform"):
    g = _GRAPHS[name]
    return simulate(g, pattern, load, slots=SLOTS, warmup=WARMUP,
                    seed=seed, tables=_TABLES[name], impl=impl)


@settings(max_examples=6)
@given(name=st.sampled_from(sorted(_GRAPHS)),
       load=st.sampled_from([0.1, 0.3, 0.7, 1.0]),
       seed=st.integers(0, 5),
       impl=st.sampled_from(["batched", "reference"]))
def test_packet_conservation(name, load, seed, impl):
    """No loss, no duplication: injected − delivered = in-flight ∈
    [0, total buffer slots]."""
    g = _GRAPHS[name]
    r = simulate(g, "uniform", load, slots=SLOTS, warmup=0, seed=seed,
                 tables=_TABLES[name], impl=impl)
    in_flight = r.injected - r.delivered
    assert 0 <= in_flight <= g.order * g.degree * 4, (impl, in_flight)


@settings(max_examples=6)
@given(name=st.sampled_from(sorted(_GRAPHS)),
       load=st.sampled_from([0.1, 0.3, 0.6]),
       seed=st.integers(0, 5))
def test_accepted_at_most_offered(name, load, seed):
    """Accepted throughput ≤ offered load up to Bernoulli sampling noise
    (≈4σ for the smallest graph/run)."""
    r = _run(name, load, seed)
    N = _GRAPHS[name].order
    sigma = np.sqrt(load * N * (SLOTS - WARMUP)) / (N * (SLOTS - WARMUP))
    assert r.accepted_load <= load + 4 * sigma + 1e-9, (r.accepted_load, load)


@settings(max_examples=6)
@given(name=st.sampled_from(["BCC2", "PC2"]),
       load=st.sampled_from([0.6, 1.0]),
       seed=st.integers(0, 5),
       impl=st.sampled_from(["batched", "reference"]))
def test_accepted_at_most_capacity_bound(name, load, seed, impl):
    """Accepted throughput of edge-symmetric graphs never beats the §3.4
    Δ/k̄ bound (with a small stochastic margin)."""
    r = _run(name, load, seed, impl=impl)
    bound = symmetric_throughput_bound(_GRAPHS[name])
    assert r.accepted_load <= bound * 1.05 + 0.02, (r.accepted_load, bound)


@settings(max_examples=6)
@given(name=st.sampled_from(sorted(_GRAPHS)),
       load=st.sampled_from([0.1, 0.2, 0.3]),
       seed=st.integers(0, 4))
def test_batched_matches_reference_below_saturation(name, load, seed):
    """Below saturation both implementations accept ≈ the offered load;
    their difference is pure arbitration-stream noise."""
    rb = _run(name, load, seed, impl="batched")
    rr = _run(name, load, seed, impl="reference")
    N = _GRAPHS[name].order
    tol = 4 * np.sqrt(load * N * (SLOTS - WARMUP)) / (N * (SLOTS - WARMUP))
    assert abs(rb.accepted_load - rr.accepted_load) <= 2 * tol + 0.01, \
        (rb.accepted_load, rr.accepted_load)


@settings(max_examples=4)
@given(name=st.sampled_from(sorted(_GRAPHS)), seed=st.integers(0, 3),
       pattern=st.sampled_from(["uniform", "centralsymmetric"]))
def test_batched_peak_matches_reference(name, seed, pattern):
    """Saturated (peak) throughput of the two implementations agrees
    within stochastic tolerance on small lattices."""
    loads = (0.5, 0.75, 1.0)
    pk = {}
    for impl in ("batched", "reference"):
        pk[impl] = max(
            _run(name, l, seed, impl=impl, pattern=pattern).accepted_load
            for l in loads)
    rel = abs(pk["batched"] - pk["reference"]) / max(pk["reference"], 1e-9)
    assert rel <= 0.15, pk


@settings(max_examples=4)
@given(name=st.sampled_from(sorted(_GRAPHS)), seed=st.integers(0, 3))
def test_sweep_equals_individual_runs(name, seed):
    """One vmapped sweep program == per-load simulate() calls.  Sweep
    point ℓ folds the base key by its load index (PR 3), so the matching
    single run is simulate(..., fold=ℓ)."""
    g = _GRAPHS[name]
    loads = [0.2, 0.5, 0.9]
    res = simulate_sweep(g, "uniform", loads, slots=SLOTS, warmup=WARMUP,
                         seed=seed, tables=_TABLES[name])
    for i, (load, r) in enumerate(zip(loads, res)):
        single = simulate(g, "uniform", load, slots=SLOTS, warmup=WARMUP,
                          seed=seed, tables=_TABLES[name], fold=i)
        assert r.delivered == single.delivered, (load, r, single)
        assert r.injected == single.injected


@settings(max_examples=3)
@given(name=st.sampled_from(sorted(_GRAPHS)), seed=st.integers(0, 3),
       load=st.sampled_from([0.3, 0.7]))
def test_sweep_points_are_decorrelated(name, seed, load):
    """Regression for the ROADMAP identical-seed-vmap note: pre-PR-3 every
    run of a sweep shared one PRNG key, so two sweep points at the SAME
    offered load were perfectly correlated (bitwise-equal counters).  With
    per-(load-index) key folds they must differ."""
    g = _GRAPHS[name]
    a, b = simulate_sweep(g, "uniform", [load, load], slots=SLOTS,
                          warmup=WARMUP, seed=seed, tables=_TABLES[name])
    assert (a.delivered, a.injected) != (b.delivered, b.injected), (a, b)


@settings(max_examples=6)
@given(name=st.sampled_from(sorted(_GRAPHS)),
       load=st.sampled_from([0.3, 0.8]),
       seed=st.integers(0, 3),
       faults=st.integers(1, 4),
       policy=st.sampled_from(["dor", "adaptive", "escape"]),
       impl=st.sampled_from(["batched", "reference"]))
def test_scenario_conservation_and_dead_link_audit(name, load, seed, faults,
                                                   policy, impl):
    """Random fault scenarios: conservation is EXACT (delivered + in-flight
    + dropped == injected) and no packet ever crosses a dead channel."""
    from repro.core import Scenario
    g = _GRAPHS[name]
    scen = Scenario.random_link_faults(g, faults, seed=seed, policy=policy)
    r = simulate(g, "uniform", load, slots=SLOTS, warmup=0, seed=seed,
                 tables=_TABLES[name], impl=impl, scenario=scen)
    assert r.delivered + r.in_flight + r.dropped == r.injected, r
    assert r.link_use[~scen.link_ok(g)].sum() == 0


@settings(max_examples=6)
@given(name=st.sampled_from(sorted(_GRAPHS)), seed=st.integers(0, 5),
       pairs=st.integers(500, 3000))
def test_channel_load_device_matches_numpy(name, seed, pairs):
    """Device DOR walk ≡ numpy walk for identical records and sources."""
    from repro.core.routing import make_router
    g = _GRAPHS[name]
    rng = np.random.default_rng(seed)
    router = make_router(g.matrix)
    srcs = rng.integers(0, g.order, pairs)
    v = g.labels[srcs] - g.labels[rng.integers(0, g.order, pairs)]
    rec = np.asarray(router(v))
    l_np = channel_load(g, rec, seed=seed)
    l_dev = channel_load_device(g, rec, srcs=srcs)
    assert np.abs(l_np - l_dev).max() < 1e-5 * max(1.0, l_np.max())
