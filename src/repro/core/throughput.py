"""Throughput bounds under uniform traffic (paper §3.4).

For edge-symmetric graphs the uniform-traffic throughput (phits/cycle/node)
is bounded by Δ/k̄.  For edge-asymmetric mixed-radix tori the binding
constraint is the most loaded dimension: Δ/(n·k̄_max), where k̄_max is the
largest per-dimension average distance.
"""
from __future__ import annotations

import numpy as np

from .condition import NetworkCondition
from .distances import (_warn_deprecated, bcc_average_distance,
                        fcc_average_distance, pc_average_distance)
from .lattice import LatticeGraph


def symmetric_throughput_bound(g: LatticeGraph) -> float:
    """Δ/k̄ for edge-symmetric lattice graphs."""
    return g.degree / g.average_distance


def ring_average_distance(s: int) -> float:
    return (s * s // 4 if s % 2 == 0 else (s * s - 1) // 4) / s


def mixed_torus_throughput_bound(*sides: int) -> float:
    """Δ/(n·k̄_max) (inferred from [7] as quoted in §3.4)."""
    n = len(sides)
    k_max = max(ring_average_distance(s) for s in sides)
    return (2 * n) / (n * k_max)


def fcc_throughput_bound(a: int) -> float:
    """48/(7a) asymptotically (§3.4); exact via the closed-form k̄."""
    return 6.0 / fcc_average_distance(a)


def bcc_throughput_bound(a: int) -> float:
    """192/(35a) asymptotically (§3.4)."""
    return 6.0 / bcc_average_distance(a)


def pc_throughput_bound(a: int) -> float:
    return 6.0 / pc_average_distance(a)


def channel_load(g: LatticeGraph, records: np.ndarray,
                 seed: int = 0) -> np.ndarray:
    """Directional link loads (N, 2n) implied by a set of routing records under
    one-packet-per-node uniform traffic, assuming DOR traversal order.

    records: (P, n) minimal routing records for P source→dest pairs, sources
    drawn uniformly.  Returns expected phit-crossings per directional link per
    injected packet; max load determines saturation throughput 1/max."""
    n = g.n
    N = g.order
    P = records.shape[0]
    load = np.zeros((N, 2 * n), dtype=np.float64)
    # DOR: dimension 0 hops first, then 1, ...
    srcs = np.random.default_rng(seed).integers(0, N, size=P)
    pos = g.labels[srcs].astype(np.int64).copy()
    for dim in range(n):
        r = records[:, dim]
        sgn = np.sign(r).astype(np.int64)
        direction = (sgn < 0).astype(np.int64)
        for s in range(int(np.abs(r).max(initial=0))):
            active = np.abs(r) > s
            idx = g.label_to_index(pos[active])
            np.add.at(load, (idx, 2 * dim + direction[active]), 1.0)
            pos[active, dim] += sgn[active]
    return load * (N / P)


_DEVICE_WALK_CACHE: dict = {}


def channel_load_device(g: LatticeGraph, records: np.ndarray,
                        srcs: np.ndarray | None = None,
                        seed: int = 0) -> np.ndarray:
    """`channel_load` with the DOR link-crossing walk on device, as ONE
    segment-sum.  DOR positions are closed-form — after finishing
    dimensions d' < d the packet sits at src + Σ_{d'<d} r_{d'}·e_{d'} —
    so every crossing event (pair, dim, step) is enumerated by
    broadcasting, canonically reduced, flattened to a directional-link id
    and accumulated with a single `jax.ops.segment_sum` over N·2n
    segments.  No per-step scatter and no fori_loop (this closes the
    ROADMAP "device walk is scatter-serialized on CPU" frontier); same
    loads as the numpy walk for the same records/sources, which remains
    as `channel_load`."""
    import jax
    import jax.numpy as jnp

    from .routing_engine import canonical_reduce

    n, N = g.n, g.order
    records = np.asarray(records)
    P = records.shape[0]
    if srcs is None:
        srcs = np.random.default_rng(seed).integers(0, N, size=P)
    bounds = tuple(int(np.abs(records[:, d]).max(initial=0))
                   for d in range(n))
    hermite = g.hermite.astype(np.int32)
    key = (n, N, P, bounds, hermite.tobytes())
    if key not in _DEVICE_WALK_CACHE:
        H = jnp.asarray(hermite)
        strides = jnp.asarray(g.strides.astype(np.int32))
        diag = tuple(int(hermite[i, i]) for i in range(n))
        eye = np.eye(n, dtype=np.int32)
        # completed-dimension mask: prefix_d = src + rec ⊙ lower[d]
        lower = np.tril(np.ones((n, n), np.int32), -1)

        def walk(pos, rec):
            ids, weights = [], []
            for dim in range(n):            # static, tiny
                b = bounds[dim]
                if b == 0:
                    continue
                r = rec[:, dim]                             # (P,)
                sgn = jnp.sign(r)
                chan = 2 * dim + (r < 0)
                prefix = pos + rec * lower[dim]             # (P, n)
                t = jnp.arange(b, dtype=jnp.int32)
                steps = (prefix[:, None, :]
                         + t[None, :, None] * sgn[:, None, None]
                         * eye[dim][None, None, :])         # (P, b, n)
                w = canonical_reduce(steps, H, diag)
                idx = (w * strides).sum(axis=-1)            # (P, b)
                ids.append((idx * (2 * n) + chan[:, None]).ravel())
                weights.append(
                    (t[None, :] < jnp.abs(r)[:, None]).ravel())
            load = jax.ops.segment_sum(
                jnp.concatenate(weights).astype(jnp.float32),
                jnp.concatenate(ids), num_segments=N * 2 * n)
            return load.reshape(N, 2 * n) * (N / P)

        _DEVICE_WALK_CACHE[key] = jax.jit(walk)
    out = _DEVICE_WALK_CACHE[key](
        jnp.asarray(g.labels[srcs].astype(np.int32)),
        jnp.asarray(records.astype(np.int32)))
    return np.asarray(out, dtype=np.float64)


def channel_load_uniform(g: LatticeGraph, pairs: int = 20_000, seed: int = 0,
                         backend: str = "auto") -> np.ndarray:
    """Monte-Carlo channel loads under uniform traffic: sample `pairs`
    source→destination pairs, route them through the batched engine, and
    accumulate DOR link crossings — routing AND the crossing walk run on
    device unless `backend='numpy'`.  The empirical saturation throughput
    is `1 / channel_load_uniform(g).max()` phits/cycle/node — cross-check
    it against the analytic Δ/k̄ bound of §3.4."""
    from .routing import make_router
    rng = np.random.default_rng(seed)
    router = make_router(g.matrix, backend)
    srcs = rng.integers(0, g.order, pairs)
    v = g.labels[srcs] - g.labels[rng.integers(0, g.order, pairs)]
    records = np.asarray(router(v))
    if backend != "numpy":
        try:
            # channel_load re-draws `srcs` from the same seed (first draw
            # of the generator), so the device walk sees identical sources
            return channel_load_device(g, records, srcs=srcs)
        except ImportError:       # jax absent — numpy walk stands alone
            pass
    return channel_load(g, records, seed=seed)


def measured_saturation_throughput(g: LatticeGraph, pairs: int = 20_000,
                                   seed: int = 0,
                                   backend: str = "auto") -> float:
    """1/max-link-load under engine-routed uniform traffic (phits/cyc/node)."""
    return float(1.0 / channel_load_uniform(g, pairs, seed, backend).max())


def simulated_saturation_load(g: LatticeGraph, loads, *, pattern="uniform",
                              config=None, seeds: int = 1) -> float:
    """Dynamic counterpart of `measured_saturation_throughput`: sweep the
    slot-level simulator over `loads` offered phits/cycle/node and return
    the peak ACCEPTED load — saturation as the router actually realises it
    (queue contention, bubble rule, and with ``config.vcs > 1`` the VC
    credit-flow router) rather than the static 1/max-link-load proxy.
    `config` is a `repro.core.SimConfig`; None uses the defaults."""
    from .simulation import simulate_sweep
    if seeds == 1:
        seeds = None          # list[SimResult] path; no replication axis
    results = simulate_sweep(g, pattern, list(loads), seeds=seeds,
                             config=config)
    if isinstance(results, list):
        return max(float(r.accepted_load) for r in results)
    return float(results.accepted_mean().max())


# ---------------------------------------------------------------------------
# degraded-graph (scenario) loads: fault-aware table rebuild
# ---------------------------------------------------------------------------

def _fault_aware_channel_load(g: LatticeGraph, scenario,
                              pairs: int = 20_000, seed: int = 0,
                              tables=None,
                              backend: str = "auto") -> np.ndarray:
    """Monte-Carlo channel loads on a *degraded* graph: `pairs` uniform
    live-src → live-dst pairs are walked along the fault-aware BFS
    next-hop tables (`routing.fault_aware_next_hop`), so the load
    distribution — and the saturation bound 1/max derived from it —
    reflects the faulted topology instead of the pristine minimal records.
    Unreachable/self pairs are redrawn out of the sample; by construction
    no dead channel is ever crossed (asserted).  Scaled to one packet per
    live node, matching the `channel_load` convention.  The table rebuild
    runs on device by default (`routing.fault_aware_next_hop_device`,
    identical tables); backend="host" forces the numpy BFS loop."""
    from .routing import fault_aware_next_hop, fault_aware_next_hop_device
    if backend not in ("auto", "device", "host"):
        raise ValueError(f"unknown BFS backend {backend!r}")
    link_ok = scenario.link_ok(g)
    node_ok = scenario.node_ok(g)
    if tables is not None:
        dist, next_hop = tables
    elif backend != "host":
        try:
            dist, next_hop = fault_aware_next_hop_device(g, link_ok, node_ok)
        except ImportError:   # jax absent — only "auto" may fall back
            if backend == "device":
                raise
            dist, next_hop = fault_aware_next_hop(g, link_ok, node_ok)
    else:
        dist, next_hop = fault_aware_next_hop(g, link_ok, node_ok)
    live = np.flatnonzero(node_ok)
    if live.size < 2:
        raise ValueError("scenario leaves fewer than 2 live nodes")
    rng = np.random.default_rng(seed)
    srcs = live[rng.integers(0, live.size, pairs)]
    dsts = live[rng.integers(0, live.size, pairs)]
    use = dist[srcs, dsts] > 0                   # reachable, not self
    pos, dst = srcs[use].copy(), dsts[use]
    n_used = pos.size
    load = np.zeros((g.order, 2 * g.n), dtype=np.float64)
    nbr = g.neighbor_indices
    while pos.size:
        p = next_hop[pos, dst]
        assert (p >= 0).all() and link_ok[pos, p].all(), \
            "fault-aware walk stepped onto a dead channel"
        np.add.at(load, (pos, p), 1.0)
        pos = nbr[pos, p]
        alive = pos != dst
        pos, dst = pos[alive], dst[alive]
    return load * (live.size / max(n_used, 1))


def _fault_aware_saturation_throughput(g: LatticeGraph, scenario,
                                       pairs: int = 20_000,
                                       seed: int = 0) -> float:
    """1/max-link-load of the degraded graph under uniform live-pair
    traffic routed around the faults (phits/cycle/node)."""
    return float(
        1.0 / _fault_aware_channel_load(g, scenario, pairs, seed).max())


def _fault_aware_schedule_load(g: LatticeGraph, schedule, slots: int = 512,
                               pairs: int = 20_000, seed: int = 0,
                               link_spec=None) -> np.ndarray:
    """Per-EPOCH Monte-Carlo channel loads of a transient-fault timeline
    (`repro.core.fault_schedule.FaultSchedule` / `CompiledSchedule`):
    the fault-aware BFS tables for ALL epochs are rebuilt in one compiled
    device program (`routing.fault_aware_next_hop_device`'s stacked-epoch
    mode), then each epoch's live-pair traffic is walked along its own
    tables.  Returns (E, N, 2n) loads — or (E, N, 2n+2X) with a
    `link_spec` carrying express overlays, where the walk follows
    weighted-shortest-path tables over the extended port axis and link
    events may kill/repair express channels — the per-epoch load curve
    the degraded saturation bound below derives from."""
    from .fault_schedule import ensure_compiled
    from .routing import fault_aware_next_hop_device
    ls = link_spec if link_spec is not None and not link_spec.is_trivial \
        else None
    compiled = ensure_compiled(schedule, g, slots, ls)
    if ls is not None:
        dist, nh = fault_aware_next_hop_device(
            g, compiled.link_ok_stack(g, ls), compiled.node_ok_stack(g),
            link_spec=ls)
        nbr = ls.extended_neighbors(g)
        return np.stack([
            _walk_loads(nbr, dist[e], nh[e], scen.node_ok(g), pairs, seed,
                        link_ok=scen.link_ok(g, ls))
            for e, scen in enumerate(compiled.epochs)])
    dist, nh = fault_aware_next_hop_device(
        g, compiled.link_ok_stack(g), compiled.node_ok_stack(g))
    return np.stack([
        _fault_aware_channel_load(g, scen, pairs, seed,
                                  tables=(dist[e], nh[e]))
        for e, scen in enumerate(compiled.epochs)])


def _fault_aware_schedule_saturation(g: LatticeGraph, schedule,
                                     slots: int = 512, pairs: int = 20_000,
                                     seed: int = 0,
                                     link_spec=None) -> np.ndarray:
    """(E,) per-epoch saturation bounds of a transient-fault timeline —
    how the fabric's degraded capacity moves as links flap and nodes
    die/return.  Uniform fabrics use 1/max-load; a weighted `link_spec`
    scales each channel's load by its slot cost first (the
    `weighted_saturation_throughput` convention)."""
    loads = _fault_aware_schedule_load(g, schedule, slots, pairs, seed,
                                       link_spec=link_spec)
    if link_spec is not None and not link_spec.is_trivial:
        w = link_spec.port_weights(g.n).astype(np.float64)
        loads = loads * w[None, None, :]
    return 1.0 / loads.reshape(loads.shape[0], -1).max(axis=1)


# ---------------------------------------------------------------------------
# heterogeneous-link (LinkSpec) loads: weighted tables over extended ports
# ---------------------------------------------------------------------------

def _walk_loads(nbr: np.ndarray, dist: np.ndarray, next_hop: np.ndarray,
                node_ok: np.ndarray, pairs: int, seed: int,
                link_ok: np.ndarray | None = None) -> np.ndarray:
    """Shared Monte-Carlo table walk over an arbitrary (N, P) port axis:
    `pairs` uniform live-src → live-dst draws stepped along `next_hop`,
    unreachable/self pairs redrawn out of the sample, loads scaled to one
    packet per live node.  With `link_ok` every step additionally asserts
    it never crosses a dead channel (express columns included)."""
    N, P = nbr.shape
    node_ok = np.asarray(node_ok, dtype=bool)
    live = np.flatnonzero(node_ok)
    if live.size < 2:
        raise ValueError("scenario leaves fewer than 2 live nodes")
    rng = np.random.default_rng(seed)
    srcs = live[rng.integers(0, live.size, pairs)]
    dsts = live[rng.integers(0, live.size, pairs)]
    use = dist[srcs, dsts] > 0                   # reachable, not self
    pos, dst = srcs[use].copy(), dsts[use]
    n_used = pos.size
    load = np.zeros((N, P), dtype=np.float64)
    while pos.size:
        p = next_hop[pos, dst]
        assert (p >= 0).all(), "fault-aware walk hit an unreachable pair"
        if link_ok is not None:
            assert link_ok[pos, p].all(), \
                "fault-aware walk stepped onto a dead channel"
        np.add.at(load, (pos, p), 1.0)
        pos = nbr[pos, p]
        alive = pos != dst
        pos, dst = pos[alive], dst[alive]
    return load * (live.size / max(n_used, 1))


def _weighted_channel_load(g: LatticeGraph, link_spec, pairs: int = 20_000,
                           seed: int = 0, scenario=None) -> np.ndarray:
    """Monte-Carlo channel loads on a HETEROGENEOUS fabric: `pairs`
    uniform pairs walked along weighted-shortest-path next-hop tables
    over the extended (base + express) port axis — express channels
    attract the traffic whose weighted cost they lower, pillar masks
    divert Z-traffic through the pillar columns.  Returns (N, P) with
    P = 2n + 2·X (the base (N, 2n) block keeps the `channel_load`
    convention; express columns follow).  Scaled to one packet per live
    node.  An optional fault `scenario` composes over the FULL extended
    axis — dead_links may name express ports (they die like any link)
    and traffic reroutes around them through the base lattice."""
    from .routing import fault_aware_next_hop_device
    ls = link_spec if link_spec is not None and not link_spec.is_trivial \
        else None
    if scenario is not None:
        link_ok = scenario.link_ok(g, ls)
        node_ok = np.asarray(scenario.node_ok(g), dtype=bool)
    else:
        link_ok = np.ones((g.order, 2 * g.n), dtype=bool)
        node_ok = np.ones(g.order, dtype=bool)
    dist, next_hop = fault_aware_next_hop_device(
        g, link_ok, node_ok, link_spec=link_spec)
    nbr = ls.extended_neighbors(g) if ls is not None else g.neighbor_indices
    return _walk_loads(nbr, dist, next_hop, node_ok, pairs, seed,
                       link_ok=None if scenario is None else
                       scenario.link_ok(g, ls))


def _weighted_saturation_throughput(g: LatticeGraph, link_spec,
                                    pairs: int = 20_000,
                                    seed: int = 0, scenario=None) -> float:
    """Saturation bound of the heterogeneous fabric (phits/cycle/node):
    ``1 / max_c(load_c · w_c)`` — a weight-w channel serves one packet
    every w slots, so its effective service demand is its Monte-Carlo
    load times its slot cost.  With a trivial spec this is exactly the
    unweighted 1/max-link-load bound.  An optional fault `scenario`
    composes (the facade's weighted × faulted cell — the legacy
    `weighted_saturation_throughput` never grew this axis)."""
    load = _weighted_channel_load(g, link_spec, pairs, seed,
                                  scenario=scenario)
    w = _effective_port_weights(g, link_spec, load.shape[-1])
    return float(1.0 / (load * w[None, :]).max())


def _effective_port_weights(g: LatticeGraph, link_spec,
                            n_ports: int) -> np.ndarray:
    """(P,) slot costs matching a load array's port axis: the LinkSpec's
    per-port weights when heterogeneous, all-ones otherwise."""
    if link_spec is not None and not link_spec.is_trivial:
        return link_spec.port_weights(g.n).astype(np.float64)
    return np.ones(n_ports, dtype=np.float64)


# ---------------------------------------------------------------------------
# unified analytic surface: channel_load_stats / saturation facades + shims
# ---------------------------------------------------------------------------

def channel_load_stats(g: LatticeGraph,
                       condition: NetworkCondition | None = None,
                       **kwargs) -> dict:
    """Monte-Carlo channel-load summary of `g` under one
    `repro.core.NetworkCondition` — THE entry point for degraded/weighted
    load metrics (the shimmed `fault_aware_*`/`weighted_*` names all
    dispatch through here).

    Returns {"load", "max_load", "saturation"} where `load` is the
    (N, P) per-channel phit-crossing array (P = 2n, or 2n+2X with
    express overlays), `max_load` is the peak *effective* service demand
    ``max_c(load_c · w_c)`` and `saturation` is its reciprocal — so
    ``saturation == saturation(g, condition)`` always.  A `schedule`
    condition returns per-EPOCH arrays ((E, N, P) / (E,)) plus
    `epoch_start_slot`.

    Dispatch: `links` → weighted tables over the extended port axis
    (composable with `scenario`); `scenario` → fault-aware BFS tables;
    `schedule` → per-epoch stacked tables; pristine → DOR minimal-record
    crossings (`channel_load_uniform`)."""
    cond = NetworkCondition.from_kwargs(condition, **kwargs)
    if cond.schedule is not None:
        load = _fault_aware_schedule_load(
            g, cond.schedule, cond.slots, cond.pairs, cond.seed,
            link_spec=cond.links)
        w = _effective_port_weights(g, cond.links, load.shape[-1])
        max_load = (load * w[None, None, :]).reshape(
            load.shape[0], -1).max(axis=1)
        from .fault_schedule import ensure_compiled
        ls = cond.links if cond.links is not None \
            and not cond.links.is_trivial else None
        compiled = ensure_compiled(cond.schedule, g, cond.slots, ls)
        return {"load": load, "max_load": max_load,
                "saturation": 1.0 / max_load,
                "epoch_start_slot": np.asarray(compiled.starts, np.int64)}
    if cond.links is not None:
        load = _weighted_channel_load(g, cond.links, cond.pairs, cond.seed,
                                      scenario=cond.scenario)
    elif cond.scenario is not None:
        load = _fault_aware_channel_load(g, cond.scenario, cond.pairs,
                                         cond.seed, backend=cond.backend)
    else:
        load = channel_load_uniform(g, cond.pairs, cond.seed,
                                    cond.router_backend)
    w = _effective_port_weights(g, cond.links, load.shape[-1])
    max_load = float((load * w[None, :]).max())
    return {"load": load, "max_load": max_load,
            "saturation": 1.0 / max_load}


def saturation(g: LatticeGraph,
               condition: NetworkCondition | None = None,
               **kwargs) -> float | np.ndarray:
    """Saturation throughput of `g` under one
    `repro.core.NetworkCondition` (phits/cycle/node): the reciprocal of
    the peak effective channel demand ``max_c(load_c · w_c)`` under
    uniform (live-pair) Monte-Carlo traffic.  Scalar for static
    conditions; (E,) per-epoch array for a `schedule`.

    This subsumes `measured_saturation_throughput` (pristine),
    `fault_aware_saturation_throughput` (scenario),
    `weighted_saturation_throughput` (links — now composable with a
    scenario) and `fault_aware_schedule_saturation` (schedule)."""
    cond = NetworkCondition.from_kwargs(condition, **kwargs)
    if cond.schedule is not None:
        return _fault_aware_schedule_saturation(
            g, cond.schedule, cond.slots, cond.pairs, cond.seed,
            link_spec=cond.links)
    if cond.links is not None:
        return _weighted_saturation_throughput(
            g, cond.links, cond.pairs, cond.seed, scenario=cond.scenario)
    if cond.scenario is not None:
        return _fault_aware_saturation_throughput(
            g, cond.scenario, cond.pairs, cond.seed)
    return measured_saturation_throughput(g, cond.pairs, cond.seed,
                                          cond.router_backend)


def fault_aware_channel_load(g: LatticeGraph, scenario,
                             pairs: int = 20_000, seed: int = 0,
                             tables=None, backend: str = "auto") -> np.ndarray:
    """Deprecated shim — `channel_load_stats(g, scenario=...)`."""
    _warn_deprecated("fault_aware_channel_load",
                     "channel_load_stats(g, scenario=...)['load']")
    return _fault_aware_channel_load(g, scenario, pairs, seed, tables,
                                     backend)


def fault_aware_saturation_throughput(g: LatticeGraph, scenario,
                                      pairs: int = 20_000,
                                      seed: int = 0) -> float:
    """Deprecated shim — `saturation(g, scenario=...)`."""
    _warn_deprecated("fault_aware_saturation_throughput",
                     "saturation(g, scenario=...)")
    return _fault_aware_saturation_throughput(g, scenario, pairs, seed)


def fault_aware_schedule_load(g: LatticeGraph, schedule, slots: int = 512,
                              pairs: int = 20_000, seed: int = 0,
                              link_spec=None) -> np.ndarray:
    """Deprecated shim — `channel_load_stats(g, schedule=...)`."""
    _warn_deprecated("fault_aware_schedule_load",
                     "channel_load_stats(g, schedule=...)['load']")
    return _fault_aware_schedule_load(g, schedule, slots, pairs, seed,
                                      link_spec)


def fault_aware_schedule_saturation(g: LatticeGraph, schedule,
                                    slots: int = 512, pairs: int = 20_000,
                                    seed: int = 0,
                                    link_spec=None) -> np.ndarray:
    """Deprecated shim — `saturation(g, schedule=...)`."""
    _warn_deprecated("fault_aware_schedule_saturation",
                     "saturation(g, schedule=...)")
    return _fault_aware_schedule_saturation(g, schedule, slots, pairs, seed,
                                            link_spec)


def weighted_channel_load(g: LatticeGraph, link_spec, pairs: int = 20_000,
                          seed: int = 0, scenario=None) -> np.ndarray:
    """Deprecated shim — `channel_load_stats(g, links=...)`."""
    _warn_deprecated("weighted_channel_load",
                     "channel_load_stats(g, links=...)['load']")
    return _weighted_channel_load(g, link_spec, pairs, seed, scenario)


def weighted_saturation_throughput(g: LatticeGraph, link_spec,
                                   pairs: int = 20_000,
                                   seed: int = 0) -> float:
    """Deprecated shim — `saturation(g, links=...)`."""
    _warn_deprecated("weighted_saturation_throughput",
                     "saturation(g, links=...)")
    return _weighted_saturation_throughput(g, link_spec, pairs, seed)
