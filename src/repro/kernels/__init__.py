"""Pallas TPU kernels for the perf-critical compute hot-spots:
flash attention (prefill/train), decode attention (long-KV serve),
SSD intra-chunk (Mamba2), fused RMSNorm, and the §6.2 simulator's fused
slot step (sim_step — winner arbitration + acceptance + apply in one
pass, the `impl="fused"` backend of `repro.core.simulation`).  Each has
a pure-jnp oracle (ref.py, or the simulator's reference impl); ops.py
holds the jit'd model-facing wrappers."""
from . import ops, ref, sim_step  # noqa: F401
