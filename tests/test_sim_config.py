"""The unified `SimConfig` surface (ISSUE 7 api_redesign): one frozen
value object accepted by every `simulate*` entry point, a strict
config-vs-legacy-kwarg conflict rule, validation centralized in
`__post_init__`, and the deprecation of `simulate_load_sweep`.
"""
import warnings

import pytest

from repro.core import FaultSchedule, Scenario, SimConfig, Torus
from repro.core.simulation import (SweepStats, build_tables, simulate,
                                   simulate_load_sweep,
                                   simulate_scenario_sweep,
                                   simulate_schedule_sweep, simulate_sweep,
                                   throughput_curve)

G = Torus(4, 4)
TAB = build_tables(G)
CFG = SimConfig(slots=96, warmup=16, seed=1, tables=TAB)


# ---------------------------------------------------------------------------
# construction & validation (the one shared home of every check)
# ---------------------------------------------------------------------------

def test_defaults_match_legacy_signature():
    c = SimConfig()
    assert (c.slots, c.warmup, c.queue, c.seed) == (512, 128, 4, 0)
    assert (c.impl, c.hist_bins, c.vcs, c.credits) == ("batched", 0, 1, None)
    assert c.scenario is None and c.schedule is None


def test_replace_revalidates():
    assert CFG.replace(vcs=2).vcs == 2
    with pytest.raises(ValueError, match="unknown simulator impl"):
        CFG.replace(impl="gpu")


@pytest.mark.parametrize("bad, match", [
    (dict(slots=0), "slots must be positive"),
    (dict(warmup=200, slots=100), "warmup <= slots"),
    (dict(queue=1), "queue must be >= 2"),
    (dict(hist_bins=-1), "hist_bins"),
    (dict(vcs=0), "vcs must be >= 1"),
    (dict(credits=2), "needs vcs >= 2"),
    (dict(vcs=2, credits=1), "2 <= credits"),
    (dict(vcs=2, credits=5, queue=4), "credits <= queue"),
    (dict(vcs=2, impl="fused"), "V=1-only"),
    (dict(scenario=Scenario(), schedule=FaultSchedule(events=())),
     "not both"),
])
def test_post_init_validation(bad, match):
    with pytest.raises(ValueError, match=match):
        SimConfig(**bad)


def test_vcs_composes_with_schedule():
    """ISSUE 9 inverted the V=1-only guard: vcs>=2 + schedule= is now a
    supported cell (the VC slot steps thread the per-epoch masks)."""
    sched = FaultSchedule(events=((10, "link_down", (0, 0)),))
    cfg = SimConfig(vcs=2, schedule=sched, slots=64, warmup=0, seed=1,
                    tables=TAB)
    r = simulate(G, "uniform", 0.4, config=cfg)
    assert r.timeline is not None and r.timeline.conservation_ok()
    assert r.vc_delivered is not None and int(r.vc_delivered.sum()) > 0


def test_from_kwargs_conflict_and_unknown():
    with pytest.raises(ValueError, match="both config= and legacy"):
        SimConfig.from_kwargs(CFG, slots=128)
    with pytest.raises(TypeError, match="unknown simulate kwargs"):
        SimConfig.from_kwargs(None, slotz=128)
    with pytest.raises(TypeError, match="expects a SimConfig"):
        SimConfig.from_kwargs({"slots": 128})
    # None-valued kwargs mean "not passed" — no conflict
    assert SimConfig.from_kwargs(CFG, slots=None) is CFG
    assert SimConfig.from_kwargs(None, slots=640).slots == 640


# ---------------------------------------------------------------------------
# all five entry points accept config= (and reject mixing)
# ---------------------------------------------------------------------------

def test_simulate_accepts_config():
    a = simulate(G, "uniform", 0.4, config=CFG)
    b = simulate(G, "uniform", 0.4, slots=96, warmup=16, seed=1, tables=TAB)
    assert (a.delivered, a.injected, a.accepted_load) == \
        (b.delivered, b.injected, b.accepted_load)
    with pytest.raises(ValueError, match="both config= and legacy"):
        simulate(G, "uniform", 0.4, config=CFG, slots=96)


def test_simulate_sweep_accepts_config():
    res = simulate_sweep(G, "uniform", (0.3, 0.5), config=CFG)
    assert len(res) == 2
    st = simulate_sweep(G, "uniform", (0.3,), config=CFG, seeds=2)
    assert isinstance(st, SweepStats)
    with pytest.raises(ValueError, match="both config= and legacy"):
        simulate_sweep(G, "uniform", (0.3,), config=CFG, seed=2)


def test_simulate_scenario_sweep_accepts_config():
    scens = [Scenario(), Scenario(dead_links=((1, 0),), policy="adaptive")]
    rows = simulate_scenario_sweep(G, "uniform", scens, loads=(0.4,),
                                   config=CFG)
    assert len(rows) == 2 and all(len(r) == 1 for r in rows)
    # the scenario axis comes from the list, never from the config
    with pytest.raises(ValueError, match="scenarios` list"):
        simulate_scenario_sweep(G, "uniform", scens,
                                config=CFG.replace(scenario=scens[1]))


def test_simulate_schedule_sweep_accepts_config():
    scheds = [FaultSchedule(events=((24, "link_down", (0, 0)),)),
              FaultSchedule(events=((12, "node_down", 3),))]
    rows = simulate_schedule_sweep(G, "uniform", scheds, loads=(0.4,),
                                   config=CFG)
    assert len(rows) == 2
    # vcs>=2 rides the same sweep program since ISSUE 9 (warmup=0: the
    # per-slot ledger only balances when every injection is counted)
    vrows = simulate_schedule_sweep(G, "uniform", scheds, loads=(0.4,),
                                    config=CFG.replace(vcs=2, warmup=0))
    assert len(vrows) == 2
    for row in vrows:
        assert row[0].timeline is not None
        assert row[0].timeline.conservation_ok()


def test_scenario_schedule_exclusion_same_error_everywhere():
    """The centralized __post_init__ check fires with ONE message on
    every path that used to duplicate it."""
    sched = FaultSchedule(events=((10, "link_down", (0, 0)),))
    for call in (
        lambda: simulate(G, "uniform", 0.4, slots=96, warmup=16,
                         tables=TAB, scenario=Scenario(), schedule=sched),
        lambda: simulate_sweep(G, "uniform", (0.4,), slots=96, warmup=16,
                               tables=TAB, scenario=Scenario(),
                               schedule=sched),
        lambda: SimConfig(scenario=Scenario(), schedule=sched),
    ):
        with pytest.raises(ValueError, match="not both"):
            call()


# ---------------------------------------------------------------------------
# the deprecated alias
# ---------------------------------------------------------------------------

def test_simulate_load_sweep_warns_and_delegates():
    with pytest.warns(DeprecationWarning, match="simulate_load_sweep is "
                      "deprecated"):
        old = simulate_load_sweep(G, "uniform", (0.4,), config=CFG)
    new = simulate_sweep(G, "uniform", (0.4,), config=CFG)
    assert old[0].accepted_load == new[0].accepted_load
    with pytest.warns(DeprecationWarning):
        throughput_curve(G, "uniform", (0.4,), config=CFG)


def test_vc_kwargs_reach_the_router_via_config():
    r = simulate(G, "uniform", 0.4, config=CFG.replace(vcs=2, credits=3))
    assert r.vc_delivered is not None and r.vc_delivered.shape == (2,)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # no stray deprecation noise
        r2 = simulate(G, "uniform", 0.4, slots=96, warmup=16, seed=1,
                      tables=TAB, vcs=2, credits=3)
    assert r2.delivered == r.delivered
