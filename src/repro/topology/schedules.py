"""Executable collective schedules from lattice routing (paper §5 → TPU).

The paper's minimal routing records are integer hop vectors on the pod's
lattice graph.  This module turns them into *collective schedules*:

  * `ring_schedule` — orders the chips of one logical mesh axis along a ring
    embedded in the lattice (from topology.placement) and derives, for every
    logical edge, the physical ICI links its traffic crosses (DOR over the
    minimal record).  `verify_contention_free` checks that a collective step
    uses every physical link at most once — the condition for the ring
    collective to run at full link bandwidth (dilation-1 embeddings pass).

  * `ppermute_ring_allreduce` — a reduce-scatter + all-gather all-reduce
    written explicitly with `jax.lax.ppermute` (2·(k−1) neighbor hops),
    numerically equal to `psum`.  This is the deterministic, topology-aware
    collective the schedule prices; on a real pod the ppermute pairs are
    laid onto the `ring_schedule` order.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LatticeGraph
from repro.core.routing import make_router
from repro.parallel import _compat

_compat.install()     # jax<0.5: callers drive these helpers via shard_map


# ---------------------------------------------------------------------------
# physical link schedules from routing records
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RingSchedule:
    """One logical axis embedded as a ring of physical chips."""
    node_order: np.ndarray          # (k,) lattice node indices, ring order
    edge_paths: list[list[tuple[int, int]]]   # per logical edge: [(node, port)]
    dilation: float                 # mean physical hops per logical edge
    # heterogeneous fabrics (ring_schedule(link_spec=...)): per logical
    # edge, the weighted slot cost of its path; and the (P,) per-port slot
    # costs so contention accounting can price weight-w links at 1/w
    # bandwidth.  None on the uniform weight-1 fabric (the historical
    # schedule, unchanged).
    edge_costs: np.ndarray | None = None
    port_weights: np.ndarray | None = None


def ring_schedule(g: LatticeGraph, ring_labels: np.ndarray,
                  link_spec=None, scenario=None) -> RingSchedule:
    """ring_labels: (k, n) lattice labels of the chips of one logical axis,
    in ring order.  Paths follow DOR over minimal routing records (all k
    logical edges routed in one batched engine call).

    `link_spec=` (a non-trivial `repro.core.LinkSpec`) lifts the standing
    pristine-uniform-ring constraint: each logical edge is instead routed
    along WEIGHTED shortest paths over the extended (base + express) port
    axis — express channels shorten edges whose offset they span, pillar
    masks force Z-traffic through pillar columns, and per-dimension
    weights steer paths onto cheap dimensions.  The returned schedule
    then carries `edge_costs` (weighted slots per logical edge) and
    `port_weights`, which `verify_contention_free` /
    `effective_ring_bandwidth` fold into their contention accounting.

    `scenario=` (a faulted `repro.core.Scenario`) routes the logical ring
    edges AROUND dead links/nodes via the fault-aware BFS next-hop tables
    (composes with `link_spec=` — dead_links may name express ports).  A
    ring chip that is itself dead, or a logical edge the live fabric
    disconnects, raises with the offending node/edge named — the caller
    must re-place the ring, not silently run a broken collective."""
    ls = (link_spec if link_spec is not None
          and not link_spec.is_trivial else None)
    scen = (scenario if scenario is not None
            and (scenario.dead_links or scenario.dead_nodes) else None)
    k = ring_labels.shape[0]
    order = g.label_to_index(ring_labels)
    if ls is not None or scen is not None:
        from repro.core.routing import fault_aware_next_hop_device
        if scen is not None:
            link_ok = scen.link_ok(g, ls)
            node_ok = np.asarray(scen.node_ok(g), dtype=bool)
            dead = [int(u) for u in order if not node_ok[u]]
            if dead:
                raise ValueError(
                    f"ring chip(s) {dead} are dead in scenario "
                    f"{scen.name!r}; re-place the ring on live nodes")
        else:
            link_ok = np.ones((g.order, 2 * g.n), dtype=bool)
            node_ok = None
        dist, nh = fault_aware_next_hop_device(g, link_ok, node_ok,
                                               link_spec=ls)
        nbr = (ls.extended_neighbors(g) if ls is not None
               else g.neighbor_indices)
        dsts = np.roll(np.asarray(order), -1)
        paths = []
        costs = []
        for t in range(k):
            u, d = int(order[t]), int(dsts[t])
            if u != d and dist[u, d] < 0:
                raise ValueError(
                    f"ring edge {u} -> {d} is unreachable — the live "
                    "fabric disconnects the ring"
                    + (f" (scenario {scen.name!r})" if scen is not None
                       else " (pillar mask cut the fabric)"))
            path = []
            pos = u
            while pos != d:
                p = int(nh[pos, d])
                path.append((pos, p))
                pos = int(nbr[pos, p])
            paths.append(path)
            costs.append(int(dist[u, d]) if u != d else 0)
        hops = [len(p) for p in paths]
        return RingSchedule(node_order=order, edge_paths=paths,
                            dilation=float(np.mean(hops)),
                            edge_costs=np.asarray(costs, dtype=np.int64),
                            port_weights=(None if ls is None
                                          else ls.port_weights(g.n)))
    router = make_router(g.matrix)
    recs = np.asarray(router(np.roll(ring_labels, -1, axis=0) - ring_labels))
    paths = []
    for t in range(k):
        src = ring_labels[t]
        rec = recs[t]
        path = []
        pos = src.copy()
        for dim in range(g.n):
            step = int(rec[dim])
            sgn = 1 if step >= 0 else -1
            for _ in range(abs(step)):
                port = 2 * dim + (0 if sgn > 0 else 1)
                path.append((int(g.label_to_index(pos)), port))
                pos = pos + sgn * np.eye(g.n, dtype=np.int64)[dim]
        paths.append(path)
    hops = [len(p) for p in paths]
    return RingSchedule(node_order=order, edge_paths=paths,
                        dilation=float(np.mean(hops)),
                        edge_costs=np.asarray(hops, dtype=np.int64))


def verify_contention_free(sched: RingSchedule) -> dict:
    """In a ring collective step every logical edge is active simultaneously;
    full bandwidth requires each directional physical link to appear in at
    most one logical edge's path.  On a weighted schedule the serialization
    unit is SERVICE slots, not crossings: a weight-w link needs w slots per
    packet, so `max_link_service` = max over links of use·w (equal to
    `max_link_use` on uniform fabrics)."""
    use: dict[tuple[int, int], int] = {}
    for path in sched.edge_paths:
        for link in path:
            use[link] = use.get(link, 0) + 1
    max_use = max(use.values()) if use else 0
    if sched.port_weights is not None:
        w = np.asarray(sched.port_weights)
        max_service = max((c * int(w[p]) for (_, p), c in use.items()),
                          default=0)
    else:
        max_service = max_use
    return {"contention_free": max_use <= 1, "max_link_use": max_use,
            "max_link_service": max_service,
            "links_used": len(use), "dilation": sched.dilation}


def effective_ring_bandwidth(sched: RingSchedule, link_bw: float = 50e9) -> float:
    """Per-step ring bandwidth after contention: the busiest link serializes
    (weight-aware — a weight-w link delivers link_bw/w, so the serialization
    denominator is the max per-link SERVICE load use·w)."""
    stats = verify_contention_free(sched)
    return link_bw / max(stats["max_link_service"], 1)


# ---------------------------------------------------------------------------
# explicit ppermute ring all-reduce (≡ psum)
# ---------------------------------------------------------------------------

def ppermute_ring_allreduce(x, axis_name: str, axis_size: int):
    """Bandwidth-optimal ring all-reduce via 2·(k−1) ppermute steps.

    Call inside shard_map.  x: any array whose leading dim is divisible by
    the ring size (the chunk dimension)."""
    k = axis_size
    if k == 1:
        return x
    chunks = jnp.stack(jnp.split(x, k, axis=0))       # (k, m/k, ...)
    perm = [(i, (i + 1) % k) for i in range(k)]
    rank = jax.lax.axis_index(axis_name)

    # reduce-scatter: after k-1 steps, chunk (rank+1) mod k is fully reduced
    def rs_step(t, buf):
        send_idx = (rank - t) % k
        piece = jnp.take(buf, send_idx, axis=0)
        received = jax.lax.ppermute(piece, axis_name, perm)
        recv_idx = (rank - t - 1) % k
        return buf.at[recv_idx].add(received)

    buf = jax.lax.fori_loop(0, k - 1, rs_step, chunks)

    # all-gather: circulate the reduced chunks
    def ag_step(t, buf):
        send_idx = (rank + 1 - t) % k
        piece = jnp.take(buf, send_idx, axis=0)
        received = jax.lax.ppermute(piece, axis_name, perm)
        recv_idx = (rank - t) % k
        return buf.at[recv_idx].set(received)

    buf = jax.lax.fori_loop(0, k - 1, ag_step, buf)
    return buf.reshape(x.shape)


def grad_ring_allreduce(grads, mesh, axis: str = "data"):
    """DP gradient all-reduce over one mesh axis using the explicit ring —
    a drop-in for psum when the collective must follow a known physical ring
    order (e.g. the `ring_schedule` embedding).  Call inside shard_map."""
    k = mesh.shape[axis]

    def one(g):
        flat = g.reshape(-1)
        pad = (-flat.shape[0]) % k
        if pad:
            flat = jnp.pad(flat, (0, pad))
        out = ppermute_ring_allreduce(flat, axis, k)
        return out[: g.size].reshape(g.shape)

    return jax.tree.map(one, grads)
