"""Phi-3.5-MoE (42B total / 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32_064,
    moe=MoEConfig(num_experts=16, top_k=2, num_shared_experts=0,
                  expert_d_ff=6400),
)
