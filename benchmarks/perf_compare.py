"""Hillclimb helper: compare dry-run artifacts for one cell across option
tags and print before/after roofline terms + top collective movers.

    PYTHONPATH=src python -m benchmarks.perf_compare --arch qwen3-4b --shape prefill_32k
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"


def terms(d: dict) -> dict:
    return {
        "compute_s": d["flops_per_device"] / PEAK_FLOPS,
        "memory_s": d["bytes_accessed_per_device"] / HBM_BW,
        "collective_s": d["collective"]["total_bytes"] / LINK_BW,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    files = sorted(
        ARTIFACTS.glob(f"{args.arch}__{args.shape}__{args.mesh}__*.json"),
        key=lambda f: f.stat().st_mtime)
    for f in files:
        d = json.loads(f.read_text())
        t = terms(d)
        opts = {k: v for k, v in d.get("options", {}).items()
                if v not in (True, "full", "chunked", 0, False)}
        print(f"\n== {f.name}")
        print(f"   options: {d.get('options')}")
        print(f"   compute={t['compute_s']:.4f}s  memory={t['memory_s']:.4f}s "
              f"collective={t['collective_s']:.4f}s  "
              f"coll_ops={d['collective']['total_count']}")
        for row in d.get("top_collectives", [])[:8]:
            print(f"     {row['op']:18} {row['shape']:32} "
                  f"{row['bytes']/1e9:9.2f} GB  ×{row['count']}")


if __name__ == "__main__":
    main()
