"""Network-simulator behaviour tests (paper §6.2 reproduction, small sizes)."""
import numpy as np
import pytest

from repro.core import BCC, FourD_BCC, Torus
from repro.core.simulation import (build_tables, pattern_table, simulate)


def test_low_load_accepted_equals_offered():
    g = BCC(2)
    r = simulate(g, "uniform", 0.1, slots=300, warmup=64, seed=1)
    assert abs(r.accepted_load - 0.1) < 0.03
    # latency near zero-load: ~avg distance × 16 cycles + queueing
    assert r.avg_latency_cycles < 16 * (g.average_distance + 3)


def test_no_deadlock_collapse_at_high_load():
    """Bubble flow control: accepted load must plateau, not collapse."""
    g = Torus(4, 4, 2)
    lo = simulate(g, "uniform", 0.4, slots=300, warmup=64, seed=2)
    hi = simulate(g, "uniform", 1.0, slots=300, warmup=64, seed=2)
    assert hi.accepted_load > 0.5 * lo.accepted_load
    assert hi.accepted_load > 0.2


def test_crystal_beats_torus_under_uniform():
    """The paper's headline: same-size crystal sustains more uniform load."""
    crystal = BCC(2)                       # 32 nodes
    torus = Torus(4, 4, 2)                 # 32 nodes
    pc = max(simulate(crystal, "uniform", l, slots=300, warmup=64, seed=3)
             .accepted_load for l in (0.6, 0.8, 1.0))
    pt = max(simulate(torus, "uniform", l, slots=300, warmup=64, seed=3)
             .accepted_load for l in (0.6, 0.8, 1.0))
    assert pc > pt


def test_pattern_tables():
    g = BCC(2)
    N = g.order
    for pattern in ("antipodal", "centralsymmetric", "randompairings"):
        dst = pattern_table(g, pattern, seed=0)
        assert dst.shape == (N,)
        assert (dst >= 0).all() and (dst < N).all()
    # randompairings is an involution
    dst = pattern_table(g, "randompairings", seed=0)
    assert np.array_equal(dst[dst], np.arange(N))
    # centralsymmetric maps origin to itself
    dst = pattern_table(g, "centralsymmetric", seed=0)
    assert dst[0] == 0


def test_alternate_records_are_minimal():
    """records_b = −route(−v) must be valid and minimal too."""
    g = FourD_BCC(2)
    t = build_tables(g)
    dist = g.distances_from_origin
    assert (np.abs(t.records_a).sum(1) == dist).all()
    assert (np.abs(t.records_b).sum(1) == dist).all()
    # validity: both records congruent to their delta
    idx_a = g.label_to_index(t.records_a)
    idx_b = g.label_to_index(t.records_b)
    assert (idx_a == np.arange(g.order)).all()
    assert (idx_b == np.arange(g.order)).all()


def test_deliveries_conserved():
    """Packets injected ≈ delivered + in flight (no loss, no duplication)."""
    g = BCC(2)
    r = simulate(g, "uniform", 0.2, slots=400, warmup=0, seed=5)
    in_flight_max = g.order * 6 * 4          # buffers upper bound
    assert 0 <= r.injected - r.delivered <= in_flight_max
