"""Per-packet latency oracle for the VC credit-flow router (ISSUE 8,
satellite 1 — the ROADMAP-flagged VC telemetry gap).

PR 6's `reference_latency_samples` oracle recorded every delivery's exact
age, but only for the V=1 single-FIFO router: `_make_ctx` rejected
`lat_trace` at `vcs >= 2`, so the VC router's histogram percentiles were
validated only against themselves.  This module closes the gap: the
vc_reference slot step now emits the same (slots, N, P) age/deliv trace
(one channel per port per slot — V lanes share the link, so at most one
delivery per (node, port) per slot, exactly the V=1 trace shape), and the
nearest-rank percentile accessors are validated CYCLE-EXACTLY against the
per-packet ages on the acceptance cells T(4,4,4,4) + RTT/FCC/BCC.
"""
import numpy as np
import pytest

from repro.core import BCC, FCC, RTT, LinkSpec, SimConfig, Torus
from repro.core.simulation import (PACKET_PHITS, reference_latency_samples,
                                   simulate)

_CELLS = {
    "T4444": Torus(4, 4, 4, 4),     # the acceptance 4-ary 4-cube
    "RTT4": RTT(4),
    "FCC2": FCC(2),
    "BCC2": BCC(2),
}
SLOTS, WARMUP = 96, 24


@pytest.mark.parametrize("cell", sorted(_CELLS))
def test_vc_percentiles_cycle_exact_vs_oracle(cell):
    """vcs=2 run: nearest-rank percentiles read off the bucketed histogram
    equal the oracle's per-packet ages EXACTLY (hist_bins exceeds any
    possible age, so no overflow truncation)."""
    g = _CELLS[cell]
    r, s = reference_latency_samples(g, "uniform", 0.3, slots=SLOTS,
                                     warmup=WARMUP, seed=0, vcs=2,
                                     hist_bins=SLOTS + 2)
    m = s["measured"]
    assert m.size == r.lat_count == int(r.latency_hist.sum())
    assert m.size > 0
    # the histogram is the exact bincount of the per-packet ages
    assert np.array_equal(
        np.asarray(r.latency_hist),
        np.bincount(m, minlength=SLOTS + 2))
    for q in (0.5, 0.99, 0.999):
        rank = min(m.size, max(1, int(np.ceil(q * m.size))))
        assert r.latency_percentile(q) == PACKET_PHITS * int(m[rank - 1]), \
            (cell, q)
    assert r.latency_p50 <= r.latency_p99 <= r.latency_p999
    assert np.isclose(r.avg_latency_cycles, PACKET_PHITS * m.mean())


def test_vc_oracle_describes_the_simulate_run():
    """The oracle uses `simulate(..., impl="reference", vcs=2)`'s exact
    key derivation: the standalone run's histogram and counters must
    match the oracle's bit for bit."""
    g = _CELLS["FCC2"]
    r, s = reference_latency_samples(g, "uniform", 0.35, slots=SLOTS,
                                     warmup=WARMUP, seed=0, vcs=2,
                                     hist_bins=32)
    r2 = simulate(g, "uniform", 0.35,
                  config=SimConfig(slots=SLOTS, warmup=WARMUP, seed=0,
                                   impl="reference", vcs=2, hist_bins=32))
    assert np.array_equal(np.asarray(r.latency_hist),
                          np.asarray(r2.latency_hist))
    assert (r.delivered, r.injected, r.lat_count) == \
        (r2.delivered, r2.injected, r2.lat_count)


def test_vc_oracle_credits_axis_threads_through():
    """A tighter credit window changes the run (credits gate the adaptive
    lanes' selection — under plain DOR they never bite) — the oracle
    accepts the credits axis and stays self-consistent on both runs."""
    from repro.core import Scenario
    g = _CELLS["BCC2"]
    adaptive = Scenario(policy="adaptive")
    r_full, s_full = reference_latency_samples(
        g, "uniform", 0.6, slots=SLOTS, warmup=0, seed=2, vcs=2,
        queue=6, scenario=adaptive, hist_bins=SLOTS + 2)
    r_tight, s_tight = reference_latency_samples(
        g, "uniform", 0.6, slots=SLOTS, warmup=0, seed=2, vcs=2,
        queue=6, credits=2, scenario=adaptive, hist_bins=SLOTS + 2)
    assert s_full["measured"].size == r_full.lat_count
    assert s_tight["measured"].size == r_tight.lat_count
    # both self-consistent; the runs themselves differ (the window bites)
    assert (r_full.delivered, r_full.lat_count) != \
        (r_tight.delivered, r_tight.lat_count)


def test_vc_oracle_composes_with_weighted_links():
    """vcs=2 × weighted LinkSpec: the oracle still reproduces the
    histogram exactly, and no measured age beats the weighted minimum
    (cheapest weighted pair cost + 1 injection slot)."""
    from repro.core import weighted_distance_matrix
    g = Torus(4, 4)
    ls = LinkSpec(dim_weights=(1, 3))
    r, s = reference_latency_samples(g, "uniform", 0.25, slots=SLOTS,
                                     warmup=WARMUP, seed=1, vcs=2,
                                     links=ls, hist_bins=SLOTS + 2)
    m = s["measured"]
    assert m.size == r.lat_count == int(r.latency_hist.sum())
    assert m.size > 0
    d = weighted_distance_matrix(g, ls)
    assert m.min() >= int(d[d > 0].min()) + 1
