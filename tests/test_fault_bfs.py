"""Device multi-source BFS vs the host per-destination BFS (ISSUE 4).

`routing.fault_aware_next_hop_device` must reproduce the host tables
EXACTLY — distances and first-live-port next hops — on the acceptance
topologies (T(4,4,4,4) + RTT/FCC/BCC) across fault classes, and the
K-scenario distance sweep must match per-scenario host statistics.
"""
import numpy as np
import pytest

from repro.core import (BCC, FCC, RTT, Scenario, Torus, channel_load_stats,
                        distance_stats, fault_aware_next_hop,
                        fault_aware_next_hop_device, faulted_distance_matrix,
                        faulted_distance_sweep)

GRAPHS = {"T4444": Torus(4, 4, 4, 4), "RTT4": RTT(4), "FCC2": FCC(2),
          "BCC2": BCC(2)}


def scenarios_for(g):
    return [Scenario(),                                        # pristine
            Scenario.random_link_faults(g, 3, seed=3),
            Scenario.random_node_faults(g, 2, seed=1),
            Scenario(dead_links=((0, 0), (0, 2)),
                     dead_nodes=(g.order // 2,))]              # mixed


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_device_tables_equal_host_tables(gname):
    g = GRAPHS[gname]
    for scen in scenarios_for(g):
        link_ok, node_ok = scen.link_ok(g), scen.node_ok(g)
        dh, nh = fault_aware_next_hop(g, link_ok, node_ok)
        dd, nd = fault_aware_next_hop_device(g, link_ok, node_ok)
        assert np.array_equal(dh, dd), (gname, scen.name)
        assert np.array_equal(nh, nd), (gname, scen.name)


def test_disconnecting_fault_marks_unreachable():
    """Cutting both links of a ring node isolates it: device and host
    agree on the -1 (unreachable) pattern."""
    ring = Torus(6)
    scen = Scenario(dead_links=((2, 0), (2, 1)))
    dh, nh = fault_aware_next_hop(ring, scen.link_ok(ring),
                                  scen.node_ok(ring))
    dd, nd = fault_aware_next_hop_device(ring, scen.link_ok(ring),
                                         scen.node_ok(ring))
    assert np.array_equal(dh, dd) and np.array_equal(nh, nd)
    assert dd[0, 2] == -1 and dd[2, 0] == -1 and (dd[2, 2] == 0)


def test_distance_matrix_backends_agree():
    g = Torus(4, 4, 4)
    scen = Scenario.random_link_faults(g, 4, seed=7)
    assert np.array_equal(faulted_distance_matrix(g, scen, backend="host"),
                          faulted_distance_matrix(g, scen, backend="device"))
    with pytest.raises(ValueError, match="unknown BFS backend"):
        faulted_distance_matrix(g, scen, backend="gpu")


def test_faulted_distance_sweep_matches_host_stats():
    g = Torus(4, 4, 4)
    scens = [Scenario.random_link_faults(g, k, seed=k) for k in (0, 2, 4, 6)]
    sw = faulted_distance_sweep(g, scens)
    for i, s in enumerate(scens):
        st = distance_stats(g, scenario=s, backend="host")
        assert np.isclose(sw["average_distance"][i],
                          st["average_distance"], atol=1e-5)
        assert sw["diameter"][i] == st["diameter"]
        assert sw["reachable_pairs"][i] == st["reachable_pairs"]


def test_sweep_disconnected_lane_reports_nan_not_zero():
    """A totally disconnected fault pattern must not score average
    distance 0.0 (which would rank the broken topology 'best'): the lane
    reports NaN + reachable_pairs=0 while healthy lanes stay finite."""
    ring = Torus(4)
    dead_all = Scenario(dead_links=tuple((u, 0) for u in range(4)))
    sw = faulted_distance_sweep(ring, [dead_all, Scenario()])
    assert np.isnan(sw["average_distance"][0])
    assert sw["reachable_pairs"][0] == 0
    assert np.isfinite(sw["average_distance"][1])
    assert sw["reachable_pairs"][1] == 4 * 3


def test_channel_load_walk_accepts_device_tables():
    """fault_aware_channel_load's walk runs on the device-built tables by
    default and still never steps onto a dead channel; host-backend loads
    are identical (identical tables ⇒ identical walk)."""
    g = Torus(4, 4)
    scen = Scenario.random_link_faults(g, 3, seed=5)
    ld = channel_load_stats(g, scenario=scen, pairs=2000, seed=1)["load"]
    lh = channel_load_stats(g, scenario=scen, pairs=2000, seed=1,
                            backend="host")["load"]
    assert np.array_equal(ld, lh)
    assert ld[~scen.link_ok(g)].sum() == 0
    with pytest.raises(ValueError, match="unknown analytic backend"):
        channel_load_stats(g, scenario=scen, pairs=100, backend="devcie")
