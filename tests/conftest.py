"""Test-session bootstrap.

1. Puts `src/` on sys.path so `python -m pytest` works from a clean clone
   without the `PYTHONPATH=src` incantation (pyproject.toml's
   `tool.pytest.ini_options.pythonpath` does the same on pytest ≥ 7; this
   is the belt to that suspender).
2. Installs the offline property-testing shim (`tests/_propcheck.py`) under
   the module names `hypothesis` / `hypothesis.strategies` when the real
   package is not importable, so the property-test modules collect and run
   in network-less environments.  When hypothesis *is* installed it is used
   unchanged.
"""
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.abspath(os.path.join(_HERE, os.pardir, "src"))
for p in (_SRC, _HERE):
    if p not in sys.path:
        sys.path.insert(0, p)

import _propcheck  # noqa: E402  (needs _HERE on sys.path)

PROPCHECK_ACTIVE = _propcheck.install()
