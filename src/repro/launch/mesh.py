"""Production mesh construction + the lattice-topology view of each pod.

`make_production_mesh` is a FUNCTION (not module-level state) so importing
this module never touches jax device state.  The physical interconnect of
each pod is modelled as a cubic crystal lattice graph from the paper:
256 chips = BCC(4), 512 = PC(8), 1024 = FCC(8) — the §3.4 power-of-two
upgrade path, which is also our elastic-scaling story.
"""
from __future__ import annotations

from repro.parallel import _compat

_compat.install()     # jax<0.5: publish shard_map/AxisType/make_mesh shims


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat.make_mesh(
        shape, axes,
        axis_types=(_compat.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many real/forced devices exist."""
    return _compat.make_mesh(
        shape, axes, axis_types=(_compat.AxisType.Auto,) * len(axes))


def pod_lattice(num_chips: int):
    """The cubic crystal lattice graph modelling one pod's ICI network."""
    from repro.core import crystal_for_order
    return crystal_for_order(num_chips)


def mesh_summary(mesh) -> str:
    return f"mesh{dict(mesh.shape)} devices={mesh.devices.size}"
