"""Quickstart: the paper's lattice graphs in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (BCC, FCC, PC, LatticeGraph, Torus, boxplus,
                        bcc_matrix, crystal_for_order, norm1, pc_matrix,
                        route_bcc, summarize, HierarchicalRouter)

# --- the three cubic crystal networks (paper §3) ---
for name, g in [("PC(4) = 4-ary 3-cube", PC(4)),
                ("FCC(4) ≅ PDTT(4)", FCC(4)),
                ("BCC(4)  (new in the paper)", BCC(4)),
                ("T(8,8,4) mixed torus", Torus(8, 8, 4))]:
    print(summarize(name, g).row())

# --- minimal routing (paper §5, Algorithm 4) ---
g = BCC(4)
src, dst = g.labels[17], g.labels[200]
r = route_bcc(4, dst - src)
print(f"\nroute {src} → {dst}: record {r} ({norm1(r)} hops, "
      f"BFS distance {g.distance(src, dst)})")

# --- hybrid graphs via the common lift ⊞ (paper §4.2) ---
M = boxplus(pc_matrix(4), bcc_matrix(2))
h = LatticeGraph(M)
print(f"\nPC(4) ⊞ BCC(2): dim={h.n}, N={h.order}, diameter={h.diameter}")
router = HierarchicalRouter(M)   # Algorithm 1 works on any lattice graph
v = h.labels[123]
print(f"hierarchical route 0 → {v}: {router(v)} (= BFS {h.distance(v*0, v)})")

# --- TPU pods on the upgrade path (paper §3.4 → DESIGN.md §2) ---
print("\npod upgrade path:", [f"{crystal_for_order(n).order}" for n in (256, 512, 1024, 2048)])
