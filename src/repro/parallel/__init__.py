from . import sharding
from .sharding import (activation_rules, constrain, make_activation_rules,
                       make_param_specs, named_tree)
