"""Dense SwiGLU MLP and sort-based mixture-of-experts.

The MoE dispatch is capacity-based with a sort/gather formulation so the
compiled FLOPs reflect the *active* expert compute (E·C·D·F), not a dense
one-hot einsum — this is what makes the MODEL_FLOPS / HLO_FLOPs roofline
ratio meaningful for the MoE architectures.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import cast_compute, dense_init


class MLPParams(NamedTuple):
    w_gate: jax.Array   # (D, F)
    w_up: jax.Array     # (D, F)
    w_down: jax.Array   # (F, D)


def init_mlp(key, d_model: int, d_ff: int) -> MLPParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return MLPParams(
        w_gate=dense_init(k1, d_model, d_ff),
        w_up=dense_init(k2, d_model, d_ff),
        w_down=dense_init(k3, d_ff, d_model))


def mlp(p: MLPParams, x):
    h = jax.nn.silu(x @ cast_compute(p.w_gate)) * (x @ cast_compute(p.w_up))
    return h @ cast_compute(p.w_down)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

class MoEParams(NamedTuple):
    router: jax.Array        # (D, E)
    w_gate: jax.Array        # (E, D, Fe)
    w_up: jax.Array          # (E, D, Fe)
    w_down: jax.Array        # (E, Fe, D)
    shared: MLPParams | None  # shared experts folded into one wider MLP


def init_moe(key, cfg) -> MoEParams:
    mc = cfg.moe
    d = cfg.d_model
    fe = mc.expert_d_ff or cfg.d_ff
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    E = mc.num_experts
    scale = 1.0 / jnp.sqrt(d)
    shared = None
    if mc.num_shared_experts:
        shared = init_mlp(ks, d, fe * mc.num_shared_experts)
    return MoEParams(
        router=dense_init(kr, d, E, scale=0.02),
        w_gate=jax.random.normal(kg, (E, d, fe), jnp.float32) * scale,
        w_up=jax.random.normal(ku, (E, d, fe), jnp.float32) * scale,
        w_down=jax.random.normal(kd, (E, fe, d), jnp.float32) / jnp.sqrt(fe),
        shared=shared)


def moe_capacity(cfg, num_tokens: int) -> int:
    mc = cfg.moe
    cap = int(mc.capacity_factor * num_tokens * mc.top_k / mc.num_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8


def moe(p: MoEParams, cfg, x):
    """Mixture-of-experts block.  Uses the explicit expert-parallel shard_map
    path when a mesh with a >1 'model' axis is in scope (production), else
    the single-device local path (tests, smoke configs)."""
    from repro.parallel.sharding import current_mesh
    mesh = current_mesh()
    if mesh is not None and mesh.shape.get("model", 1) > 1 \
            and cfg.moe.num_experts % mesh.shape["model"] == 0:
        return moe_sharded(p, cfg, x, mesh)
    return moe_local(p, cfg, x)


def _route(p: MoEParams, cfg, xt):
    """Router: top-k gates + Switch-style aux loss.  xt: (T, D)."""
    mc = cfg.moe
    T, E, K = xt.shape[0], mc.num_experts, mc.top_k
    logits = (xt @ cast_compute(p.router)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0 / (T * K))
    aux = E * jnp.sum(me * ce) * mc.router_aux_loss_coef
    return gate_vals, expert_ids, aux


def _dispatch_indices(expert_ids, K: int, C: int):
    """Sort dispatched copies by expert; rank within expert; capacity mask.
    Returns (sorted_expert, token_of, pos_in_expert, keep) each (T·K,)."""
    TK = expert_ids.size
    flat_expert = expert_ids.reshape(-1)
    sort_idx = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[sort_idx]
    pos_in_expert = jnp.arange(TK) - jnp.searchsorted(
        sorted_expert, sorted_expert, side="left")
    token_of = sort_idx // K
    keep = pos_in_expert < C
    return sorted_expert, token_of, pos_in_expert, keep, sort_idx


def _expert_ffn(xe, wg, wu, wd):
    """(E, C, D) × per-expert SwiGLU → (E, C, D)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, cast_compute(wg)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, cast_compute(wu))
    return jnp.einsum("ecf,efd->ecd", h, cast_compute(wd))


def moe_sharded(p: MoEParams, cfg, x, mesh):
    """Expert-parallel MoE via shard_map.

    Activations are replicated over the 'model' axis (standard TP layout), so
    dispatch is COMM-FREE: each model-rank scatters only the token copies
    bound for its own E/tp experts.  The only collectives are the FSDP
    all-gather of the expert weights (over 'data') and one psum of the
    combined output (over 'model') — exactly the EP traffic a production
    system pays.  Overflow beyond per-rank capacity drops (GShard)."""
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import current_fsdp_axis, current_rules

    mc = cfg.moe
    B, S_, D = x.shape
    tp = mesh.shape["model"]
    E, K = mc.num_experts, mc.top_k
    E_loc = E // tp
    fsdp_axis = current_fsdp_axis()
    rules = current_rules() or {}
    batch_axes = rules.get("hidden", P(None))[0]  # how x's batch is sharded
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fe = mc.expert_d_ff or cfg.d_ff
    fsdp_on = (fsdp_axis is not None and D % mesh.shape.get(fsdp_axis, 1) == 0
               and mesh.shape.get(fsdp_axis, 1) > 1)
    w_spec = P("model", fsdp_axis if fsdp_on else None, None)

    # local token count per device (batch may be unsharded)
    def _sz(axes):
        n = 1
        if axes is None:
            return 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            n *= mesh.shape[a]
        return n
    T_loc = (B // _sz(batch_axes)) * S_
    C_loc = moe_capacity(cfg, T_loc)

    def local(xl, router, wg, wu, wd):
        rank = jax.lax.axis_index("model")
        if fsdp_on:
            wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_axis, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_axis, axis=2, tiled=True)
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        xt = xl.reshape(T, D)
        gate_vals, expert_ids, aux = _route(
            MoEParams(router, None, None, None, None), cfg, xt)
        sorted_expert, token_of, pos_in_expert, keep, sort_idx = \
            _dispatch_indices(expert_ids, K, C_loc)
        # copies bound for MY experts only
        mine = (sorted_expert >= rank * E_loc) & \
               (sorted_expert < (rank + 1) * E_loc) & keep
        slot = jnp.where(
            mine, (sorted_expert - rank * E_loc) * C_loc + pos_in_expert,
            E_loc * C_loc - 1)
        src = jnp.where(mine[:, None], xt[token_of], jnp.zeros((), xt.dtype))
        xe = jnp.zeros((E_loc * C_loc, D), xt.dtype).at[slot].add(src)
        ye = _expert_ffn(xe.reshape(E_loc, C_loc, D), wg, wu, wd)
        contrib = ye.reshape(E_loc * C_loc, D)
        gathered = jnp.where(mine[:, None], contrib[slot],
                             jnp.zeros((), xt.dtype))
        gates_sorted = gate_vals.reshape(-1)[sort_idx]
        yt = jnp.zeros((T, D), xt.dtype).at[token_of].add(
            gathered * gates_sorted[:, None].astype(xt.dtype))
        yt = jax.lax.psum(yt, "model")          # combine across expert ranks
        # aux is identical on every model rank; gate it to rank 0 before the
        # psum so reverse-mode doesn't over-count its router cotangent tp×
        aux = jax.lax.psum(jnp.where(rank == 0, aux, 0.0), "model")
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)
        return yt.reshape(Bl, Sl, D), aux

    from repro.parallel._compat import shard_map
    all_axes = tuple(mesh.axis_names)
    x_spec = P(batch_axes, None, None)
    y, aux = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec,
                  P("model", None, fsdp_axis if fsdp_on else None)),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p.router, p.w_gate, p.w_up, p.w_down)
    if p.shared is not None:
        y = y + mlp(p.shared, x)
    return y, aux


def moe_local(p: MoEParams, cfg, x):
    """x: (B, S, D) → (y, aux_loss).

    Sort-based dispatch: tokens are ordered by expert id, sliced into
    (E, C, D) with capacity C, processed by a batched per-expert SwiGLU, and
    combined back with the router weights.  Overflow tokens beyond capacity
    are dropped (standard GShard semantics)."""
    mc = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = mc.num_experts, mc.top_k
    C = moe_capacity(cfg, T)

    xt = x.reshape(T, D)
    logits = (xt @ cast_compute(p.router)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)              # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                  # renormalise

    # --- aux load-balancing loss (Switch-style) ---
    me = probs.mean(axis=0)                                      # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (T * K))
    aux = E * jnp.sum(me * ce) * mc.router_aux_loss_coef

    # --- dispatch: rank tokens within their expert ---
    flat_expert = expert_ids.reshape(-1)                         # (T*K,)
    sort_idx = jnp.argsort(flat_expert, stable=True)             # group by expert
    sorted_expert = flat_expert[sort_idx]
    # position of each dispatched copy within its expert group
    pos_in_expert = jnp.arange(T * K) - jnp.searchsorted(
        sorted_expert, sorted_expert, side="left")
    token_of = sort_idx // K                                     # source token
    keep = pos_in_expert < C
    # overflow copies are folded onto the last slot with a zero contribution
    slot = jnp.where(keep, sorted_expert * C + pos_in_expert, E * C - 1)

    from repro.parallel.sharding import constrain
    src = jnp.where(keep[:, None], xt[token_of], jnp.zeros((), x.dtype))
    xe = jnp.zeros((E * C, D), x.dtype).at[slot].add(src)
    xe = constrain(xe.reshape(E, C, D), "expert_tokens")

    # --- per-expert SwiGLU (batched einsum over E) ---
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, cast_compute(p.w_gate)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, cast_compute(p.w_up))
    ye = jnp.einsum("ecf,efd->ecd", h, cast_compute(p.w_down))   # (E, C, D)
    ye = constrain(ye, "expert_tokens")

    # --- combine: gather back and weight by gate ---
    gates_sorted = gate_vals.reshape(-1)[sort_idx]
    contrib = ye.reshape(E * C, D)
    gathered = jnp.where(keep[:, None], contrib[slot], jnp.zeros((), x.dtype))
    yt = jnp.zeros((T, D), x.dtype).at[token_of].add(
        gathered * gates_sorted[:, None].astype(x.dtype))

    if p.shared is not None:
        yt = yt + mlp(p.shared, xt)
    return yt.reshape(B, S, D), aux
