"""Routing tests (paper §5): validity + minimality against the BFS oracle.

A routing record r for difference v must satisfy r ≡ v (mod M) (validity)
and |r|₁ = d_G(0, v) (minimality, Theorem 29)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (BCC, FCC, RTT, HierarchicalRouter, LatticeGraph,
                        bcc_matrix, boxplus, fcc_matrix, fourd_bcc_matrix,
                        fourd_fcc_matrix, lip_matrix,
                        minimal_record_bruteforce, norm1, pc_matrix,
                        route_bcc, route_fcc, route_ring, route_rtt,
                        route_torus, rtt_matrix, torus_matrix)

RNG = np.random.default_rng(7)


def assert_router_exact(g: LatticeGraph, router, trials=1500):
    labels = g.labels
    s = labels[RNG.integers(0, g.order, trials)]
    d = labels[RNG.integers(0, g.order, trials)]
    v = d - s
    r = np.asarray(router(v))
    assert (g.label_to_index(r) == g.label_to_index(v)).all(), "invalid record"
    dist = g.distances_from_origin[g.label_to_index(v)]
    assert (norm1(r) == dist).all(), "non-minimal record"


# ---------------------------------------------------------------------------
# closed-form routers (Algorithms 2, 3, 4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("a", [1, 2, 3, 4, 5, 8])
def test_algorithm3_rtt(a):
    assert_router_exact(RTT(a), lambda v: route_rtt(a, v))


@pytest.mark.parametrize("a", [2, 3, 4, 5])
def test_algorithm2_fcc(a):
    assert_router_exact(FCC(a), lambda v: route_fcc(a, v))


@pytest.mark.parametrize("a", [2, 3, 4, 5])
def test_algorithm4_bcc(a):
    assert_router_exact(BCC(a), lambda v: route_bcc(a, v))


def test_paper_example_32():
    vs = np.array([1, 3, 3])
    vd = np.array([6, 0, 1])
    r = route_fcc(4, vd - vs)
    assert np.array_equal(r, [1, 1, -2])
    assert norm1(r) == 4


# ---------------------------------------------------------------------------
# Algorithm 1 (hierarchical) on the whole zoo
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M", [
    rtt_matrix(4), fcc_matrix(3), bcc_matrix(3), pc_matrix(4),
    fourd_fcc_matrix(3), fourd_bcc_matrix(2), lip_matrix(2),
    boxplus(pc_matrix(4), bcc_matrix(2)),
    boxplus(bcc_matrix(2), fcc_matrix(2)),
    torus_matrix(6, 4, 2),
    np.array([[4, 0, 0], [0, 4, 2], [0, 0, 4]]),   # Example 10
], ids=["RTT4", "FCC3", "BCC3", "PC4", "4DFCC3", "4DBCC2", "Lip2",
        "PCboxBCC", "BCCboxFCC", "T642", "Ex10"])
def test_hierarchical_router_minimal(M):
    g = LatticeGraph(M)
    assert_router_exact(g, HierarchicalRouter(M), trials=1200)


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

@given(st.integers(2, 10), st.integers(-40, 40))
@settings(max_examples=80, deadline=None)
def test_ring_routing_minimal(a, d):
    r = int(route_ring(a, d))
    assert (d - r) % a == 0
    assert abs(r) == min(d % a, a - d % a)


@given(st.integers(1, 6),
       st.integers(-60, 60), st.integers(-60, 60))
@settings(max_examples=60, deadline=None)
def test_rtt_routing_valid_any_difference(a, x, y):
    """Algorithm 3 must return a valid record for ANY integer difference,
    not only those inside L − L."""
    v = np.array([x, y])
    r = route_rtt(a, v)
    g = RTT(a)
    assert g.label_to_index(r) == g.label_to_index(v)
    assert norm1(r) == g.distances_from_origin[g.label_to_index(v)]


@given(st.integers(2, 4), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_fcc_bcc_routing_random_pairs(a, seed):
    rng = np.random.default_rng(seed)
    for ctor, router in ((FCC, route_fcc), (BCC, route_bcc)):
        g = ctor(a)
        s = g.labels[rng.integers(0, g.order)]
        d = g.labels[rng.integers(0, g.order)]
        r = router(a, d - s)
        assert g.label_to_index(r) == g.label_to_index(d - s)
        assert norm1(r) == g.distance(s, d)


@given(st.integers(2, 3), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_hierarchical_equals_bruteforce(a, seed):
    M = fourd_fcc_matrix(a)
    g = LatticeGraph(M)
    router = HierarchicalRouter(M)
    rng = np.random.default_rng(seed)
    v = g.labels[rng.integers(0, g.order)] - g.labels[rng.integers(0, g.order)]
    r = router(v)
    rb = minimal_record_bruteforce(M, v, box=3)
    assert norm1(r) == norm1(rb)


# ---------------------------------------------------------------------------
# Remark 33 structure: number of nested calls
# ---------------------------------------------------------------------------

def test_remark33_cycle_intersections():
    """ord(e_n)/a = 2 sub-calls for FCC and BCC lifts (paper §5.2)."""
    for a in (2, 3, 4):
        hr = HierarchicalRouter(fcc_matrix(a))
        assert hr.copy_table.shape == (a, 2)
        hr = HierarchicalRouter(bcc_matrix(a))
        assert hr.copy_table.shape == (a, 2)


def test_torus_routing_separable():
    sides = (5, 4, 3)
    g = LatticeGraph(torus_matrix(*sides))
    v = np.array([[4, -3, 2], [0, 1, -1], [2, 2, 2]])
    r = route_torus(sides, v)
    assert (norm1(r) == g.distances_from_origin[g.label_to_index(v)]).all()
