"""Version compatibility for the Pallas TPU API.

`pltpu.TPUCompilerParams` was renamed to `pltpu.CompilerParams` across JAX
releases; resolve whichever this environment provides so the kernels import
on both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
