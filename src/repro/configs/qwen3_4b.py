"""Qwen3-4B [hf:Qwen/Qwen3-4B]: qk-norm, GQA kv=8, head_dim=128."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=9728,
    vocab_size=151_936,
    head_dim=128,       # Qwen3 decouples head_dim from d_model/num_heads
    qk_norm=True,
    rope_theta=1_000_000.0,
)
