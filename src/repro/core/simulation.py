"""Cycle-level interconnection-network simulator (paper §6.2), JAX-vectorised.

Reproduces the INSEE experiments comparing 4D-FCC(8) vs T(16,8,8,8) and
4D-BCC(4) vs T(8,8,8,4) under uniform / antipodal / central-symmetric /
random-pairings traffic.

Router model (simplifications vs INSEE noted in DESIGN.md §10):
  * packet = 16 phits; a link moves one packet per 16-cycle slot
    (virtual cut-through at packet granularity),
  * per-input-port queues of `queue` packets (paper Table 3: 4),
  * DOR over minimal routing records (Algorithms 1–4) with random
    tie-breaking between the two equal-norm records r and −route(−v)
    (Remark 30),
  * bubble flow control: entering a dimension ring (injection or turn)
    requires 2 free slots in the target queue, continuing in-dimension
    requires 1 — the paper's deadlock-avoidance rule,
  * output-link arbitration with a per-slot rotating queue-slot priority;
    in-transit traffic beats injection (the BlueGene congestion-control
    behaviour noted in §6.2).

Three implementations of the slot update share the state layout:

  * ``impl="batched"`` (default) — all per-link quantities (winners,
    records-after-hop, delivery flags, bubble requirements) are computed
    in one vectorised pass over all 2n ports, with no Python loop over
    ports and no scatters; the per-(node, out-port) winner is a segmented
    min over N·2nQ encoded priority keys (segment id = node·2n +
    requested port — realized as 2n fused masked column-mins, so no
    (N, 2nQ, 2n) candidate tensor is ever materialized); only the
    same-slot space-reuse fixed point (a packet moving into a slot
    vacated in this very slot) runs as a cheap `lax.scan` over the 2n
    port levels on an (N, 2n) carry, reproducing the reference sweep's
    acceptance exactly.  A whole run is one `lax.scan` over slots, and a
    whole load curve is one vmapped device program (`simulate_sweep`).
  * ``impl="fused"`` — the same slot update as a Pallas kernel
    (`repro.kernels.sim_step`): winner segmented-min, acceptance fixed
    point and the one-hot clears/transit/injection writes fused into ONE
    kernel pass over VMEM node tiles.  Off-TPU it runs in interpret mode
    (this container is CPU-only; TPU is the target) and is bitwise-equal
    to ``batched`` given the same pre-drawn traffic.  Real-TPU lowering
    is still unvalidated — see the caveat in `kernels/sim_step.py`.
  * ``impl="reference"`` — the pre-batching per-port Python loop, kept as
    the semantic oracle: tests validate both other implementations
    statistically against it (same load curves within stochastic
    tolerance), and `benchmarks/sim_throughput.py` measures the speedup.

Scenario fault masks are TRACED inputs of the compiled batched/fused
programs (the pristine scenario keeps its own static specialization, so
baselines stay bitwise-identical): K fault patterns of one structure
(policy × dead-node-ness) share a single trace/compile, and
`simulate_scenario_sweep` vmaps the whole scenario axis through one
device program (see docs/simulator.md).

Arbitration detail: the reference breaks queue-slot contention for an
output link with i.i.d. uniform scores drawn inside the slot update; the
batched pass pre-draws 8-bit seeded priorities for the whole run in one
bulk threefry call and resolves priority collisions with a per-slot
rotating (hence unbiased) tie-break — statistically equivalent, one
min-reduction per slot.  Both keep every *semantic* randomness source —
Bernoulli injection, uniform destinations, and the Remark-30 record
coin.

**Transient faults.**  A `repro.core.fault_schedule.FaultSchedule`
(ordered fault/repair events) threads a TIME axis through the same
mask machinery: the schedule compiles to per-epoch mask stacks ``(E, …)``
plus a slot→epoch map, all of which ride in the state as traced inputs —
the batched and fused paths gather the current epoch's masks inside the
existing `lax.scan` carry (one dynamic index per slot; no per-epoch
retrace, and the pristine path keeps its static specialization), while
the reference oracle bakes the stacks and stays the per-slot semantic
authority.  Timeline semantics (tests/test_transient_sim.py):

  * packets enqueued at a node that dies are DROPPED that slot and
    counted, so ``delivered + in_flight + dropped == injected`` holds at
    *every* slot (with warmup=0), not just at run end — scheduled runs
    emit a per-slot `SimTimeline` asserting exactly that;
  * injection at currently-dead sources is masked per-epoch, and fixed
    patterns drop packets aimed at a currently-dead destination;
  * adaptive/escape re-consult `routing_engine.policy_ports` against the
    current epoch's masks every slot (a carried port can go stale when
    the world changes under a waiting packet); DOR ports are
    liveness-independent and keep the carried-port fast path;
  * a degenerate single-epoch schedule (E = 1) is BITWISE-equal to the
    static `Scenario` run — the whole static engine is the E = 1 special
    case of the timeline engine.

`simulate_schedule_sweep(g, pattern, schedules, loads, seeds)` runs K
timelines × loads × seeds through ONE compiled program (schedules pad
their epoch stacks to a common E; the slot→epoch maps are per-lane
traced inputs, so padding is free).

Throughput is reported in phits/cycle/node = packets/slot/node.

**Latency telemetry.**  Every delivery knows its packet's birth slot, so
latency statistics are *measured-window* statistics: a delivery counts
toward the latency mean (and, with ``hist_bins > 0``, the bucketed
histogram) only when the packet was BORN at or after `warmup` — packets
born during warmup carry queue-buildup ages that are not steady-state
samples (pre-PR-6 they silently inflated the mean).  `lat_cnt` tracks
how many deliveries were measured; with zero measured deliveries the
mean is NaN, never 0.0.  ``hist_bins=B`` threads a fixed-width ``(B,)``
age histogram through the scan carry of all three implementations
(bucket ``i < B-1`` = deliveries aged exactly ``i`` slots; bucket
``B-1`` = overflow, ages ``>= B-1``), accumulated with one
`segment_sum` per slot — no per-packet host transfer, no shape change
across loads, and bitwise-zero effect on every pre-existing counter.
`SimResult.latency_percentile` / `latency_p50/p99/p999` recover EXACT
nearest-rank percentiles from the histogram (validated cycle-exactly
against the per-packet `reference_latency_samples` oracle whenever no
mass reaches the overflow bucket); `SweepStats` pools seed histograms
into percentile-vs-load curves, and scheduled runs carry a per-slot
cumulative histogram in `SimTimeline` from which
`SimTimeline.recovery_slots` measures slots-until-p99-returns-to-
baseline after a repair event (see docs/simulator.md).

**Scenario engine.**  Both implementations accept a `repro.core.scenario.
Scenario` (dead links, dead nodes, routing policy ∈ {dor, adaptive,
escape}).  Faults and policies enter the compiled slot update purely as
masks and tables — a `link_ok` (N, 2n) mask excludes dead channels from
arbitration, dead nodes are masked out of injection and destination
sampling, and the per-packet output port comes from
`routing_engine.policy_ports` — so a scenario run is still ONE device
program and `simulate_sweep` can vmap it over loads AND seeds.  The
trivial scenario (no faults, DOR) takes the exact pre-scenario code
paths, so baseline results stay bitwise-identical.  Invariants (enforced
by tests/test_scenarios.py): no packet ever crosses a dead channel
(`SimResult.link_use` audits every crossing), and — with warmup=0, so
every slot is counted — `delivered + in_flight + dropped == injected`
exactly (a packet is *dropped* only at injection, when a fixed pattern
targets a dead node; with a warmup, packets injected before measurement
starts are excluded from the counters but still occupy queue slots).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .fault_schedule import CompiledSchedule, FaultSchedule, ensure_compiled
from .lattice import LatticeGraph
from .link_spec import LinkSpec
from .routing import make_router
from .routing_engine import canonical_reduce, credit_vc_select, policy_ports
from .scenario import Scenario
from .sim_config import SimConfig, validate_feature_combo

PACKET_PHITS = 16


# ---------------------------------------------------------------------------
# static tables
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SimTables:
    n: int
    N: int
    neighbors: np.ndarray        # (N, 2n) — col 2i: +e_i, 2i+1: −e_i
    records_a: np.ndarray        # (N, n) minimal record per delta index
    records_b: np.ndarray        # (N, n) alternate minimal record (= −route(−v))
    labels: np.ndarray           # (N, n)
    hermite: np.ndarray          # (n, n)
    strides: np.ndarray          # (n,)


def build_tables(g: LatticeGraph, seed: int = 0,
                 backend: str = "auto") -> SimTables:
    """All-pairs record tables via the batched routing engine (the numpy
    oracle remains available with backend='numpy')."""
    router = make_router(g.matrix, backend)
    labels = g.labels
    rec_a = np.asarray(router(labels))
    # −route(−v) is also minimal for v and picks the *other* option on every
    # direction tie (half-ring hops, twin cycle intersections) — per-packet
    # coin between the two implements Remark 30's randomized tie-breaking.
    rec_b = -router(-labels)
    return SimTables(
        n=g.n, N=g.order, neighbors=g.neighbor_indices.astype(np.int32),
        records_a=rec_a.astype(np.int32), records_b=rec_b.astype(np.int32),
        labels=labels.astype(np.int32),
        hermite=g.hermite.astype(np.int32),
        strides=g.strides.astype(np.int32))


def _delta_idx(labels_src, labels_dst, hermite, strides):
    """Vectorised canonical reduction of (dst − src) into a node index."""
    v = canonical_reduce(labels_dst - labels_src, hermite)
    return (v * strides).sum(axis=-1)


# ---------------------------------------------------------------------------
# traffic patterns
# ---------------------------------------------------------------------------

def pattern_table(g: LatticeGraph, pattern: str, seed: int = 0) -> np.ndarray | None:
    """Fixed destination table (N,) for deterministic patterns; None for
    uniform (destination sampled per packet)."""
    N = g.order
    if pattern == "uniform":
        return None
    if pattern == "antipodal":
        d = g.distances_from_origin
        far = g.labels[int(np.argmax(d))]
        dst = g.label_to_index(g.labels + far)
        return dst.astype(np.int32)
    if pattern == "centralsymmetric":
        dst = g.label_to_index(-g.labels)
        return dst.astype(np.int32)
    if pattern == "randompairings":
        rng = np.random.default_rng(seed)
        perm = rng.permutation(N)
        dst = np.empty(N, dtype=np.int32)
        dst[perm[0::2]] = perm[1::2]
        dst[perm[1::2]] = perm[0::2]
        return dst
    raise ValueError(pattern)


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------

def _hist_percentile(hist: np.ndarray, q: float) -> float:
    """EXACT nearest-rank percentile of a (B,) latency histogram, in
    CYCLES (bucket i = latency of exactly i slots = 16·i cycles for
    i < B−1).  NaN with no mass; +inf when the rank lands in the
    overflow bucket B−1 (the true value is only lower-bounded there —
    pick `hist_bins` above the worst age for exact tails)."""
    hist = np.asarray(hist)
    total = int(hist.sum())
    if total == 0:
        return float("nan")
    if not (0.0 < q <= 1.0):
        raise ValueError(f"percentile q must be in (0, 1], got {q}")
    rank = min(total, max(1, int(np.ceil(q * total))))
    idx = int(np.searchsorted(np.cumsum(hist), rank, side="left"))
    if idx >= hist.size - 1:
        return float("inf")
    return float(PACKET_PHITS * idx)


def _bucket_counts(age, meas, bins: int):
    """(B,) bucketed delivery counts of one slot: clip ages into the
    fixed-width buckets and reduce the measured-delivery mask through a
    one-hot matvec (ages of unmeasured lanes are clipped garbage with
    weight 0).  Deliberately NOT `segment_sum`: XLA CPU serializes its
    scatter-add lowering — a dense (NP, B) dot is ~3× cheaper per slot
    at bench shapes (same trick as the segmented-min arbitration
    rewrite).  The dot packs TWO buckets per int32 column (bucket 2c in
    the low half-word, 2c+1 in the high), halving the one-hot
    intermediate — another ~2×.  A per-slot per-bucket count is at most
    the N·P lane count, so 16-bit halves cannot overflow while
    N·P ≤ 65535; beyond that (or for odd `bins`) fall back to the plain
    one-column-per-bucket dot."""
    b = jnp.clip(age.astype(jnp.int32), 0, bins - 1).ravel()
    m = meas.astype(jnp.int32).ravel()
    if bins % 2 or b.size > 0xFFFF:
        onehot = (b[:, None] == jnp.arange(bins, dtype=jnp.int32)[None, :]
                  ).astype(jnp.int32)
        return m @ onehot
    cols = jnp.arange(bins // 2, dtype=jnp.int32)
    packed = jnp.where((b[:, None] >> 1) == cols[None, :],
                       jnp.int32(1) << (16 * (b[:, None] & 1)), 0)
    # unpack via uint32: the high half-word may set bit 31 (count 2^15)
    r = (m @ packed).astype(jnp.uint32)
    lo = (r & 0xFFFF).astype(jnp.int32)
    hi = (r >> 16).astype(jnp.int32)
    return jnp.stack([lo, hi], axis=1).ravel()


@dataclass(frozen=True)
class SimTimeline:
    """Per-slot counter trace of a scheduled (transient-fault) run: each
    array has shape (slots,) — cumulative counted totals AFTER each slot,
    plus the instantaneous queue occupancy and the per-slot count of
    dead-channel crossings (an exact audit: always zero).  With warmup=0
    conservation holds at EVERY slot, not just at run end.

    With ``hist_bins > 0`` the trace also carries `lat_hist`, the
    CUMULATIVE (slots, B) latency histogram after each slot — windowed
    differences of it give per-slot tail-latency estimates without any
    per-packet storage (`latency_percentile_trace`, `recovery_slots`)."""

    delivered: np.ndarray
    injected: np.ndarray
    dropped: np.ndarray
    in_flight: np.ndarray
    dead_crossings: np.ndarray
    lat_hist: np.ndarray | None = None

    def conservation_violations(self) -> np.ndarray:
        """Slots where delivered + in_flight + dropped != injected."""
        return np.flatnonzero(
            self.injected != self.delivered + self.dropped + self.in_flight)

    def conservation_ok(self) -> bool:
        return self.conservation_violations().size == 0

    # -- tail-latency telemetry (hist_bins runs only) -----------------------
    def _require_hist(self):
        if self.lat_hist is None:
            raise ValueError(
                "timeline has no latency histogram — run with hist_bins>0")

    def latency_window_hist(self, end_slot: int, window: int) -> np.ndarray:
        """(B,) histogram of deliveries measured in the `window` slots
        ending AT `end_slot` (inclusive) — a cumulative difference."""
        self._require_hist()
        if end_slot < 0:
            return np.zeros(self.lat_hist.shape[1], self.lat_hist.dtype)
        hi = self.lat_hist[end_slot]
        if end_slot - window >= 0:
            return hi - self.lat_hist[end_slot - window]
        return hi.copy()

    def latency_percentile_trace(self, q: float = 0.99,
                                 window: int = 64) -> np.ndarray:
        """(slots,) windowed nearest-rank percentile (cycles) after each
        slot — NaN where the window saw no measured delivery."""
        self._require_hist()
        return np.array([
            _hist_percentile(self.latency_window_hist(s, window), q)
            for s in range(self.lat_hist.shape[0])])

    def recovery_slots(self, fault_slot: int, repair_slot: int, *,
                       q: float = 0.99, window: int = 64,
                       slack_cycles: float = 0.0) -> int | None:
        """Slots from the repair event until the windowed percentile-q
        latency first returns to its pre-fault baseline (the same-width
        window ending just before `fault_slot`), or None if it never
        does within the run.  `slack_cycles` loosens the baseline for
        stochastic traffic (windows are finite samples)."""
        self._require_hist()
        if not 0 < fault_slot <= repair_slot < self.lat_hist.shape[0]:
            raise ValueError(
                f"need 0 < fault_slot <= repair_slot < slots, got "
                f"fault={fault_slot} repair={repair_slot} "
                f"slots={self.lat_hist.shape[0]}")
        base = _hist_percentile(
            self.latency_window_hist(fault_slot - 1, window), q)
        if np.isnan(base):
            raise ValueError(
                "no measured deliveries in the pre-fault window — widen "
                "`window` or shorten the warmup")
        for s in range(repair_slot, self.lat_hist.shape[0]):
            p = _hist_percentile(self.latency_window_hist(s, window), q)
            if not np.isnan(p) and p <= base + slack_cycles:
                return s - repair_slot
        return None


@dataclass(frozen=True)
class SimResult:
    accepted_load: float      # phits / cycle / node
    avg_latency_cycles: float  # NaN when lat_count == 0 (no measured pkt)
    delivered: int
    injected: int
    slots: int
    dropped: int = 0          # refused at injection (dead destination)
    in_flight: int = 0        # occupied queue slots at run end
    # deliveries the latency stats measured: born AND delivered at or
    # after warmup (== delivered when warmup=0; the mean and histogram
    # are taken over exactly these packets)
    lat_count: int = 0
    # (hist_bins,) age histogram of the measured deliveries — bucket i
    # counts latency of exactly i slots (i < B−1), bucket B−1 overflows;
    # None unless the run asked for hist_bins > 0
    latency_hist: np.ndarray | None = field(default=None, compare=False)
    # (N, 2n) per-channel packet crossings, counted over ALL slots; only
    # tracked for non-trivial scenarios (the dead-link audit)
    link_use: np.ndarray | None = field(default=None, compare=False)
    # per-slot counter trace, only emitted by FaultSchedule runs
    timeline: SimTimeline | None = field(default=None, compare=False)
    # per-VC telemetry of the credit-flow router (vcs > 1 runs only):
    # (V,) deliveries attributed to the winner's SOURCE lane, (V,)
    # injections by the lane the packet was admitted into, and (V,)
    # occupied queue slots at run end.  Packets may switch lanes at each
    # hop, so only the V-SUMS obey conservation:
    # sum(vc_injected) == injected, sum(vc_delivered) == delivered,
    # sum(vc_in_flight) == in_flight.  None for vcs=1.
    vc_delivered: np.ndarray | None = field(default=None, compare=False)
    vc_injected: np.ndarray | None = field(default=None, compare=False)
    vc_in_flight: np.ndarray | None = field(default=None, compare=False)

    def latency_percentile(self, q: float) -> float:
        """EXACT nearest-rank percentile-q latency in cycles from the
        bucketed histogram (requires a hist_bins>0 run); NaN with no
        measured delivery, +inf if the rank overflows the last bucket."""
        if self.latency_hist is None:
            raise ValueError(
                "result has no latency histogram — run with hist_bins>0")
        return _hist_percentile(self.latency_hist, q)

    @property
    def latency_p50(self) -> float:
        return self.latency_percentile(0.50)

    @property
    def latency_p99(self) -> float:
        return self.latency_percentile(0.99)

    @property
    def latency_p999(self) -> float:
        return self.latency_percentile(0.999)


_RUNNER_CACHE: dict = {}


def _next_port(rec):
    """DOR: first nonzero dimension of the record → output port."""
    nz = jnp.abs(rec) > 0
    dim = jnp.argmax(nz, axis=-1)
    sgn = jnp.take_along_axis(rec, dim[..., None], -1)[..., 0]
    return 2 * dim + (sgn < 0), dim, sgn


def _next_port_ext(rec, pdim, psgn, pspan):
    """Greedy weighted DOR over an express-extended port set: among the
    ports of the record's first nonzero dimension whose sign matches and
    whose span FITS the remaining offset (no overshoot — the minimal-
    record invariant survives), take the largest span.  With no express
    entries this selects exactly `_next_port`'s 2·dim + (sgn<0)."""
    nz = jnp.abs(rec) > 0
    dim = jnp.argmax(nz, axis=-1)
    val = jnp.take_along_axis(rec, dim[..., None], -1)[..., 0]
    val = val.astype(jnp.int32)
    ok = ((pdim == dim[..., None]) & (psgn * val[..., None] > 0)
          & (pspan <= jnp.abs(val)[..., None]))
    return jnp.argmax(jnp.where(ok, pspan, -1), axis=-1)


def _next_port_ext_ok(rec, pdim, psgn, pspan, link_ok):
    """`_next_port_ext` under faults: among the fitting ports of the
    record's first nonzero dimension, prefer the largest-span LIVE one —
    live beats span, so a dead express hop degrades onto the base span-1
    port (which always fits) instead of wedging the packet.  Only a dead
    BASE channel leaves the packet requesting a dead port, where it
    blocks in place exactly like DOR through a fault.  `link_ok`
    broadcasts to ``rec.shape[:-1] + (P,)``; with all-live masks this
    selects exactly `_next_port_ext`."""
    nz = jnp.abs(rec) > 0
    dim = jnp.argmax(nz, axis=-1)
    val = jnp.take_along_axis(rec, dim[..., None], -1)[..., 0]
    val = val.astype(jnp.int32)
    ok = ((pdim == dim[..., None]) & (psgn * val[..., None] > 0)
          & (pspan <= jnp.abs(val)[..., None]))
    lok = jnp.broadcast_to(link_ok, ok.shape)
    key = jnp.where(ok, lok.astype(jnp.int32) * 4096 + pspan, -1)
    return jnp.argmax(key, axis=-1)


def _inject(state, key, new_dst, new_rec, new_birth, ctx, masks=None):
    """Reference injection stage (per-slot PRNG draws + scatter writes,
    bitwise-stable vs the pre-batching simulator for trivial scenarios).
    Runs after transit so in-flight traffic has priority; entering a ring
    costs 2 free slots (bubble rule).  Under a non-trivial scenario dead
    sources never want, destinations are sampled over live nodes, packets
    of fixed patterns aimed at a dead node are *dropped*, and the
    injection port follows the scenario policy.  `masks` overrides the
    scenario mask entries with the CURRENT EPOCH's slices when the run
    follows a `FaultSchedule` (the reference path resolves the epoch once
    per slot and hands the static-shaped masks down here)."""
    N, P = ctx["N"], ctx["P"]
    m = ctx if masks is None else {**ctx, **masks}
    fixed_dst = ctx["fixed_dst"]
    trivial = ctx["trivial"]
    labels, hermite, strides = ctx["labels"], ctx["hermite"], ctx["strides"]
    rec_a, rec_b = ctx["rec_a"], ctx["rec_b"]
    slot = state["slot"]
    k1, k2, k3 = jax.random.split(jax.random.fold_in(key, 2), 3)
    want_new = jax.random.uniform(k1, (N,)) < state["load"]
    if not trivial:
        want_new = want_new & m["inj_ok"]
    want = want_new | (state["backlog"] > 0)
    if fixed_dst:
        d = state["dst_table"]
    elif not trivial and ctx["has_dead_nodes"]:
        # uniform over *live* destinations (self-draws carry di == 0 and
        # simply back-log, exactly like a fixed self-pattern)
        d = m["live_tbl"][jax.random.randint(k2, (N,), 0, m["n_live"])]
    else:
        d = jax.random.randint(k2, (N,), 0, N - 1)
        d = jnp.where(d >= jnp.arange(N), d + 1, d)
    di = _delta_idx(labels, labels[d], hermite, strides)
    coin = jax.random.uniform(k3, (N,)) < 0.5
    r = jnp.where(coin[:, None], rec_a[di], rec_b[di])
    if trivial:
        if ctx.get("express"):
            inj_port = _next_port_ext(r, ctx["pdim"], ctx["psgn"],
                                      ctx["pspan"])
        else:
            inj_port, _, _ = _next_port(r[:, None, :])
            inj_port = inj_port[:, 0]
        drop = None
        ipc = inj_port
    else:
        if ctx.get("express"):
            # greedy weighted DOR over the extended ports, liveness-aware
            # (express at V=1 is dor-only; see validate_feature_combo)
            inj_port = _next_port_ext_ok(r, ctx["pdim"], ctx["psgn"],
                                         ctx["pspan"], m["link_ok"])
        else:
            inj_port = policy_ports(r, m["link_ok"], ctx["policy"])
        drop = want & ~m["dst_ok"][d]
        ipc = jnp.minimum(inj_port, P - 1)        # clamp the P sentinel
    freeq = jnp.take_along_axis(
        (new_dst < 0).sum(axis=2), ipc[:, None], axis=1)[:, 0]
    can = want & (freeq >= 2) & (jnp.abs(r).sum(-1) > 0)
    if not trivial:
        can = can & ~drop & (inj_port < P)
    r_ = jnp.arange(N)
    r = r.astype(new_rec.dtype)
    slot_idx = jnp.argmax(new_dst[r_, ipc] < 0, axis=1)
    new_dst = new_dst.at[r_, ipc, slot_idx].set(
        jnp.where(can, d, new_dst[r_, ipc, slot_idx]))
    new_rec = new_rec.at[r_, ipc, slot_idx].set(
        jnp.where(can[:, None], r, new_rec[r_, ipc, slot_idx]))
    new_birth = new_birth.at[r_, ipc, slot_idx].set(
        jnp.where(can, slot, new_birth[r_, ipc, slot_idx]))
    backlog = state["backlog"] + want_new - can
    if drop is not None:
        backlog = backlog - drop
    backlog = jnp.clip(backlog, 0, 1 << 30)
    return new_dst, new_rec, new_birth, backlog, can, drop


def _make_traffic(ctx, state, key, slots: int):
    """Pre-draw the whole run's injection randomness in a handful of large
    batched PRNG calls (per-slot threefry + routing-table lookups inside
    the scan cost ~45% of a run): per (slot, node) a uniform injection
    draw and the Remark-30 record coin, plus — for uniform traffic — the
    destination as a *delta index* drawn directly (dst uniform over the
    N−1 other nodes ⟺ delta uniform over the nonzero canonical labels),
    reduced to the record and its first DOR port via the `rec_ab` /
    `port_ab` tables.

    Under a `FaultSchedule` (ctx["scheduled"]) the mask state entries
    carry a leading epoch axis and `state["slot2epoch"]` maps each slot
    to its epoch: live-destination sampling and non-DOR injection ports
    gather the CURRENT epoch's masks per slot.  With E = 1 every gather
    reproduces the static values bitwise."""
    N, P, Q = ctx["N"], ctx["P"], ctx["Q"]
    V = ctx.get("V", 1)
    scheduled = ctx.get("scheduled", False)
    ku, kd, kc, kp = jax.random.split(jax.random.fold_in(key, 2), 4)
    u = jax.random.uniform(ku, (slots, N))
    coin = (jax.random.uniform(kc, (slots, N)) < 0.5).astype(jnp.int32)
    if ctx["fixed_dst"]:
        # read from the state so one compiled runner serves every fixed
        # pattern on this topology (the cache key only carries fixed-ness)
        di = state["di_fixed"][None, :]                    # (1, N), broadcast
    elif not ctx["trivial"] and ctx["has_dead_nodes"]:
        # uniform over *live* destinations: draw the node, reduce the
        # delta on device (self-draws carry di == 0 and back-log).  The
        # live table is a traced state input padded to N entries; the
        # traced n_live bound keeps the draw exactly uniform over them.
        if scheduled:
            s2e = state["slot2epoch"]
            lt = state["live_tbl"][s2e]                    # (slots, N)
            idx = jax.random.randint(
                kd, (slots, N), 0, state["n_live"][s2e][:, None])
            dstn = jnp.take_along_axis(lt, idx, axis=1)
        else:
            dstn = state["live_tbl"][
                jax.random.randint(kd, (slots, N), 0, state["n_live"])]
        di = _delta_idx(ctx["labels"][None, :, :], ctx["labels"][dstn],
                        ctx["hermite"], ctx["strides"])
    else:
        di = jax.random.randint(kd, (slots, N), 1, N)
    r = ctx["rec_ab"][di, coin]                            # (slots, N, n)
    if V > 1 or ctx["trivial"] or ctx["policy"] == "dor":
        # DOR ignores liveness, so the precomputed port table stays valid.
        # The VC router also takes this branch for EVERY policy: its
        # injection (port, VC) choice depends on the per-slot credit
        # counters, so it is recomputed inside the scan
        # (`credit_vc_select`) and tr["p"] only seeds the DOR fallback.
        p = ctx["port_ab"][di, coin]
    elif scheduled:
        p = policy_ports(r, state["link_ok"][state["slot2epoch"]],
                         ctx["policy"]).astype(jnp.int8)
    else:
        p = policy_ports(r, state["link_ok"][None, :, :],
                         ctx["policy"]).astype(jnp.int8)
    return dict(
        u=u,
        r=r,
        p=p,
        v=jnp.broadcast_to(di != 0, (slots, N)),
        # arbitration priorities for every queue slot of every slot time,
        # one bulk threefry draw (~5× cheaper than hashing in the scan);
        # the VC router draws per (port, VC, slot) — V=1 is the exact
        # pre-VC shape
        prio=jax.random.bits(kp, (slots, N, P * V * Q), jnp.uint8))


def _finish_slot(state, counted_from, delivered, lat_sum, lat_cnt, can,
                 drop=None, qdrop=None, **updates):
    slot = state["slot"]
    counted = slot >= counted_from
    # dropped packets count as injected so that conservation stays exact:
    # injected == delivered + in_flight + dropped.  Queue drops (packets
    # already in flight when their node dies, `qdrop`) were counted
    # injected at injection time, so they increment ONLY `dropped`.
    inj = can.sum() if drop is None else can.sum() + drop.sum()
    # lat_sum / lat_cnt arrive already filtered to measured deliveries
    # (birth >= warmup) — a packet born at or after warmup can only be
    # delivered at a counted slot, so no extra `counted` gate is needed
    # (and with warmup=0 the filter is the old behaviour bitwise)
    out = dict(
        state, **updates, slot=slot + 1,
        delivered=state["delivered"] + jnp.where(counted, delivered, 0),
        lat_sum=state["lat_sum"] + lat_sum,
        lat_cnt=state["lat_cnt"] + lat_cnt,
        injected=state["injected"] + jnp.where(counted, inj, 0))
    if drop is not None:
        d = drop.sum() if qdrop is None else drop.sum() + qdrop
        out["dropped"] = state["dropped"] + jnp.where(counted, d, 0)
    return out


def _make_slot_step_batched(ctx, warmup: int):
    """One simulated slot with NO Python loop over ports and NO scatters
    (XLA CPU serializes scatter updates; everything here is gathers,
    one-hot masks and small reductions):

      * winner per (node, out-port): a segmented min over the N·2nQ
        encoded priority keys (segment id = node·2n + requested port,
        realized as 2n fused masked column-mins — nothing bigger than
        O(N·2nQ) is ever materialized) — 8-bit seeded threefry
        priorities pre-drawn for the whole run (`_make_traffic`) plus a
        per-slot rotating tie-break, standing in for the reference's
        i.i.d. uniform arbitration scores,
      * link acceptance for all 2n ports at once; the same-slot space
        reuse fixed point runs as a `lax.scan` over port levels on a tiny
        (N, 2n) carry (exactly the reference sweep's acceptance),
      * queue updates through one-hot write masks (each in-queue receives
        at most one packet per slot, so masks never collide),
      * each packet's DOR output port is carried in the state and updated
        only when the packet moves, so no per-slot argmax over the full
        (N, 2n, Q, n) record tensor.

    Scenario faults and policies enter as masks/tables only: dead channels
    are excluded from the winner min-reduce (`link_ok` where-mask), the
    carried port comes from `policy_ports`, and dropped/audit counters are
    extra fused reductions — the trivial scenario compiles to the exact
    pre-scenario program.  The masks are TRACED inputs (they travel in the
    state, like `di_fixed`), so one compiled runner serves every fault
    pattern of the same structure (policy × dead-node-ness) and
    `simulate_scenario_sweep` can vmap a whole scenario axis through it.

    NOTE: `kernels.sim_step._slot_step_kernel` mirrors this update phase
    for phase and must stay bitwise-equal — change both together
    (tests/test_fused_impl.py enforces the parity in CI)."""
    n, N, P, Q = ctx["n"], ctx["N"], ctx["P"], ctx["Q"]
    nbr = ctx["nbr"]
    rec_dtype = ctx["rec_dtype"]
    trivial = ctx["trivial"]
    weighted = ctx.get("weighted", False)
    express = ctx.get("express", False)
    if weighted:
        wgt = ctx["wgt"]                           # (P,) int32 slot costs
    PQ = P * Q
    # arbitration key = prio(8 bit)·PQ + rot(<PQ): int16 fits exactly up
    # to PQ=127 (256·PQ − 1 < 0x7FFF); wider queues fall back to int32
    key_dtype = jnp.int16 if PQ <= 127 else jnp.int32
    BIG = key_dtype(np.iinfo(np.dtype(key_dtype)).max)
    ports = jnp.arange(P)
    opp = jnp.arange(P) ^ 1                        # paired ±e_i ports
    sender = nbr[:, opp]                           # (N, P): src of in-port p
    receiver = nbr                                 # (N, P): dst of out-port p
    if express:
        # overlay ports hop span·e_dim; the table already carries signs
        hop = ctx["hop_tab"].astype(rec_dtype)
    else:
        dim_p = ports // 2
        sgn_p = 1 - 2 * (ports % 2)
        # hop of out-port p subtracted from the record: sgn_p · e_{dim_p}
        hop = np.zeros((P, n), np.int64)
        hop[np.arange(P), np.asarray(dim_p)] = np.asarray(sgn_p)
        hop = jnp.asarray(hop, rec_dtype)
    pq32 = jnp.arange(PQ, dtype=jnp.int32)
    ports8 = jnp.arange(P, dtype=jnp.int8)
    NO_PORT = jnp.int8(P)

    def gather_port(per_port, fill, port_flat):
        """(N, P) per-out-port values → (N, PQ) per-slot values through each
        queue slot's requested port (sentinel port P reads `fill`)."""
        padded = jnp.concatenate(
            [per_port, jnp.full((N, 1), fill, per_port.dtype)], axis=1)
        return jnp.take_along_axis(padded, port_flat.astype(jnp.int32),
                                   axis=1)

    scheduled = ctx.get("scheduled", False)

    def slot_step(state, tr):
        # birth doubles as the occupancy marker (−1 = free slot): the
        # destination index itself is never consulted in transit — delivery
        # is decided by the record reaching zero — so the batched state
        # carries no dst array at all.
        rec, birth, port = state["rec"], state["birth"], state["port"]
        if scheduled:
            # resolve the current epoch INSIDE the scan carry: one dynamic
            # gather per (E, …) mask stack, no per-epoch retrace.  Packets
            # enqueued at a node that just died are dropped HERE (counted
            # into `dropped` below), so per-slot conservation holds; its
            # injection BACKLOG dies with it too (pending demand is not a
            # packet — clearing it keeps a dead node from injecting while
            # dead, and is a no-op at E=1 where dead nodes never backlog)
            e = tr["epoch"]
            link_ok = state["link_ok"][e]
            inj_ok_e = state["inj_ok"][e]
            deadq = (birth >= 0) & ~inj_ok_e[:, None, None]
            qdrop = deadq.sum()
            birth = jnp.where(deadq, -1, birth)
            backlog0 = jnp.where(inj_ok_e, state["backlog"], 0)
        else:
            link_ok = None if trivial else state["link_ok"]
            qdrop = None
            backlog0 = state["backlog"]
        slot = state["slot"]
        occ = birth >= 0                                   # (N, P, Q)
        if weighted:
            # a packet still paying a multi-slot crossing (wait > 0) sits
            # in its queue slot — occupying space and in_flight — but is
            # not yet eligible to request an output port
            busy, wait = state["busy"], state["wait"]
            elig = occ & (wait == 0)
        else:
            elig = occ
        if express and not trivial:
            # liveness-aware greedy weighted DOR: a carried express port
            # goes stale when its channel dies (and becomes preferable
            # again when it repairs) — re-consult against the current
            # masks every slot.  All-live masks reproduce the carried
            # port (same greedy argmax), keeping forced-mask/pristine
            # lanes equivalent.
            port = jnp.where(
                occ,
                _next_port_ext_ok(rec, ctx["pdim"], ctx["psgn"],
                                  ctx["pspan"],
                                  link_ok[:, None, None, :]
                                  ).astype(jnp.int8), NO_PORT)
        elif scheduled and ctx["policy"] != "dor":
            # adaptive/escape re-consult policy_ports against the CURRENT
            # epoch's masks: a carried port can go stale when the world
            # changes under a waiting packet.  With E = 1 the recompute is
            # the identity (the carried port was this very function of the
            # same rec/link_ok), keeping the static run bitwise-equal.
            port = jnp.where(
                occ,
                policy_ports(rec, link_ok[:, None, None, :],
                             ctx["policy"]).astype(jnp.int8), NO_PORT)
        else:
            port = jnp.where(occ, port, NO_PORT)
        if weighted:
            # the state-carried port survives the wait (the packet still
            # wants the same hop once eligible); only the ARBITRATION view
            # hides waiting packets
            port_flat = jnp.where(elig, port, NO_PORT).reshape(N, PQ)
        else:
            port_flat = port.reshape(N, PQ)

        # ---- winner per (node, out-port): segmented min over encoded keys --
        # segment id = node·2n + requested_port, key = prio·PQ + rot —
        # pre-drawn 8-bit threefry priorities (tr["prio"]) + a per-slot
        # rotating tie-break keep the key narrow; priority collisions land
        # on the rotating tie-break, so they carry no systematic
        # queue-slot bias.  The segmented reduction is realized as one
        # fused masked column-min per port bucket (2n static buckets)
        # rather than jax.ops.segment_min, whose scatter-min lowering XLA
        # CPU serializes (~17× slower at N=4096); either way every
        # per-slot intermediate stays O(N·2nQ) — the (N, 2nQ, 2n) one-hot
        # candidate tensor this replaces was the largest tensor of the
        # whole slot program.  Winners are bitwise-identical to the
        # one-hot min-reduce: same keys, same min, per segment
        # (tests/test_sim_memory.py pins the absence of the blowup).
        rot = (pq32[None, :] + jnp.int32(slot)) % PQ       # tie-break perm
        enc = tr["prio"].astype(key_dtype) * key_dtype(PQ) \
            + rot.astype(key_dtype)                        # (N, PQ) < BIG
        w_enc = jnp.stack(
            [jnp.min(jnp.where(port_flat == ports8[p], enc, BIG), axis=1)
             for p in range(P)], axis=1)                   # (N, P)
        if link_ok is not None:
            # a dead channel moves nothing: mask its winner away (packets
            # requesting it — DOR through a fault — block in place)
            w_enc = jnp.where(link_ok, w_enc, BIG)
        if weighted:
            # a weight-w channel stays held for w slots after a crossing:
            # mask it out of arbitration exactly like a dead link while
            # its busy countdown runs
            w_enc = jnp.where(busy == 0, w_enc, BIG)
        whas = w_enc < BIG
        widx = jnp.where(
            whas, (w_enc.astype(jnp.int32) % PQ - jnp.int32(slot)) % PQ, 0)
        w_srcq = widx // Q                                 # queue it occupies
        # a queue slot departs iff it IS its port's winner and the link moves
        is_winner = gather_port(w_enc, BIG, port_flat) == enc  # (N, PQ)

        flat_rec = rec.reshape(N, PQ, n)
        flat_birth = birth.reshape(N, PQ)

        # ---- per-link view at the receiver of in-port p ----
        # (gathers composed: winner fields are read once, directly through
        # the sender's winner index)
        in_has = whas[sender, ports]                       # (N, P)
        in_widx = widx[sender, ports]
        in_rec = flat_rec[sender, in_widx]                 # (N, P, n)
        in_birth = flat_birth[sender, in_widx]
        in_srcq = in_widx // Q
        rec_after = in_rec - hop[None]
        done = jnp.abs(rec_after.astype(jnp.int32)).sum(-1) == 0
        deliver = in_has & done
        turning = in_srcq != ports[None]                   # entering this ring
        need = jnp.where(turning, 2, 1)                    # bubble rule
        free0 = Q - occ.sum(axis=2)                        # (N, P) per queue

        # ---- acceptance: exact sequential-sweep fixed point ----
        # The reference resolves same-slot space reuse by sweeping ports in
        # index order: in-port p sees slots vacated by winners that left
        # through ports p' < p.  That recurrence needs only an (N, P)
        # carry — per-queue vacancy counts and acceptance flags — so the
        # heavy per-link quantities above stay one batched pass and the
        # fixed point itself is a cheap `lax.scan` over the 2n port levels
        # (bitwise-equal acceptance to the reference sweep given the same
        # winners).
        lvl_xs = dict(h=in_has.T, dn=done.T, f=free0.T, nd=need.T,
                      dl=deliver.T, rx=receiver.T, wq=w_srcq.T, wh=whas.T,
                      p=ports)

        def level(vac, x):
            acc_p = x["h"] & ~x["dn"] & (
                x["f"] + jnp.take(vac, x["p"], axis=1) >= x["nd"])
            # my port-p winner departs iff the packet moved at its receiver
            dep_w = (x["dl"] | acc_p)[x["rx"]] & x["wh"]
            vac = vac + jnp.where(
                dep_w[:, None] & (x["wq"][:, None] == ports[None, :]), 1, 0)
            return vac, acc_p

        _, accT = jax.lax.scan(level, jnp.zeros((N, P), jnp.int32), lvl_xs)
        acc = accT.T                                       # (N, P)
        moved = deliver | acc

        delivered = deliver.sum()
        # latency telemetry measures only packets BORN in the measured
        # window: warmup-era births carry queue-buildup ages that are not
        # steady-state samples (the PR-6 warmup-bias fix).  birth >= warmup
        # implies delivery slot > warmup, so these sums need no extra
        # counted gate.
        age = slot + 1 - in_birth                          # (N, P)
        if weighted:
            # delivery is counted at the win slot, but the packet still
            # pays the final crossing: its true arrival is wgt[p]−1
            # slots later (weight-1 adds 0 — identical arithmetic)
            age = age + (wgt - 1)[None, :]
        meas = deliver & (in_birth >= warmup)
        lat_sum = jnp.where(meas, age, 0).sum()
        lat_cnt = meas.sum()

        # ---- apply: clear departed slots + fused transit/injection write --
        # Transit fills the FIRST free slot of the in-queue, injection the
        # LAST free slot of its ring's queue; when both fire on the same
        # queue the bubble rule guarantees ≥3 free post-clear slots, so
        # the two one-hot masks never collide and every state array takes
        # a single fused where-chain.
        dep_port = moved[receiver, ports] & whas
        dep_slot = is_winner & gather_port(dep_port, False, port_flat)
        birth_cleared = jnp.where(dep_slot, -1, flat_birth).reshape(N, P, Q)
        free_mask = birth_cleared < 0
        qi = jnp.arange(Q)[None, None, :]
        slot_f = jnp.argmax(free_mask, axis=2)             # (N, P) first free
        slot_l = (Q - 1) - jnp.argmax(free_mask[:, :, ::-1], axis=2)
        wmask = acc[:, :, None] & (qi == slot_f[:, :, None])
        if express and not trivial:
            port_in = _next_port_ext_ok(rec_after, ctx["pdim"],
                                        ctx["psgn"], ctx["pspan"],
                                        link_ok[:, None, :])
        elif express:
            port_in = _next_port_ext(rec_after, ctx["pdim"], ctx["psgn"],
                                     ctx["pspan"])         # (N, P) next hop
        elif trivial:
            port_in, _, _ = _next_port(rec_after)          # (N, P) next hop
        else:
            port_in = policy_ports(rec_after, link_ok[:, None, :],
                                   ctx["policy"])

        # injection from pre-drawn traffic (after transit: in-flight
        # traffic has priority; entering a ring costs 2 free slots)
        want_new = tr["u"] < state["load"]
        if scheduled:
            want_new = want_new & inj_ok_e
        elif not trivial:
            want_new = want_new & state["inj_ok"]
        want = want_new | (backlog0 > 0)
        depcnt = dep_slot.reshape(N, P, Q).sum(axis=2)
        freeq_post = free0 + depcnt - acc                  # after transit
        inj_p = tr["p"]
        if express and not trivial:
            # the pre-drawn port table is liveness-ignorant; recompute
            # the greedy weighted-DOR port against the current masks so
            # a new packet never queues behind a dead express channel
            # while its base port is live
            inj_p = _next_port_ext_ok(tr["r"], ctx["pdim"], ctx["psgn"],
                                      ctx["pspan"],
                                      link_ok).astype(jnp.int8)
        inj_port = inj_p.astype(jnp.int32)
        if trivial:
            drop = None
            can = want & (jnp.take_along_axis(
                freeq_post, inj_port[:, None], axis=1)[:, 0] >= 2) & tr["v"]
        else:
            # the drop mask is pattern-specific, so — like di_fixed — it
            # lives in the STATE: the compiled runner stays shared across
            # fixed patterns (the cache key only carries fixed-ness)
            drop = want & ~(state["dst_live_fixed"][e] if scheduled
                            else state["dst_live_fixed"])
            ipc = jnp.minimum(inj_port, P - 1)             # clamp P sentinel
            can = (want & ~drop & (jnp.take_along_axis(
                freeq_post, ipc[:, None], axis=1)[:, 0] >= 2)
                & tr["v"] & (inj_port < P))
        imask = (can[:, None, None]
                 & (ports8[None, :, None] == inj_p[:, None, None])
                 & (qi == slot_l[:, :, None]))
        backlog = backlog0 + want_new - can
        if drop is not None:
            backlog = backlog - drop
        backlog = jnp.clip(backlog, 0, 1 << 30)

        new_rec = jnp.where(
            imask[..., None], tr["r"][:, None, None, :],
            jnp.where(wmask[..., None], rec_after[:, :, None, :], rec))
        new_birth = jnp.where(
            imask, slot.astype(birth.dtype),
            jnp.where(wmask, in_birth[:, :, None], birth_cleared))
        new_port = jnp.where(
            imask, inj_p[:, None, None],
            jnp.where(wmask, port_in[:, :, None].astype(jnp.int8), port))

        updates = dict(rec=new_rec, birth=new_birth, port=new_port,
                       backlog=backlog)
        if weighted:
            # countdown bookkeeping: a departed slot's wait clears with
            # it, an arriving packet starts at wgt[in-port]−1 (the write
            # masks never collide with injection, which starts at 0 —
            # crossing no link costs nothing), and the crossed channel's
            # busy restarts at wgt−1 (blocked for the w−1 FOLLOWING slots)
            wait_dec = jnp.where(dep_slot.reshape(N, P, Q), 0,
                                 jnp.maximum(wait - 1, 0))
            updates["wait"] = jnp.where(
                imask, 0,
                jnp.where(wmask, (wgt - 1)[None, :, None], wait_dec))
            updates["busy"] = jnp.where(dep_port, wgt[None, :] - 1,
                                        jnp.maximum(busy - 1, 0))
        if ctx["hist_bins"]:
            updates["lat_hist"] = state["lat_hist"] + _bucket_counts(
                age, meas, ctx["hist_bins"])
        if not trivial:
            # dead-channel audit: count every crossing (all slots, not just
            # measured ones — "never" means never)
            updates["link_use"] = state["link_use"] + dep_port.astype(jnp.int32)
        out = _finish_slot(state, warmup, delivered, lat_sum, lat_cnt, can,
                           drop, qdrop=qdrop, **updates)
        return out, (_timeline_y(out, new_birth, dep_port, link_ok)
                     if scheduled else None)

    return slot_step


def _timeline_y(out, occupancy, dep_port, link_ok):
    """One per-slot `SimTimeline` sample: post-slot cumulative counters,
    current queue occupancy, and the dead-channel-crossing audit (crossing
    a channel while it is dead is impossible by construction — arbitration
    masks it — so this is an exact always-zero regression tripwire)."""
    crossed = dep_port if dep_port.dtype == jnp.bool_ else dep_port != 0
    y = dict(delivered=out["delivered"], injected=out["injected"],
             dropped=out["dropped"],
             in_flight=(occupancy >= 0).sum(),
             dead_crossings=(crossed & ~link_ok).sum())
    if "lat_hist" in out:
        # cumulative post-slot histogram: windowed differences on the host
        # give per-slot tail-latency traces (SimTimeline.recovery_slots)
        y["lat_hist"] = out["lat_hist"]
    return y


def _make_slot_step_fused(ctx, warmup: int):
    """The batched slot update routed through the Pallas kernel
    (`repro.kernels.sim_step.fused_slot_step`): winner segmented-min +
    acceptance fixed point + one-hot clears/transit/injection writes run
    as ONE kernel pass over VMEM node tiles.  Same state layout and
    pre-drawn traffic as `_make_slot_step_batched`, and bitwise-equal
    results; off-TPU the kernel runs in interpret mode (validated by the
    differential suite at quick shapes).  Real-TPU lowering is untested
    in this CPU-only container — see the caveat in kernels/sim_step.py."""
    from ..kernels.ops import _on_tpu
    from ..kernels.sim_step import fused_slot_step
    N = ctx["N"]
    nbr = ctx["nbr"]
    trivial = ctx["trivial"]
    scheduled = ctx.get("scheduled", False)
    interpret = not _on_tpu()

    def slot_step(state, tr):
        slot = state["slot"]
        rec, birth, port = state["rec"], state["birth"], state["port"]
        if scheduled:
            # epoch resolution + dead-node queue kill + the stale-port
            # policy re-consult all happen HERE, in the scan carry — the
            # kernel itself stays epoch-oblivious (it sees one slot's
            # static-shaped masks) and bitwise-mirrors the batched step.
            e = tr["epoch"]
            link_ok = state["link_ok"][e]
            inj_ok_e = state["inj_ok"][e]
            dst_live = state["dst_live_fixed"][e]
            deadq = (birth >= 0) & ~inj_ok_e[:, None, None]
            qdrop = deadq.sum()
            birth = jnp.where(deadq, -1, birth)
            # a dead node's injection backlog dies with it (see batched)
            backlog0 = jnp.where(inj_ok_e, state["backlog"], 0)
            if ctx["policy"] != "dor":
                port = policy_ports(rec, link_ok[:, None, None, :],
                                    ctx["policy"]).astype(jnp.int8)
        else:
            link_ok = None if trivial else state["link_ok"]
            dst_live = None if trivial else state["dst_live_fixed"]
            qdrop = None
            backlog0 = state["backlog"]
        want_new = tr["u"] < state["load"]
        if scheduled:
            want_new = want_new & inj_ok_e
        elif not trivial:
            want_new = want_new & state["inj_ok"]
        want = want_new | (backlog0 > 0)
        (new_rec, new_birth, new_port, deliver, lat, can8, drop8,
         dep_port) = fused_slot_step(
            rec, birth, port, tr["prio"], slot,
            want, tr["r"], tr["p"], tr["v"], nbr,
            link_ok=link_ok,
            dst_live_fixed=dst_live,
            policy="dor" if trivial else ctx["policy"],
            interpret=interpret)
        can = can8 != 0
        drop = None if trivial else (drop8 != 0)
        backlog = backlog0 + want_new - can
        if drop is not None:
            backlog = backlog - drop
        backlog = jnp.clip(backlog, 0, 1 << 30)
        # the kernel's `lat` output is slot+1−birth where delivered (0
        # elsewhere), so birth = slot+1−lat: the measured-window filter and
        # histogram run OUTSIDE the kernel on its existing outputs — the
        # kernel body stays untouched and the batched bitwise-parity
        # contract is preserved counter for counter
        delivered_m = deliver != 0
        meas = delivered_m & (slot + 1 - lat >= warmup)
        lat_sum = jnp.where(meas, lat, 0).sum()
        lat_cnt = meas.sum()
        updates = dict(rec=new_rec, birth=new_birth, port=new_port,
                       backlog=backlog)
        if ctx["hist_bins"]:
            updates["lat_hist"] = state["lat_hist"] + _bucket_counts(
                lat, meas, ctx["hist_bins"])
        if not trivial:
            updates["link_use"] = state["link_use"] + dep_port.astype(jnp.int32)
        out = _finish_slot(state, warmup, delivered_m.sum(), lat_sum,
                           lat_cnt, can, drop, qdrop=qdrop, **updates)
        return out, (_timeline_y(out, new_birth, dep_port, link_ok)
                     if scheduled else None)

    return slot_step


def _make_slot_step_reference(ctx, warmup: int):
    """The pre-batching per-port sweep (semantic oracle for the batched
    implementation; random output-link arbitration, sequential same-slot
    space reuse in port order).  Under a `FaultSchedule` the per-epoch
    mask stacks stay BAKED constants (full-fingerprint cache key) and the
    step resolves the current epoch from the slot counter — the oracle
    defines the per-slot semantics the traced implementations must
    match."""
    n, N, P, Q = ctx["n"], ctx["N"], ctx["P"], ctx["Q"]
    nbr = ctx["nbr"]
    opp = [p ^ 1 for p in range(P)]
    trivial = ctx["trivial"]
    scheduled = ctx.get("scheduled", False)
    weighted = ctx.get("weighted", False)
    express = ctx.get("express", False)
    if express:
        dim_of = np.asarray(ctx["pdim"]).tolist()
        sgn_of = np.asarray(ctx["psgn"]).tolist()
        span_of = np.asarray(ctx["pspan"]).tolist()
    else:
        dim_of = [p // 2 for p in range(P)]
        sgn_of = [1 - 2 * (p % 2) for p in range(P)]
        span_of = [1] * P
    wgt_of = (np.asarray(ctx["wgt"]).tolist() if weighted else [1] * P)

    def slot_step(state, key):
        dst, rec, birth = state["dst"], state["rec"], state["birth"]
        slot = state["slot"]
        if scheduled:
            e = ctx["slot2epoch"][slot]
            link_ok = ctx["link_ok"][e]
            node_ok = ctx["inj_ok"][e]
            masks = dict(link_ok=link_ok, inj_ok=node_ok, dst_ok=node_ok,
                         live_tbl=ctx["live_tbl"][e],
                         n_live=ctx["n_live"][e])
            deadq = (dst >= 0) & ~node_ok[:, None, None]
            qdrop = deadq.sum()
            dst = jnp.where(deadq, -1, dst)
            # a dead node's injection backlog dies with it (see batched):
            # _inject reads the cleared value, so a dead source never
            # injects from stale demand while dead
            state = dict(state,
                         backlog=jnp.where(node_ok, state["backlog"], 0))
        else:
            link_ok = None if trivial else ctx["link_ok"]
            masks, qdrop = None, None
        occ = dst >= 0                                     # (N, P, Q)
        if express and not trivial:
            # liveness-aware greedy weighted DOR (see the batched step)
            port = _next_port_ext_ok(rec, ctx["pdim"], ctx["psgn"],
                                     ctx["pspan"],
                                     link_ok[:, None, None, :])
        elif express:
            port = _next_port_ext(rec, ctx["pdim"], ctx["psgn"],
                                  ctx["pspan"])             # (N, P, Q)
        elif trivial:
            port, _, _ = _next_port(rec)                   # (N, P, Q)
        else:
            port = policy_ports(rec, link_ok[:, None, None, :],
                                ctx["policy"])
        if weighted:
            # packets still paying a multi-slot crossing are ineligible
            busy, wait = state["busy"], state["wait"]
            port = jnp.where(occ & (wait == 0), port, -1)
        else:
            port = jnp.where(occ, port, -1)

        # ---- arbitration: one winner packet per (node, out-port) ----
        rand = jax.random.uniform(jax.random.fold_in(key, 1), (N, P, Q))
        requested = port[..., None] == jnp.arange(P)
        if not trivial:
            # dead channels never arbitrate: packets aimed at them block
            requested = requested & link_ok[:, None, None, :]
        if weighted:
            # a busy (multi-slot-held) channel moves nothing this slot
            requested = requested & (busy == 0)[:, None, None, :]
        flatscore = jnp.where(requested, rand[..., None], -1.0)
        flat = flatscore.reshape(N, P * Q, P)
        widx = jnp.argmax(flat, axis=1)                    # (N, P) flat pq index
        whas = jnp.take_along_axis(flat, widx[:, None, :], axis=1)[:, 0, :] >= 0.0

        flat_dst = dst.reshape(N, P * Q)
        flat_rec = rec.reshape(N, P * Q, n)
        flat_birth = birth.reshape(N, P * Q)
        rows = jnp.arange(N)[:, None]
        w_dst = flat_dst[rows, widx]                       # (N, P)
        w_rec = flat_rec[rows, widx]                       # (N, P, n)
        w_birth = flat_birth[rows, widx]
        w_src_port = widx // Q                             # (N, P)

        # ---- per-link acceptance (each in-queue receives ≤ 1 packet) ----
        delivered = jnp.int32(0)
        lat_sum = jnp.int32(0)
        lat_cnt = jnp.int32(0)
        dead_crossings = jnp.int32(0)
        age_l, meas_l, del_l = [], [], []
        new_dst, new_rec, new_birth = dst, rec, birth
        if weighted:
            # countdowns tick once per slot; crossings below re-arm them
            new_busy = jnp.maximum(busy - 1, 0)
            new_wait = jnp.maximum(wait - 1, 0)
        link_use = None if trivial else state["link_use"]
        for p in range(P):
            d_p = dim_of[p]
            s_p = sgn_of[p] * span_of[p]                   # signed hop span
            w_p = wgt_of[p]                                # slot cost
            u = nbr[:, opp[p]]                             # sender for recv w
            has = whas[u, p]
            pk_dst = w_dst[u, p]
            pk_rec = w_rec[u, p]
            pk_birth = w_birth[u, p]
            pk_src_port = w_src_port[u, p]
            rec_after = pk_rec.at[:, d_p].add(-s_p)
            done = jnp.abs(rec_after.astype(jnp.int32)).sum(-1) == 0
            will_deliver = has & done
            turning = pk_src_port != p                     # entering this ring
            freeq = (new_dst[:, p] < 0).sum(axis=1)
            ok = has & ~done & (freeq >= jnp.where(turning, 2, 1))
            moved = will_deliver | ok
            # stats — latency over measured deliveries only (birth >=
            # warmup, the PR-6 warmup-bias fix; identical to the batched
            # step's filter).  Weighted channels add their final-crossing
            # cost: delivery is counted at the win slot, arrival is w−1
            # slots later.
            age_p = slot + 1 - pk_birth
            if weighted:
                age_p = age_p + (w_p - 1)
            meas_p = will_deliver & (pk_birth >= warmup)
            delivered += will_deliver.sum()
            lat_sum += jnp.where(meas_p, age_p, 0).sum()
            lat_cnt += meas_p.sum()
            if ctx["hist_bins"] or ctx.get("lat_trace"):
                age_l.append(age_p)
                meas_l.append(meas_p)
                del_l.append(will_deliver)
            if scheduled:
                dead_crossings += (moved & ~link_ok[u, p]).sum()
            if link_use is not None:
                # crossing of channel (u, p); u ↔ receiver is a bijection,
                # so the scatter-add never collides
                link_use = link_use.at[u, p].add(moved.astype(jnp.int32))
            # clear winner slot at sender
            sel = widx[:, p]
            fd = new_dst.reshape(N, P * Q)
            fd = fd.at[u, sel[u]].set(jnp.where(moved, -1, fd[u, sel[u]]))
            new_dst = fd.reshape(N, P, Q)
            # write into receiver queue p (first free slot)
            slot_idx = jnp.argmax(new_dst[:, p] < 0, axis=1)
            r_ = jnp.arange(N)
            new_dst = new_dst.at[r_, p, slot_idx].set(
                jnp.where(ok, pk_dst, new_dst[r_, p, slot_idx]))
            new_rec = new_rec.at[r_, p, slot_idx].set(
                jnp.where(ok[:, None], rec_after, new_rec[r_, p, slot_idx]))
            new_birth = new_birth.at[r_, p, slot_idx].set(
                jnp.where(ok, pk_birth, new_birth[r_, p, slot_idx]))
            if weighted:
                # crossed channel (u, p) re-arms its hold; the accepted
                # packet starts its own eligibility countdown at w−1
                new_busy = new_busy.at[u, p].set(
                    jnp.where(moved, w_p - 1, new_busy[u, p]))
                new_wait = new_wait.at[r_, p, slot_idx].set(
                    jnp.where(ok, w_p - 1, new_wait[r_, p, slot_idx]))

        if weighted:
            # free slots carry no countdown: zero them so injection (which
            # crosses no link) always starts eligible
            new_wait = jnp.where(new_dst >= 0, new_wait, 0)
        new_dst, new_rec, new_birth, backlog, can, drop = _inject(
            state, key, new_dst, new_rec, new_birth, ctx, masks)
        updates = dict(dst=new_dst, rec=new_rec, birth=new_birth,
                       backlog=backlog)
        if weighted:
            updates["busy"] = new_busy
            updates["wait"] = new_wait
        if ctx["hist_bins"]:
            updates["lat_hist"] = state["lat_hist"] + _bucket_counts(
                jnp.stack(age_l, 1), jnp.stack(meas_l, 1),
                ctx["hist_bins"])
        if link_use is not None:
            updates["link_use"] = link_use
        out = _finish_slot(state, warmup, delivered, lat_sum, lat_cnt, can,
                           drop, qdrop=qdrop, **updates)
        y = None
        if scheduled:
            y = dict(delivered=out["delivered"], injected=out["injected"],
                     dropped=out["dropped"],
                     in_flight=(new_dst >= 0).sum(),
                     dead_crossings=dead_crossings)
            if ctx["hist_bins"]:
                y["lat_hist"] = out["lat_hist"]
        elif ctx.get("lat_trace"):
            # the per-packet oracle: every delivery's age + flags, per slot
            # (test-scale only — slots×N×P device→host traffic).  The meas
            # flag travels too: weighted ages carry the +w−1 final-crossing
            # term, so the host cannot reconstruct birth from slot+1−age.
            y = dict(age=jnp.stack(age_l, 1), deliv=jnp.stack(del_l, 1),
                     meas=jnp.stack(meas_l, 1))
        return out, y

    return slot_step


def _make_slot_step_vc_batched(ctx, warmup: int):
    """The credit-flow virtual-channel router (vcs > 1), vectorised with
    the same no-scatter discipline as `_make_slot_step_batched`:

      * state generalizes the per-port FIFO to (N, 2n, V, Q) lanes plus a
        carried (N, 2n, V) CREDIT array — `credit[w, p, v]` is the
        advertised free window of queue (w, p, v), initialized to
        `credits` (or Q) and kept exact incrementally (+1 per departure,
        −1 per acceptance/injection into the lane),
      * every occupied slot re-evaluates its (out-port, lane) request
        per slot via `routing_engine.credit_vc_select`: lanes 1..V−1 are
        credit-gated minimal-adaptive (max downstream credits, rotating
        tie-break), lane 0 is the restricted-DOR ESCAPE lane with bubble
        flow control — the Duato construction, so the router is
        deadlock-free by the escape-CDG acyclicity argument
        (tests/test_vc_router.py enumerates it).  No per-packet port is
        carried: the choice depends on the live credit state,
      * winner per (node, out-port) is the same segmented min, now over
        N·2nVQ encoded keys (lanes share the physical link — one packet
        per channel per slot),
      * acceptance needs: escape-lane entry (turn/injection) 2 free
        credits, in-lane continuation 1 (the bubble rule per lane-ring);
        adaptive lanes need 1 — their eligibility is already credit>0 at
        selection, and deadlock recovery is the escape lane's job.  Under
        policy "dor" every lane runs the bubble rule (no credit gate in
        selection), which keeps plain DOR deadlock-free per lane-ring.

    V=1 never reaches this builder — `_get_runner` dispatches to the
    pre-VC `_make_slot_step_batched`, keeping the vcs=1 program bitwise
    identical.  `FaultSchedule` timelines compose: the per-epoch mask
    stacks are gathered in the scan carry exactly like the V=1 step, a
    killed node's enqueued phits drop across all lanes with the freed
    credits restored in the same slot, and a degenerate E=1 schedule is
    bitwise-equal to the static `Scenario` run.  Express overlays extend
    the port axis (geometry flows through `credit_vc_select`'s
    port_geom); only the fused kernel stays V=1 (rejected in
    `SimConfig`)."""
    n, N, P, Q, V = ctx["n"], ctx["N"], ctx["P"], ctx["Q"], ctx["V"]
    nbr = ctx["nbr"]
    rec_dtype = ctx["rec_dtype"]
    trivial = ctx["trivial"]
    policy = ctx["policy"]
    adaptive = policy in ("adaptive", "escape")
    PV, PVQ = P * V, P * V * Q
    key_dtype = jnp.int16 if PVQ <= 127 else jnp.int32
    BIG = key_dtype(np.iinfo(np.dtype(key_dtype)).max)
    ports = jnp.arange(P)
    opp = jnp.arange(P) ^ 1
    sender = nbr[:, opp]                           # (N, P): src of in-port p
    receiver = nbr                                 # (N, P): dst of out-port p
    express = ctx.get("express", False)
    if express:
        # overlay ports hop span·e_dim; the table already carries signs,
        # and `credit_vc_select` scores the extended axis via port_geom
        hop = ctx["hop_tab"].astype(rec_dtype)
        port_geom = (ctx["pdim"], ctx["psgn"], ctx["pspan"])
    else:
        dim_p = ports // 2
        sgn_p = 1 - 2 * (ports % 2)
        hop = np.zeros((P, n), np.int64)
        hop[np.arange(P), np.asarray(dim_p)] = np.asarray(sgn_p)
        hop = jnp.asarray(hop, rec_dtype)
        port_geom = None
    # fault-aware escape: only the "escape" policy opts into the PR 3
    # misroute when VC0's DOR port is dead ("adaptive" keeps the packet
    # blocking, like V=1 DOR through a fault); inert on live ports, so
    # all-live masks select identically either way
    esc_fb = policy == "escape" and not trivial
    scheduled = ctx.get("scheduled", False)
    pvq32 = jnp.arange(PVQ, dtype=jnp.int32)
    qids = jnp.arange(PV, dtype=jnp.int32)
    varange = jnp.arange(V, dtype=jnp.int32)
    weighted = ctx.get("weighted", False)
    if weighted:
        wgt = ctx["wgt"]                    # (P,) int32 slot costs

    def gather_port(per_port, fill, port_flat):
        padded = jnp.concatenate(
            [per_port, jnp.full((N, 1), fill, per_port.dtype)], axis=1)
        return jnp.take_along_axis(padded, port_flat.astype(jnp.int32),
                                   axis=1)

    def take_q(arr_flat, qidx):
        """(N, PV) per-lane values gathered at a (N,) queue id each."""
        return jnp.take_along_axis(arr_flat, qidx[:, None], axis=1)[:, 0]

    def slot_step(state, tr):
        rec, birth, credit = state["rec"], state["birth"], state["credit"]
        slot = state["slot"]
        if scheduled:
            # resolve the current epoch INSIDE the scan carry (one gather
            # per mask stack, no per-epoch retrace).  A killed node's
            # enqueued phits drop across ALL lanes; the dropped occupancy
            # frees its queue space, so the lane's advertised credits are
            # restored HERE — `credit == credit_init − occupancy` holds
            # at every slot.  At E = 1 dead nodes never hold occupants
            # (their channels are dead and their injection is masked from
            # slot 0), so deadq ≡ False and the restore adds zero: the
            # static Scenario run stays bitwise-equal.
            e = tr["epoch"]
            link_ok = state["link_ok"][e]
            inj_ok_e = state["inj_ok"][e]
            deadq = (birth >= 0) & ~inj_ok_e[:, None, None, None]
            qdrop = deadq.sum()
            birth = jnp.where(deadq, -1, birth)
            credit = credit + deadq.sum(axis=3)
            backlog0 = jnp.where(inj_ok_e, state["backlog"], 0)
        else:
            link_ok = None if trivial else state["link_ok"]
            qdrop = None
            backlog0 = state["backlog"]
        occ = birth >= 0                                   # (N, P, V, Q)

        # ---- per-packet (out-port, lane) request, credit-aware ----
        # downstream credit view: what u sees for out-port p is the
        # credit of ITS OWN queue at the receiver, (nbr[u,p], p, ·)
        cd = credit[nbr, ports[None, :]]                   # (N, P, V)
        lok = (jnp.ones((N, P), bool) if trivial else link_ok)
        sel_port, sel_vc = credit_vc_select(
            rec, lok[:, None, None, None, :],
            cd[:, None, None, None, :, :], policy, rot=slot,
            port_geom=port_geom, escape_fallback=esc_fb)
        if weighted:
            # multi-slot crossings: waiting packets are ineligible
            busy, wait = state["busy"], state["wait"]
            sel_port = jnp.where(occ & (wait == 0), sel_port, P)
        else:
            sel_port = jnp.where(occ, sel_port, P)         # sentinel if free
        port_flat = sel_port.reshape(N, PVQ)
        vc_flat = sel_vc.reshape(N, PVQ)

        # ---- winner per (node, out-port): segmented min over lanes ----
        rot = (pvq32[None, :] + jnp.int32(slot)) % PVQ
        enc = tr["prio"].astype(key_dtype) * key_dtype(PVQ) \
            + rot.astype(key_dtype)                        # (N, PVQ)
        w_enc = jnp.stack(
            [jnp.min(jnp.where(port_flat == p, enc, BIG), axis=1)
             for p in range(P)], axis=1)                   # (N, P)
        if link_ok is not None:
            w_enc = jnp.where(link_ok, w_enc, BIG)
        if weighted:
            # a held (busy) physical channel arbitrates nothing this slot
            w_enc = jnp.where(busy == 0, w_enc, BIG)
        whas = w_enc < BIG
        widx = jnp.where(
            whas, (w_enc.astype(jnp.int32) % PVQ - jnp.int32(slot)) % PVQ,
            0)
        w_srcq = widx // Q                                 # queue id p·V+v
        is_winner = gather_port(w_enc, BIG, port_flat) == enc

        flat_rec = rec.reshape(N, PVQ, n)
        flat_birth = birth.reshape(N, PVQ)
        rows = jnp.arange(N)[:, None]
        w_vc = jnp.take_along_axis(vc_flat, widx, axis=1)  # target lane

        # ---- per-link view at the receiver of in-port p ----
        in_has = whas[sender, ports]                       # (N, P)
        in_widx = widx[sender, ports]
        in_rec = flat_rec[sender, in_widx]                 # (N, P, n)
        in_birth = flat_birth[sender, in_widx]
        in_srcq = w_srcq[sender, ports]                    # source queue id
        in_vc = w_vc[sender, ports]                        # target lane
        rec_after = in_rec - hop[None]
        done = jnp.abs(rec_after.astype(jnp.int32)).sum(-1) == 0
        deliver = in_has & done
        tgt_q = ports[None, :] * V + in_vc                 # target queue id
        # bubble rule per lane-ring: continuing in the SAME (port, lane)
        # needs 1 free credit, entering (turn, lane switch) needs 2;
        # credit-gated adaptive lanes need only 1 (Duato)
        need = jnp.where(in_srcq == tgt_q, 1, 2)
        if adaptive:
            need = jnp.where(in_vc > 0, 1, need)

        # ---- acceptance: sequential-sweep fixed point over channels ----
        # same recurrence as V=1, with a queue-granular (N, P·V) vacancy
        # carry: each channel p writes only queue (w, p, lane), so lanes
        # never collide and the carry stays tiny
        credit_flat = credit.reshape(N, PV)
        lvl_xs = dict(h=in_has.T, dn=done.T, nd=need.T, dl=deliver.T,
                      rx=receiver.T, wq=w_srcq.T, wh=whas.T, tq=tgt_q.T)

        def level(vac, x):
            freeq = take_q(credit_flat, x["tq"]) + take_q(vac, x["tq"])
            acc_p = x["h"] & ~x["dn"] & (freeq >= x["nd"])
            dep_w = (x["dl"] | acc_p)[x["rx"]] & x["wh"]
            vac = vac + jnp.where(
                dep_w[:, None] & (x["wq"][:, None] == qids[None, :]), 1, 0)
            return vac, acc_p

        _, accT = jax.lax.scan(level, jnp.zeros((N, PV), jnp.int32), lvl_xs)
        acc = accT.T                                       # (N, P)
        moved = deliver | acc

        delivered = deliver.sum()
        age = slot + 1 - in_birth
        if weighted:
            # final-crossing cost: arrival is wgt[p]−1 slots after the win
            age = age + (wgt - 1)[None, :]
        meas = deliver & (in_birth >= warmup)
        lat_sum = jnp.where(meas, age, 0).sum()
        lat_cnt = meas.sum()

        # ---- apply: clears + one-hot transit/injection writes ----
        dep_port = moved[receiver, ports] & whas
        dep_slot = is_winner & gather_port(dep_port, False, port_flat)
        birth_cleared = jnp.where(dep_slot, -1,
                                  flat_birth).reshape(N, P, V, Q)
        free_mask = birth_cleared < 0
        qi = jnp.arange(Q)[None, None, None, :]
        slot_f = jnp.argmax(free_mask, axis=3)             # (N, P, V)
        slot_l = (Q - 1) - jnp.argmax(free_mask[..., ::-1], axis=3)
        accv = acc[:, :, None] & (varange[None, None, :] == in_vc[:, :, None])
        wmask = accv[..., None] & (qi == slot_f[..., None])

        # ---- injection (after transit; local credits gate admission) --
        want_new = tr["u"] < state["load"]
        if scheduled:
            want_new = want_new & inj_ok_e
        elif not trivial:
            want_new = want_new & state["inj_ok"]
        want = want_new | (backlog0 > 0)
        depcnt = dep_slot.reshape(N, P, V, Q).sum(axis=3)  # (N, P, V)
        credit_post = credit + depcnt - accv.astype(jnp.int32)
        inj_port, inj_vc = credit_vc_select(tr["r"], lok, credit_post,
                                            policy, rot=slot,
                                            port_geom=port_geom,
                                            escape_fallback=esc_fb)
        ipc = jnp.minimum(inj_port, P - 1)                 # clamp P sentinel
        freesel = take_q(credit_post.reshape(N, PV), ipc * V + inj_vc)
        can = want & (freesel >= 2) & tr["v"] & (inj_port < P)
        if trivial:
            drop = None
        else:
            drop = want & ~(state["dst_live_fixed"][e] if scheduled
                            else state["dst_live_fixed"])
            can = can & ~drop
        imask = (can[:, None, None, None]
                 & (ports[None, :, None, None] == ipc[:, None, None, None])
                 & (varange[None, None, :, None]
                    == inj_vc[:, None, None, None])
                 & (qi == slot_l[..., None]))
        backlog = backlog0 + want_new - can
        if drop is not None:
            backlog = backlog - drop
        backlog = jnp.clip(backlog, 0, 1 << 30)

        new_rec = jnp.where(
            imask[..., None], tr["r"][:, None, None, None, :],
            jnp.where(wmask[..., None], rec_after[:, :, None, None, :],
                      rec))
        new_birth = jnp.where(
            imask, slot.astype(birth.dtype),
            jnp.where(wmask, in_birth[:, :, None, None], birth_cleared))
        new_credit = credit_post - imask.sum(axis=3)

        # per-lane telemetry: deliveries by the winner's SOURCE lane,
        # injections (incl. drops — they count as injected) by the
        # admitted lane; warmup-gated like the scalar counters
        counted = slot >= warmup
        src_vc = in_srcq % V
        vc_del = (deliver[..., None]
                  & (src_vc[..., None] == varange)).sum((0, 1))
        injm = can if drop is None else (can | drop)
        vc_inj = (injm[:, None] & (inj_vc[:, None] == varange)).sum(0)

        updates = dict(
            rec=new_rec, birth=new_birth, credit=new_credit,
            backlog=backlog,
            vc_delivered=state["vc_delivered"] + jnp.where(counted, vc_del,
                                                           0),
            vc_injected=state["vc_injected"] + jnp.where(counted, vc_inj,
                                                         0))
        if weighted:
            wait_dec = jnp.where(dep_slot.reshape(N, P, V, Q), 0,
                                 jnp.maximum(wait - 1, 0))
            updates["wait"] = jnp.where(
                imask, 0, jnp.where(wmask, (wgt - 1)[None, :, None, None],
                                    wait_dec))
            updates["busy"] = jnp.where(dep_port, wgt[None, :] - 1,
                                        jnp.maximum(busy - 1, 0))
        if ctx["hist_bins"]:
            updates["lat_hist"] = state["lat_hist"] + _bucket_counts(
                age, meas, ctx["hist_bins"])
        if not trivial:
            updates["link_use"] = state["link_use"] + dep_port.astype(
                jnp.int32)
        out = _finish_slot(state, warmup, delivered, lat_sum, lat_cnt, can,
                           drop, qdrop=qdrop, **updates)
        return out, (_timeline_y(out, new_birth, dep_port, link_ok)
                     if scheduled else None)

    return slot_step


def _make_slot_step_vc_reference(ctx, warmup: int):
    """Per-(port, lane) sweep oracle of the VC credit-flow router: the
    same macro-semantics as `_make_slot_step_vc_batched` (credit-gated
    `credit_vc_select` requests, one winner per physical channel, the
    per-lane bubble/credit acceptance rule, exact incremental credit
    bookkeeping) with the reference arbitration style — i.i.d. uniform
    per-slot scores and scatter writes in channel order.  Validated
    statistically against the batched VC path, like the V=1 oracle."""
    n, N, P, Q, V = ctx["n"], ctx["N"], ctx["P"], ctx["Q"], ctx["V"]
    nbr = ctx["nbr"]
    opp = [p ^ 1 for p in range(P)]
    trivial = ctx["trivial"]
    policy = ctx["policy"]
    adaptive = policy in ("adaptive", "escape")
    PV, PVQ = P * V, P * V * Q
    varange = jnp.arange(V, dtype=jnp.int32)
    scheduled = ctx.get("scheduled", False)
    weighted = ctx.get("weighted", False)
    express = ctx.get("express", False)
    wgt_of = (np.asarray(ctx["wgt"]).tolist() if weighted else [1] * P)
    if express:
        dim_of = np.asarray(ctx["pdim"]).tolist()
        sgn_of = np.asarray(ctx["psgn"]).tolist()
        span_of = np.asarray(ctx["pspan"]).tolist()
        port_geom = (ctx["pdim"], ctx["psgn"], ctx["pspan"])
    else:
        dim_of = [p // 2 for p in range(P)]
        sgn_of = [1 - 2 * (p % 2) for p in range(P)]
        span_of = [1] * P
        port_geom = None
    esc_fb = policy == "escape" and not trivial

    def slot_step(state, key):
        dst, rec, birth = state["dst"], state["rec"], state["birth"]
        credit = state["credit"]
        slot = state["slot"]
        if scheduled:
            # epoch resolution from the slot counter (masks stay BAKED);
            # dead-node drops mirror the batched VC step: occupancy at a
            # killed node clears across all lanes and the freed queue
            # space restores the lane's credits in the same slot
            e = ctx["slot2epoch"][slot]
            link_ok = ctx["link_ok"][e]
            node_ok = ctx["inj_ok"][e]
            masks = dict(link_ok=link_ok, inj_ok=node_ok, dst_ok=node_ok,
                         live_tbl=ctx["live_tbl"][e],
                         n_live=ctx["n_live"][e])
            deadq = (dst >= 0) & ~node_ok[:, None, None, None]
            qdrop = deadq.sum()
            dst = jnp.where(deadq, -1, dst)
            credit = credit + deadq.sum(axis=3)
            state = dict(state,
                         backlog=jnp.where(node_ok, state["backlog"], 0))
        else:
            link_ok = None if trivial else ctx["link_ok"]
            masks, qdrop = None, None
        occ = dst >= 0                                     # (N, P, V, Q)
        lok = jnp.ones((N, P), bool) if trivial else link_ok
        cd = credit[nbr, jnp.arange(P)[None, :]]           # (N, P, V)
        sel_port, sel_vc = credit_vc_select(
            rec, lok[:, None, None, None, :],
            cd[:, None, None, None, :, :], policy, rot=slot,
            port_geom=port_geom, escape_fallback=esc_fb)
        if weighted:
            busy, wait = state["busy"], state["wait"]
            sel_port = jnp.where(occ & (wait == 0), sel_port, -1)
        else:
            sel_port = jnp.where(occ, sel_port, -1)

        # ---- arbitration: one winner per (node, out-port) ----
        rand = jax.random.uniform(jax.random.fold_in(key, 1), (N, P, V, Q))
        requested = sel_port[..., None] == jnp.arange(P)
        if not trivial:
            requested = requested & link_ok[:, None, None, None, :]
        if weighted:
            requested = requested & (busy == 0)[:, None, None, None, :]
        flat = jnp.where(requested, rand[..., None], -1.0).reshape(
            N, PVQ, P)
        widx = jnp.argmax(flat, axis=1)                    # (N, P)
        whas = jnp.take_along_axis(flat, widx[:, None, :],
                                   axis=1)[:, 0, :] >= 0.0
        rows = jnp.arange(N)[:, None]
        flat_dst = dst.reshape(N, PVQ)
        flat_rec = rec.reshape(N, PVQ, n)
        flat_birth = birth.reshape(N, PVQ)
        w_dst = flat_dst[rows, widx]
        w_rec = flat_rec[rows, widx]
        w_birth = flat_birth[rows, widx]
        w_srcq = widx // Q                                 # queue id p·V+v
        w_vc = jnp.take_along_axis(sel_vc.reshape(N, PVQ), widx, axis=1)

        delivered = jnp.int32(0)
        lat_sum = jnp.int32(0)
        lat_cnt = jnp.int32(0)
        dead_crossings = jnp.int32(0)
        vc_del = jnp.zeros((V,), jnp.int32)
        age_l, meas_l, del_l = [], [], []
        new_dst, new_rec, new_birth = dst, rec, birth
        if weighted:
            new_busy = jnp.maximum(busy - 1, 0)
            new_wait = jnp.maximum(wait - 1, 0)
        credit_work = credit                               # (N, P, V)
        link_use = None if trivial else state["link_use"]
        r_ = jnp.arange(N)
        for p in range(P):
            d_p = dim_of[p]
            s_p = sgn_of[p] * span_of[p]                   # signed hop span
            w_p = wgt_of[p]
            u = nbr[:, opp[p]]                             # sender for recv w
            has = whas[u, p]
            pk_dst = w_dst[u, p]
            pk_rec = w_rec[u, p]
            pk_birth = w_birth[u, p]
            pk_srcq = w_srcq[u, p]
            pk_vc = w_vc[u, p]                             # target lane
            rec_after = pk_rec.at[:, d_p].add(-s_p)
            done = jnp.abs(rec_after.astype(jnp.int32)).sum(-1) == 0
            will_deliver = has & done
            need = jnp.where(pk_srcq == p * V + pk_vc, 1, 2)
            if adaptive:
                need = jnp.where(pk_vc > 0, 1, need)
            freeq = jnp.take_along_axis(credit_work[:, p], pk_vc[:, None],
                                        axis=1)[:, 0]
            ok = has & ~done & (freeq >= need)
            moved = will_deliver | ok
            age_p = slot + 1 - pk_birth
            if weighted:
                age_p = age_p + (w_p - 1)
            meas_p = will_deliver & (pk_birth >= warmup)
            delivered += will_deliver.sum()
            lat_sum += jnp.where(meas_p, age_p, 0).sum()
            lat_cnt += meas_p.sum()
            vc_del = vc_del + (will_deliver[:, None]
                               & ((pk_srcq % V)[:, None] == varange)).sum(0)
            if ctx["hist_bins"] or ctx.get("lat_trace"):
                age_l.append(age_p)
                meas_l.append(meas_p)
                del_l.append(will_deliver)
            if scheduled:
                dead_crossings += (moved & ~link_ok[u, p]).sum()
            if link_use is not None:
                link_use = link_use.at[u, p].add(moved.astype(jnp.int32))
            # clear the winner slot at the sender; its lane regains a credit
            sel = widx[:, p]
            fd = new_dst.reshape(N, PVQ)
            fd = fd.at[u, sel[u]].set(jnp.where(moved, -1, fd[u, sel[u]]))
            new_dst = fd.reshape(N, P, V, Q)
            credit_work = credit_work.reshape(N, PV).at[u, pk_srcq].add(
                moved.astype(jnp.int32)).reshape(N, P, V)
            # write into receiver queue (w, p, lane), first free slot
            lane_dst = new_dst[r_, p, pk_vc]               # (N, Q)
            slot_idx = jnp.argmax(lane_dst < 0, axis=1)
            new_dst = new_dst.at[r_, p, pk_vc, slot_idx].set(
                jnp.where(ok, pk_dst, new_dst[r_, p, pk_vc, slot_idx]))
            new_rec = new_rec.at[r_, p, pk_vc, slot_idx].set(
                jnp.where(ok[:, None], rec_after,
                          new_rec[r_, p, pk_vc, slot_idx]))
            new_birth = new_birth.at[r_, p, pk_vc, slot_idx].set(
                jnp.where(ok, pk_birth, new_birth[r_, p, pk_vc, slot_idx]))
            credit_work = credit_work.at[r_, p, pk_vc].add(
                -ok.astype(jnp.int32))
            if weighted:
                new_busy = new_busy.at[u, p].set(
                    jnp.where(moved, w_p - 1, new_busy[u, p]))
                new_wait = new_wait.at[r_, p, pk_vc, slot_idx].set(
                    jnp.where(ok, w_p - 1,
                              new_wait[r_, p, pk_vc, slot_idx]))

        if weighted:
            # free slots carry no countdown (injection crosses no link)
            new_wait = jnp.where(new_dst >= 0, new_wait, 0)

        # ---- injection: credit-aware lane admission (bubble cost 2) ----
        m = ctx if masks is None else {**ctx, **masks}
        k1, k2, k3 = jax.random.split(jax.random.fold_in(key, 2), 3)
        want_new = jax.random.uniform(k1, (N,)) < state["load"]
        if not trivial:
            want_new = want_new & m["inj_ok"]
        want = want_new | (state["backlog"] > 0)
        if ctx["fixed_dst"]:
            d = state["dst_table"]
        elif not trivial and ctx["has_dead_nodes"]:
            d = m["live_tbl"][jax.random.randint(k2, (N,), 0, m["n_live"])]
        else:
            d = jax.random.randint(k2, (N,), 0, N - 1)
            d = jnp.where(d >= jnp.arange(N), d + 1, d)
        di = _delta_idx(ctx["labels"], ctx["labels"][d], ctx["hermite"],
                        ctx["strides"])
        coin = jax.random.uniform(k3, (N,)) < 0.5
        r = jnp.where(coin[:, None], ctx["rec_a"][di], ctx["rec_b"][di])
        inj_port, inj_vc = credit_vc_select(r, lok, credit_work, policy,
                                            rot=slot, port_geom=port_geom,
                                            escape_fallback=esc_fb)
        ipc = jnp.minimum(inj_port, P - 1)
        freesel = jnp.take_along_axis(
            credit_work.reshape(N, PV), (ipc * V + inj_vc)[:, None],
            axis=1)[:, 0]
        can = (want & (freesel >= 2) & (jnp.abs(r).sum(-1) > 0)
               & (inj_port < P))
        if trivial:
            drop = None
        else:
            drop = want & ~m["dst_ok"][d]
            can = can & ~drop
        r = r.astype(new_rec.dtype)
        lane_dst = new_dst[r_, ipc, inj_vc]
        slot_idx = jnp.argmax(lane_dst < 0, axis=1)
        new_dst = new_dst.at[r_, ipc, inj_vc, slot_idx].set(
            jnp.where(can, d, new_dst[r_, ipc, inj_vc, slot_idx]))
        new_rec = new_rec.at[r_, ipc, inj_vc, slot_idx].set(
            jnp.where(can[:, None], r, new_rec[r_, ipc, inj_vc, slot_idx]))
        new_birth = new_birth.at[r_, ipc, inj_vc, slot_idx].set(
            jnp.where(can, slot, new_birth[r_, ipc, inj_vc, slot_idx]))
        credit_work = credit_work.reshape(N, PV).at[
            r_, ipc * V + inj_vc].add(-can.astype(jnp.int32)).reshape(
                N, P, V)
        backlog = state["backlog"] + want_new - can
        if drop is not None:
            backlog = backlog - drop
        backlog = jnp.clip(backlog, 0, 1 << 30)

        counted = slot >= warmup
        injm = can if drop is None else (can | drop)
        vc_inj = (injm[:, None] & (inj_vc[:, None] == varange)).sum(0)
        updates = dict(
            dst=new_dst, rec=new_rec, birth=new_birth, backlog=backlog,
            credit=credit_work,
            vc_delivered=state["vc_delivered"] + jnp.where(counted, vc_del,
                                                           0),
            vc_injected=state["vc_injected"] + jnp.where(counted, vc_inj,
                                                         0))
        if weighted:
            updates["busy"] = new_busy
            updates["wait"] = new_wait
        if ctx["hist_bins"]:
            updates["lat_hist"] = state["lat_hist"] + _bucket_counts(
                jnp.stack(age_l, 1), jnp.stack(meas_l, 1),
                ctx["hist_bins"])
        if link_use is not None:
            updates["link_use"] = link_use
        out = _finish_slot(state, warmup, delivered, lat_sum, lat_cnt, can,
                           drop, qdrop=qdrop, **updates)
        y = None
        if scheduled:
            y = dict(delivered=out["delivered"], injected=out["injected"],
                     dropped=out["dropped"],
                     in_flight=(new_dst >= 0).sum(),
                     dead_crossings=dead_crossings)
            if ctx["hist_bins"]:
                y["lat_hist"] = out["lat_hist"]
        elif ctx.get("lat_trace"):
            # the per-packet oracle, VC flavour: ages/flags per physical
            # in-port — same (slots, N, P) trace shape as the V=1 oracle
            y = dict(age=jnp.stack(age_l, 1), deliv=jnp.stack(del_l, 1),
                     meas=jnp.stack(meas_l, 1))
        return out, y

    return slot_step


def _scenario_mask_fields(scenario: Scenario, g: LatticeGraph, N: int,
                          dst_np, force_dead_nodes: bool = False,
                          link_spec=None) -> dict:
    """The scenario-DEPENDENT traced arrays of a mask-threaded context —
    factored out so a K-scenario sweep derives per-scenario masks without
    rebuilding the scenario-independent routing/label tables K times.
    `link_spec` extends the link_ok axis over express overlay ports
    (2n+2X), so express channels die and repair like any link."""
    link_ok = scenario.link_ok(g, link_spec)
    node_ok = scenario.node_ok(g)
    live = np.flatnonzero(node_ok).astype(np.int32)
    if live.size == 0:
        raise ValueError("scenario kills every node")
    # pad the live table to N entries so it has a scenario-independent
    # shape (a traced input must not change shape across patterns);
    # entries past n_live repeat live[0] and are never drawn
    live_pad = np.full(N, live[0], np.int32)
    live_pad[:live.size] = live
    return dict(
        link_ok=jnp.asarray(link_ok),
        inj_ok=jnp.asarray(node_ok),
        dst_ok=jnp.asarray(node_ok),
        has_dead_nodes=bool(scenario.dead_nodes) or force_dead_nodes,
        live_tbl=jnp.asarray(live_pad),
        n_live=int(live.size),
        # fixed-pattern packets aimed at a dead node are dropped at
        # injection (uniform traffic samples live nodes, never drops)
        dst_live_fixed=jnp.asarray(
            node_ok[dst_np] if dst_np is not None else np.ones(N, bool)))


def _schedule_mask_fields(compiled: CompiledSchedule, g: LatticeGraph,
                          N: int, dst_np, force_dead_nodes: bool = False,
                          pad_to: int | None = None,
                          link_spec=None) -> dict:
    """Per-EPOCH stacks of the scenario mask fields, plus the slot→epoch
    map — the traced time axis of a scheduled run.  `pad_to` repeats the
    final epoch so K schedules of differing epoch counts can share one
    compiled program (padded epochs are unreachable: the slot→epoch map
    never points at them)."""
    per = [_scenario_mask_fields(s, g, N, dst_np, force_dead_nodes,
                                 link_spec)
           for s in compiled.epochs]
    E = pad_to if pad_to is not None else len(per)
    if E < len(per):
        raise ValueError(
            f"pad_to={E} is smaller than the schedule's {len(per)} epochs")
    per = per + [per[-1]] * (E - len(per))
    out = {k: jnp.stack([m[k] for m in per])
           for k in ("link_ok", "inj_ok", "dst_ok", "live_tbl",
                     "dst_live_fixed")}
    out["n_live"] = jnp.asarray([m["n_live"] for m in per], jnp.int32)
    out["has_dead_nodes"] = (any(m["has_dead_nodes"] for m in per)
                             or force_dead_nodes)
    out["slot2epoch"] = jnp.asarray(compiled.slot2epoch, jnp.int32)
    return out


def _make_ctx(t: SimTables, g: LatticeGraph, pattern: str, seed: int,
              queue: int, scenario: Scenario | None = None,
              force_masks: bool = False, force_dead_nodes: bool = False,
              schedule: CompiledSchedule | None = None,
              pad_epochs: int | None = None, *, hist_bins: int = 0,
              lat_trace: bool = False, vcs: int = 1,
              credits: int | None = None, links: LinkSpec | None = None):
    """`force_masks=True` builds the mask-threaded (non-trivial) context
    even for the pristine scenario — used by `simulate_scenario_sweep`,
    where a pristine pattern may ride the traced-mask program alongside
    faulted ones (all-live masks reproduce the trivial results);
    `force_dead_nodes=True` additionally gives a dead-node-free pattern
    the dead-node program STRUCTURE (live-table destination sampling over
    all N nodes), so it can share a sweep with dead-node patterns.
    `schedule` (a `CompiledSchedule`) builds the TIME-INDEXED context:
    per-epoch mask stacks (padded to `pad_epochs` when sweeping K
    schedules of differing epoch counts) plus the slot→epoch map, all
    traced inputs of the batched/fused programs.  `hist_bins=B` turns on
    the in-carry latency histogram (age buckets 0..B−2 exact, B−1
    overflow); `lat_trace=True` makes the REFERENCE runner additionally
    emit per-slot delivery traces (the per-packet latency oracle —
    test-scale only, exclusive with `schedule`).  `links` (a `LinkSpec`)
    adds heterogeneous-link semantics: per-port slot weights (a weight-w
    channel is held for w slots), a pillar structural mask AND-ed into
    the link_ok masks, and express overlay ports extending P past 2n; a
    trivial/None spec compiles the identical pre-heterogeneous program."""
    scenario = scenario or Scenario()
    if lat_trace and schedule is not None:
        raise ValueError("lat_trace is exclusive with schedule=")
    if hist_bins < 0:
        raise ValueError(f"hist_bins must be >= 0, got {hist_bins}")
    policy = schedule.policy if schedule is not None else scenario.policy
    ls = links if links is not None and not links.is_trivial else None
    if ls is not None:
        ls.validate(t.n)
        # SimConfig raises these with friendlier context; the shared
        # validator keeps direct _make_ctx callers honest too
        validate_feature_combo(vcs=vcs, links_trivial=False,
                               express=bool(ls.express), policy=policy)
        # a pillar spec removes links: even a pristine Scenario must ride
        # the mask-threaded program so the structural mask is enforced
        force_masks = force_masks or ls.has_pillar
    trivial = (schedule is None and scenario.is_trivial
               and not force_masks)
    dst_np = pattern_table(g, pattern, seed)
    fixed_dst = dst_np is not None
    # records are tiny for every pod-sized lattice — int8 state quarters the
    # memory traffic of the biggest per-slot tensors (int32 kept as a
    # fallback for enormous single-dimension graphs; escape misrouting can
    # grow records past the minimal bound, so it gets the wide dtype —
    # at V=1 directly, and at V>1 via the VC0 escape-fallback misroute
    # that kicks in when DOR's escape port is dead)
    rec_max = max(int(np.abs(t.records_a).max(initial=0)),
                  int(np.abs(t.records_b).max(initial=0)))
    rec_dtype = (jnp.int32
                 if policy == "escape" or rec_max > 120
                 else jnp.int8)
    # per-delta-index injection tables: record (Remark-30 pair) + its first
    # DOR port, so traffic generation is two gathers instead of routing work
    rec_ab = np.stack([t.records_a, t.records_b], axis=1)  # (N, 2, n)
    nz = np.abs(rec_ab) > 0
    dim = np.argmax(nz, axis=-1)
    sgn = np.take_along_axis(rec_ab, dim[..., None], axis=-1)[..., 0]
    if ls is not None and ls.express:
        # greedy weighted-DOR first hop over the extended port set: among
        # ports of the record's first nonzero dimension whose sign matches
        # and whose span fits the remaining offset, take the largest span
        pdim_np = ls.port_dims(t.n)
        psgn_np = ls.port_signs(t.n)
        pspan_np = ls.port_spans(t.n)
        ok = ((pdim_np == dim[..., None]) & (psgn_np * sgn[..., None] > 0)
              & (pspan_np <= np.abs(sgn)[..., None]))
        port_ab = np.argmax(np.where(ok, pspan_np, -1), axis=-1)  # (N, 2)
    else:
        port_ab = 2 * dim + (sgn < 0)                      # (N, 2)
    if fixed_dst:
        g_strides = t.strides.astype(np.int64)
        lab = t.labels.astype(np.int64)
        delta = lab[dst_np] - lab
        # reduce into the Hermite box on host (exact integer arithmetic)
        from . import intmat
        di_fixed = (intmat.canonical_label(delta, t.hermite)
                    * g_strides).sum(axis=-1).astype(np.int32)
    else:
        di_fixed = np.zeros(t.N, np.int32)
    # the batched/fused cache key carries only the scenario STRUCTURE
    # (policy × dead-node-ness — plus the epoch count for schedules, a
    # shape): masks are traced state inputs, so every fault pattern of the
    # same structure reuses one compiled runner.  The reference oracle
    # keeps masks baked (full fingerprint key).
    if schedule is not None:
        fields = _schedule_mask_fields(
            schedule, g, t.N, dst_np if fixed_dst else None,
            force_dead_nodes, pad_to=pad_epochs, link_spec=ls)
        E = int(fields["link_ok"].shape[0])
        scen: dict = dict(trivial=False, scheduled=True, policy=policy,
                          scen_fp=schedule.fingerprint(g),
                          scen_structure=("schedule", policy,
                                          fields["has_dead_nodes"], E))
        scen.update(fields)
    else:
        hdn = bool(scenario.dead_nodes) or force_dead_nodes
        scen = dict(trivial=trivial, scheduled=False, policy=policy,
                    scen_fp=scenario.fingerprint(g),
                    scen_structure=(("trivial",) if trivial else
                                    ("traced", policy, hdn)))
        if not trivial:
            scen.update(_scenario_mask_fields(
                scenario, g, t.N, dst_np if fixed_dst else None,
                force_dead_nodes, ls))
    # heterogeneous-link context: per-port weights, pillar structural
    # mask (AND-ed into every link_ok, so the dead-channel audit covers
    # missing pillars), express-extended neighbour/port-geometry tables
    if ls is not None:
        nbr_np = ls.extended_neighbors(g)
        wgt_np = ls.port_weights(t.n)
        structural_np = ls.structural_mask(g)
        if structural_np is not None:
            scen["link_ok"] = scen["link_ok"] & jnp.asarray(structural_np)
        link = dict(
            link_fp=ls.fingerprint(),
            weighted=bool((wgt_np > 1).any()),
            express=bool(ls.express),
            wgt=jnp.asarray(wgt_np),
            structural=(None if structural_np is None
                        else jnp.asarray(structural_np)),
            pdim=jnp.asarray(ls.port_dims(t.n)),
            psgn=jnp.asarray(ls.port_signs(t.n)),
            pspan=jnp.asarray(ls.port_spans(t.n)),
            hop_tab=jnp.asarray(ls.hop_table(t.n)))
        P = ls.num_ports(t.n)
    else:
        nbr_np = t.neighbors
        link = dict(link_fp=None, weighted=False, express=False,
                    structural=None)
        P = 2 * t.n
    return dict(
        n=t.n, N=t.N, P=P, Q=queue, rec_dtype=rec_dtype,
        V=int(vcs), credit_init=int(queue if credits is None else credits),
        hist_bins=int(hist_bins), lat_trace=bool(lat_trace),
        **scen, **link,
        nbr=jnp.asarray(nbr_np),
        rec_a=jnp.asarray(t.records_a),
        rec_b=jnp.asarray(t.records_b),
        rec_ab=jnp.asarray(rec_ab.astype(np.int64), rec_dtype),
        port_ab=jnp.asarray(port_ab, jnp.int8),
        di_fixed=jnp.asarray(di_fixed),
        labels=jnp.asarray(t.labels),
        hermite=jnp.asarray(t.hermite),
        strides=jnp.asarray(t.strides),
        fixed_dst=fixed_dst,
        dst_table=jnp.asarray(
            dst_np if fixed_dst else np.zeros(t.N, np.int32)))


def _init_state(ctx, load: float, impl: str, slots: int = 1 << 14):
    n, N, P, Q = ctx["n"], ctx["N"], ctx["P"], ctx["Q"]
    V = ctx.get("V", 1)
    birth_dtype = jnp.int16 if slots < (1 << 15) - 1 else jnp.int32
    # the VC router (V > 1) widens every per-port queue to V lanes and
    # carries the (N, P, V) credit array + per-lane counters in the scan
    # state; V = 1 keeps the exact pre-VC layout (no credit, no lane axis)
    qshape = (N, P, V, Q) if V > 1 else (N, P, Q)
    state = dict(
        load=jnp.float32(load),
        dst_table=ctx["dst_table"],
        rec=jnp.zeros(qshape + (n,), dtype=ctx["rec_dtype"]),
        birth=jnp.full(qshape, -1, dtype=birth_dtype),
        backlog=jnp.zeros((N,), dtype=jnp.int32),
        slot=jnp.int32(0),
        delivered=jnp.int32(0),
        lat_sum=jnp.int32(0),
        lat_cnt=jnp.int32(0),
        injected=jnp.int32(0),
        dropped=jnp.int32(0))
    if V > 1:
        state["credit"] = jnp.full((N, P, V), ctx["credit_init"],
                                   jnp.int32)
        state["vc_delivered"] = jnp.zeros((V,), jnp.int32)
        state["vc_injected"] = jnp.zeros((V,), jnp.int32)
    if ctx.get("weighted"):
        # heterogeneous links: `busy` counts down the remaining slots a
        # weight-w channel stays held after a crossing; `wait` counts
        # down the slots before an in-queue packet becomes eligible (it
        # occupies buffer space — and in_flight — the whole time)
        state["busy"] = jnp.zeros((N, P), dtype=jnp.int32)
        state["wait"] = jnp.zeros(qshape, dtype=jnp.int32)
    if ctx["hist_bins"]:
        state["lat_hist"] = jnp.zeros((ctx["hist_bins"],), jnp.int32)
    if not ctx["trivial"]:
        state["link_use"] = jnp.zeros((N, P), dtype=jnp.int32)
    if impl in ("batched", "fused"):
        if V == 1:
            # birth < 0 marks free slots; each packet carries its next
            # DOR port (the VC router re-selects per slot instead — its
            # choice depends on the live credit counters)
            state["port"] = jnp.zeros((N, P, Q), dtype=jnp.int8)
        state["di_fixed"] = ctx["di_fixed"]
        if not ctx["trivial"]:
            # scenario masks are TRACED inputs: they ride in the state so
            # one compiled runner serves every fault pattern of the same
            # structure, and scenario sweeps can vmap over them.  Under a
            # schedule they carry a leading (E,) epoch axis, n_live is an
            # (E,) vector, and the slot→epoch map joins them.
            state["dst_live_fixed"] = ctx["dst_live_fixed"]
            state["link_ok"] = ctx["link_ok"]
            state["inj_ok"] = ctx["inj_ok"]
            if ctx.get("scheduled"):
                state["slot2epoch"] = ctx["slot2epoch"]
                if ctx["has_dead_nodes"]:
                    state["live_tbl"] = ctx["live_tbl"]
                    state["n_live"] = ctx["n_live"]
            elif ctx["has_dead_nodes"]:
                state["live_tbl"] = ctx["live_tbl"]
                state["n_live"] = jnp.int32(ctx["n_live"])
        del state["dst_table"]
    else:
        # the reference keeps the original dst-as-occupancy layout
        state["dst"] = jnp.full(qshape, -1, dtype=jnp.int32)
        state["birth"] = jnp.zeros(qshape, dtype=jnp.int32)
    return state


# scenario-dependent traced state inputs (vmapped by the scenario axis of
# `simulate_scenario_sweep` / the schedule axis of
# `simulate_schedule_sweep`, shared across the load/seed axes);
# slot2epoch only exists in scheduled states
_SCEN_STATE = ("link_ok", "inj_ok", "live_tbl", "n_live", "dst_live_fixed",
               "slot2epoch")
# state entries shared across the load AND seed sweep axes
_SHARED_STATE = ("dst_table", "di_fixed") + _SCEN_STATE

# traces per impl, incremented when a runner's Python body runs (i.e. at
# jit-trace time) — the recompile-count tests read this to prove that K
# fault patterns of one structure share a single trace/compile
TRACE_COUNTS: dict = {"batched": 0, "reference": 0, "fused": 0}


def _get_runner(t: SimTables, ctx, *, slots: int, warmup: int, impl: str,
                n_loads: int, n_seeds: int = 1, n_scen: int = 1):
    """One compiled `lax.scan` per (topology, pattern kind, scenario
    STRUCTURE, run shape); sweeps vmap the same program over the load axis
    and, nested inside it, the seed axis — and `simulate_scenario_sweep`
    over an outermost scenario axis.  The batched/fused runners take
    per-run PRNG keys and pre-draw all traffic (`_make_traffic`); the
    reference runner splits its key into per-slot keys and draws inside
    the scan.  Scenario masks are traced state inputs for batched/fused
    (cache key = structure only: policy × dead-node-ness), and baked
    constants for the reference oracle (cache key = full fingerprint)."""
    scen_key = (ctx["scen_fp"] if impl == "reference"
                else ctx["scen_structure"])
    scheduled = ctx.get("scheduled", False)
    tracing = ctx["lat_trace"] and impl == "reference"
    V = ctx.get("V", 1)
    validate_feature_combo(
        impl=impl, vcs=V, links_trivial=ctx.get("link_fp") is None,
        express=ctx.get("express", False), policy=ctx["policy"])
    key = (t.neighbors.tobytes(), ctx["fixed_dst"], slots, warmup,
           ctx["Q"], impl, n_loads, n_seeds, n_scen, scen_key,
           ctx["hist_bins"], tracing, V, ctx.get("credit_init"),
           ctx.get("link_fp"))
    if key not in _RUNNER_CACHE:
        if impl == "reference":
            step = (_make_slot_step_vc_reference(ctx, warmup) if V > 1
                    else _make_slot_step_reference(ctx, warmup))

            def runner(st, key):
                TRACE_COUNTS[impl] += 1
                ks = jax.random.split(key, slots)
                final, ys = jax.lax.scan(step, st, ks)
                if scheduled:
                    return dict(final, timeline=ys)
                if tracing:
                    return dict(final, lat_trace=ys)
                return final
        else:
            step = (_make_slot_step_vc_batched(ctx, warmup) if V > 1
                    else _make_slot_step_batched(ctx, warmup)
                    if impl == "batched"
                    else _make_slot_step_fused(ctx, warmup))

            def runner(st, key):
                TRACE_COUNTS[impl] += 1
                tr = _make_traffic(ctx, st, key, slots)
                if scheduled:
                    # the slot→epoch map is scanned alongside the traffic
                    # so each step sees its epoch as a scalar
                    tr["epoch"] = st["slot2epoch"]
                final, ys = jax.lax.scan(step, st, tr)
                return dict(final, timeline=ys) if scheduled else final
        # dst_table / di_fixed / scenario masks are shared across both
        # sweep axes, so fixed-pattern traffic is derived once, not once
        # per run
        state_keys = list(_init_state(ctx, 0.0, impl))
        axes = {k: (None if k in _SHARED_STATE else 0) for k in state_keys}
        # the per-slot timeline ys only exist in scheduled outputs and are
        # always batched along the vmapped axes (ditto the oracle trace)
        out_ax = dict(axes, timeline=0) if scheduled else (
            dict(axes, lat_trace=0) if tracing else axes)
        if n_seeds > 1:
            # seed axis: same initial state, one key per seed
            runner = jax.vmap(runner, in_axes=(None, 0), out_axes=out_ax)
        if n_loads > 1:
            # load axis: per-load state (the offered load lives in it) and
            # per-load fold of the key (decorrelates sweep points)
            runner = jax.vmap(runner, in_axes=(axes, 0), out_axes=out_ax)
        if n_scen > 1:
            # outermost scenario axis: only the masks vary; the PRNG key
            # is shared (common random numbers — scenario differences in
            # the results are fault effects, not sampling noise)
            in_sc = {k: (0 if k in _SCEN_STATE else None)
                     for k in state_keys}
            out_sc = {k: (None if k in ("dst_table", "di_fixed") else 0)
                      for k in state_keys}
            if scheduled:
                out_sc = dict(out_sc, timeline=0)
            runner = jax.vmap(runner, in_axes=(in_sc, None), out_axes=out_sc)
        _RUNNER_CACHE[key] = jax.jit(runner)
    return _RUNNER_CACHE[key]


def _result(out, *, slots: int, warmup: int, N: int) -> SimResult:
    measured = slots - warmup
    delivered = int(out["delivered"])
    lat_cnt = int(out["lat_cnt"])
    # occupancy at run end: the reference keeps dst-as-occupancy, the
    # batched state marks free slots with birth < 0
    occ = out.get("dst", out.get("birth"))
    lu = out.get("link_use")
    tl = out.get("timeline")
    lh = out.get("lat_hist")
    vcd = out.get("vc_delivered")
    return SimResult(
        accepted_load=delivered / max(measured * N, 1),
        # mean over MEASURED deliveries (born at/after warmup); NaN — not
        # a fake 0.0 — when nothing qualified
        avg_latency_cycles=(PACKET_PHITS * float(out["lat_sum"]) / lat_cnt
                            if lat_cnt else float("nan")),
        delivered=delivered,
        injected=int(out["injected"]),
        slots=slots,
        dropped=int(out.get("dropped", 0)),
        in_flight=0 if occ is None else int((np.asarray(occ) >= 0).sum()),
        lat_count=lat_cnt,
        latency_hist=None if lh is None else np.asarray(lh),
        link_use=None if lu is None else np.asarray(lu),
        timeline=None if tl is None else SimTimeline(
            **{k: np.asarray(v) for k, v in tl.items()}),
        # per-lane telemetry only exists for vcs>1 runs; occupancy is
        # (N, P, V, Q) there, so the lane axis is axis 2
        vc_delivered=None if vcd is None else np.asarray(vcd),
        vc_injected=(None if vcd is None
                     else np.asarray(out["vc_injected"])),
        vc_in_flight=(None if vcd is None
                      else (np.asarray(occ) >= 0).sum(axis=(0, 1, 3))))


def _result_grid(out, axes_sizes: tuple, impl: str, *, slots: int,
                 warmup: int, N: int) -> np.ndarray:
    """Slice a (possibly vmapped) runner output into one `SimResult` per
    grid cell.  `axes_sizes` is the full leading batch shape (e.g.
    (L, S) or (K, L, S)); size-1 axes are absent from the raw output and
    re-inserted here.  Shared by `simulate_sweep` and
    `simulate_scenario_sweep` so the kept-counter set and axis
    normalization cannot drift between them."""
    occ_key = "dst" if impl == "reference" else "birth"
    keep = ("delivered", "lat_sum", "lat_cnt", "lat_hist", "injected",
            "dropped", "link_use", "vc_delivered", "vc_injected", occ_key)
    out_np = {k: np.asarray(v) for k, v in out.items() if k in keep}
    tl = out.get("timeline")
    tl_np = (None if tl is None
             else {k: np.asarray(v) for k, v in tl.items()})
    for i, size in enumerate(axes_sizes):
        if size == 1:
            out_np = {k: np.expand_dims(v, i) for k, v in out_np.items()}
            if tl_np is not None:
                tl_np = {k: np.expand_dims(v, i) for k, v in tl_np.items()}
    res = np.empty(axes_sizes, dtype=object)
    for idx in np.ndindex(*axes_sizes):
        cell = {k: v[idx] for k, v in out_np.items()}
        if tl_np is not None:
            cell["timeline"] = {k: v[idx] for k, v in tl_np.items()}
        res[idx] = _result(cell, slots=slots, warmup=warmup, N=N)
    return res


@dataclass(frozen=True)
class SweepStats:
    """Multi-seed sweep: `results[load][seed]` plus the mean ± CI reducers
    the Figs 5–8 error bars are drawn from."""
    loads: tuple[float, ...]
    seeds: tuple[int, ...]
    results: tuple[tuple[SimResult, ...], ...]

    def field(self, name: str) -> np.ndarray:
        """(L, S) array of one SimResult field."""
        return np.array([[getattr(r, name) for r in row]
                         for row in self.results], dtype=np.float64)

    def accepted(self) -> np.ndarray:
        return self.field("accepted_load")

    def accepted_mean(self) -> np.ndarray:
        return self.accepted().mean(axis=1)

    def accepted_ci(self, z: float = 1.96) -> np.ndarray:
        """Per-load CI half-width z·s/√k over the seed axis (0 for k=1)."""
        a = self.accepted()
        k = a.shape[1]
        if k < 2:
            return np.zeros(a.shape[0])
        return z * a.std(axis=1, ddof=1) / np.sqrt(k)

    def latency_mean(self) -> np.ndarray:
        """Per-load latency mean pooled over seeds, weighted by each
        seed's MEASURED delivery count (an unweighted per-seed mean
        over-represents starved seeds); seeds that measured nothing
        (NaN mean, zero weight) drop out, and a load point where no seed
        measured anything is NaN."""
        m = self.field("avg_latency_cycles")               # (L, S)
        w = self.field("lat_count")
        w = np.where(np.isnan(m), 0.0, w)
        tot = w.sum(axis=1)
        num = np.where(w > 0, m, 0.0) * w
        return np.where(tot > 0, num.sum(axis=1) / np.maximum(tot, 1.0),
                        np.nan)

    def latency_hist(self) -> np.ndarray:
        """(L, B) histogram pooled (summed) over the seed axis — the
        exact multi-seed distribution, not an average of averages."""
        rows = []
        for row in self.results:
            hs = [r.latency_hist for r in row]
            if any(h is None for h in hs):
                raise ValueError(
                    "sweep ran without hist_bins; pass hist_bins= to the "
                    "sweep call to collect latency histograms")
            rows.append(np.sum(hs, axis=0))
        return np.asarray(rows)

    def latency_percentile(self, q: float) -> np.ndarray:
        """(L,) exact q-th latency percentile (cycles) of the pooled
        per-load histogram; NaN where nothing was measured, +inf where
        the percentile falls in the overflow bucket."""
        return np.array([_hist_percentile(h, q)
                         for h in self.latency_hist()])

    def latency_p50(self) -> np.ndarray:
        return self.latency_percentile(0.50)

    def latency_p99(self) -> np.ndarray:
        return self.latency_percentile(0.99)

    def latency_p999(self) -> np.ndarray:
        return self.latency_percentile(0.999)


def _seed_list(seed: int, seeds) -> list[int] | None:
    if seeds is None:
        return None
    if isinstance(seeds, (int, np.integer)):
        return [seed + i for i in range(int(seeds))]
    return [int(s) for s in seeds]


def _sweep_plan(g: LatticeGraph, pattern: str, loads, *, slots, warmup,
                queue, seed, seed_list, tables, impl, scenario,
                scenarios=None, schedules=None, hist_bins=0, vcs=1,
                credits=None, links=None):
    """Build (runner, broadcast initial state, (L[, S]) key grid) for one
    sweep device program.  Key derivation: run (ℓ, s) of a multi-load
    sweep uses `fold_in(PRNGKey(seeds[s] + 17), ℓ)` — every load point
    gets its own fold (pre-PR-3 all points of a sweep shared one key and
    were perfectly correlated), and every seed its own base key.  A
    single-load sweep uses the unfolded base keys, so its seed-axis
    slices stay bitwise-equal to plain `simulate(..., seed=seeds[s])`.
    With `scenarios` (a list of K fault patterns) the state's traced mask
    entries are stacked on an outermost scenario axis and the runner is
    vmapped over it — K patterns, one trace, one compile.  The
    scenario-independent tables are built ONCE (only the mask fields are
    derived per scenario, via `_scenario_mask_fields`);
    `force_dead_nodes` gives every lane the dead-node program structure
    when any pattern in the sweep kills nodes.  `schedules` (a list of K
    `CompiledSchedule`s, already bound to `slots`) is the transient
    analogue: per-schedule epoch stacks are padded to a common E and
    stacked on the same outermost axis — K timelines, one trace, one
    compile."""
    t = tables or build_tables(g, seed)
    ls = links if links is not None and not links.is_trivial else None
    if schedules is not None:
        E = max(c.E for c in schedules)
        fdn = any(c.has_dead_nodes for c in schedules)
        ctx = _make_ctx(t, g, pattern, seed, queue, schedule=schedules[0],
                        pad_epochs=E, force_dead_nodes=fdn,
                        hist_bins=hist_bins, vcs=vcs, credits=credits,
                        links=links)
        dst_np = (np.asarray(ctx["dst_table"]) if ctx["fixed_dst"]
                  else None)
        sched_keys = ["link_ok", "inj_ok", "dst_live_fixed", "slot2epoch"]
        if ctx["has_dead_nodes"]:
            sched_keys += ["live_tbl", "n_live"]
        masks = [{k: ctx[k] for k in sched_keys}] + [
            _schedule_mask_fields(c, g, t.N, dst_np, fdn, pad_to=E,
                                  link_spec=ls)
            for c in schedules[1:]]
    elif scenarios is None:
        ctx = _make_ctx(t, g, pattern, seed, queue, scenario,
                        hist_bins=hist_bins, vcs=vcs, credits=credits,
                        links=links)
        masks = None
    else:
        fdn = any(s.dead_nodes for s in scenarios)
        ctx = _make_ctx(t, g, pattern, seed, queue, scenarios[0],
                        force_masks=True, force_dead_nodes=fdn,
                        hist_bins=hist_bins, vcs=vcs, credits=credits,
                        links=links)
        dst_np = (np.asarray(ctx["dst_table"]) if ctx["fixed_dst"]
                  else None)
        masks = [{k: ctx[k] for k in ("link_ok", "inj_ok", "live_tbl",
                                      "n_live", "dst_live_fixed")}] + [
            _scenario_mask_fields(s, g, t.N, dst_np, fdn, ls)
            for s in scenarios[1:]]
    if masks is not None and ctx.get("structural") is not None:
        # pillar structural mask: ctx lane 0 already has it AND-ed in
        # (_make_ctx); compose it into every other sweep lane's link_ok
        # (broadcasts over the (E, ...) epoch axis of schedule stacks)
        for m in masks[1:]:
            m["link_ok"] = m["link_ok"] & ctx["structural"]
    sl = seed_list if seed_list is not None else [seed]
    L, S = len(loads), len(sl)
    runner = _get_runner(t, ctx, slots=slots, warmup=warmup, impl=impl,
                         n_loads=L, n_seeds=S,
                         n_scen=1 if masks is None else len(masks))
    state = _init_state(ctx, 0.0, impl, slots)
    if L > 1:
        state = {
            k: (v if k in _SHARED_STATE
                else jnp.broadcast_to(v, (L,) + v.shape))
            for k, v in state.items()}
    if masks is not None and len(masks) > 1:
        # stack the per-scenario traced masks on the scenario axis (a
        # K=1 sweep has no scenario vmap — ctx's masks are already in
        # the state)
        scheduled = ctx.get("scheduled", False)
        stack = ["link_ok", "inj_ok", "dst_live_fixed"]
        if scheduled:
            stack.append("slot2epoch")
        if ctx["has_dead_nodes"]:
            stack.append("live_tbl")
            if scheduled:
                stack.append("n_live")
        for k in stack:
            state[k] = jnp.stack([m[k] for m in masks])
        if ctx["has_dead_nodes"] and not scheduled:
            state["n_live"] = jnp.asarray([m["n_live"] for m in masks],
                                          jnp.int32)
    state = dict(state, load=jnp.asarray(loads, jnp.float32) if L > 1
                 else jnp.float32(loads[0]))
    def run_key(s, li):
        base = jax.random.PRNGKey(s + 17)
        return np.asarray(jax.random.fold_in(base, li) if L > 1 else base)

    keys = np.stack([
        np.stack([run_key(s, li) for s in sl])
        for li in range(L)])                               # (L, S, 2)
    if S == 1:
        keys = keys[:, 0]
    if L == 1:
        keys = keys[0]
    return runner, state, jnp.asarray(keys), t, ctx


def simulate(g: LatticeGraph, pattern: str, load: float, *,
             config: SimConfig | None = None,
             slots: int | None = None, warmup: int | None = None,
             queue: int | None = None, seed: int | None = None,
             tables: SimTables | None = None, impl: str | None = None,
             scenario: Scenario | None = None, fold: int | None = None,
             schedule: FaultSchedule | None = None,
             hist_bins: int | None = None, vcs: int | None = None,
             credits: int | None = None,
             links: LinkSpec | None = None) -> SimResult:
    """Run `slots` packet-slots (16 cycles each) at offered load `load`
    (phits/cycle/node) and measure accepted throughput + latency.

    Every run-shaping parameter can arrive EITHER as a `SimConfig` via
    `config=` or as the historical kwargs (a thin shim over
    `SimConfig.from_kwargs`; mixing both raises).  `fold` stays a
    per-call argument — it names *which* sweep point to reproduce, not
    how to run: `simulate_sweep(loads)[i]` equals
    `simulate(loads[i], fold=i)`.

    impl="batched" is the port-batched single-pass simulator;
    impl="reference" is the per-port-sweep oracle it is validated against.
    `scenario` injects faults / selects the routing policy (see
    `repro.core.scenario.Scenario`); None is the pristine DOR baseline and
    compiles to the exact pre-scenario program.  `schedule` (a
    `repro.core.fault_schedule.FaultSchedule`, exclusive with `scenario`)
    runs a TRANSIENT-fault timeline: per-epoch mask stacks ride the state
    as traced inputs, the result carries a per-slot `SimTimeline`, and a
    single-epoch schedule is bitwise-equal to the static scenario run.

    impl="fused" routes the slot update through the Pallas kernel
    (`repro.kernels.sim_step`): same state layout and pre-drawn traffic as
    the batched path, winner/acceptance/apply fused into one kernel pass
    (interpret mode off-TPU) — results are bitwise-equal to batched.

    `hist_bins=B` additionally collects the (B,)-bucket latency histogram
    in the scan carry (`SimResult.latency_hist` /
    `latency_p50/p99/p999`); 0 (the default) compiles the exact
    histogram-free program.

    `vcs=V` (> 1) switches to the credit-flow VIRTUAL-CHANNEL router:
    (N, 2n, V, queue) lanes per port, downstream credit counters in the
    scan carry, lanes 1..V−1 credit-gated minimal-adaptive and lane 0
    the restricted-DOR escape lane (deadlock-free by CDG acyclicity —
    see docs/simulator.md).  `credits` caps the per-lane window (None =
    full queue depth).  vcs=1 (default) compiles the EXACT pre-VC
    program; vcs>1 requires impl in (batched | reference) and composes
    with scenario= AND schedule= (a degenerate single-epoch schedule
    stays bitwise-equal to the static scenario VC run).

    `links` (a `repro.core.LinkSpec`) turns on heterogeneous-link
    semantics — per-dimension slot weights, pillar Z-masks, express
    overlay channels (docs/simulator.md "Heterogeneous links"); a
    trivial/None spec compiles the identical pre-heterogeneous
    program."""
    cfg = SimConfig.from_kwargs(
        config, slots=slots, warmup=warmup, queue=queue, seed=seed,
        tables=tables, impl=impl, scenario=scenario, schedule=schedule,
        hist_bins=hist_bins, vcs=vcs, credits=credits, links=links)
    t = cfg.tables or build_tables(g, cfg.seed)
    if cfg.schedule is not None:
        ctx = _make_ctx(t, g, pattern, cfg.seed, cfg.queue,
                        schedule=ensure_compiled(cfg.schedule, g,
                                                 cfg.slots, cfg.links),
                        hist_bins=cfg.hist_bins, vcs=cfg.vcs,
                        credits=cfg.credits, links=cfg.links)
    else:
        ctx = _make_ctx(t, g, pattern, cfg.seed, cfg.queue, cfg.scenario,
                        hist_bins=cfg.hist_bins, vcs=cfg.vcs,
                        credits=cfg.credits, links=cfg.links)
    runner = _get_runner(t, ctx, slots=cfg.slots, warmup=cfg.warmup,
                         impl=cfg.impl, n_loads=1)
    key = jax.random.PRNGKey(cfg.seed + 17)
    if fold is not None:
        key = jax.random.fold_in(key, fold)
    out = runner(_init_state(ctx, load, cfg.impl, cfg.slots), key)
    return _result(out, slots=cfg.slots, warmup=cfg.warmup, N=t.N)


def simulate_sweep(g: LatticeGraph, pattern: str, loads, *,
                   config: SimConfig | None = None,
                   slots: int | None = None, warmup: int | None = None,
                   queue: int | None = None, seed: int | None = None,
                   seeds=None, tables: SimTables | None = None,
                   impl: str | None = None,
                   scenario: Scenario | None = None,
                   schedule: FaultSchedule | None = None,
                   hist_bins: int | None = None, vcs: int | None = None,
                   credits: int | None = None,
                   links: LinkSpec | None = None):
    """An entire offered-load curve (Figs. 5–8) as ONE device program: the
    per-slot update is vmapped over the load axis and — when `seeds` is
    given — over a nested seed axis, so the whole sweep JITs once and runs
    without host round-trips between runs.  Run-shaping parameters come
    from `config=` (a `SimConfig`) or the legacy kwargs (not both —
    `SimConfig.from_kwargs` raises on conflicts); `seeds` stays a
    per-call argument (it names the replication axis, not the router).

    seeds=None returns list[SimResult] (one per load; run ℓ uses
    `fold_in(PRNGKey(seed+17), ℓ)`, so distinct sweep points are
    decorrelated).  seeds=k (int) uses base seeds [seed, …, seed+k−1],
    seeds=[…] uses them verbatim; both return a `SweepStats` whose
    seed-axis slice s is bitwise-identical to the single-seed sweep with
    seed=seeds[s].  A single-load, single-seed sweep delegates to
    `simulate` (same key, pre-PR-3 compatible)."""
    cfg = SimConfig.from_kwargs(
        config, slots=slots, warmup=warmup, queue=queue, seed=seed,
        tables=tables, impl=impl, scenario=scenario, schedule=schedule,
        hist_bins=hist_bins, vcs=vcs, credits=credits, links=links)
    loads = [float(l) for l in np.asarray(loads).ravel()]
    sl = _seed_list(cfg.seed, seeds)
    if sl is None and len(loads) == 1:
        return [simulate(g, pattern, loads[0], config=cfg)]
    runner, state, keys, t, _ = _sweep_plan(
        g, pattern, loads, slots=cfg.slots, warmup=cfg.warmup,
        queue=cfg.queue, seed=cfg.seed, seed_list=sl, tables=cfg.tables,
        impl=cfg.impl, scenario=cfg.scenario,
        schedules=(None if cfg.schedule is None
                   else [ensure_compiled(cfg.schedule, g, cfg.slots,
                                         cfg.links)]),
        hist_bins=cfg.hist_bins, vcs=cfg.vcs, credits=cfg.credits,
        links=cfg.links)
    out = runner(state, keys)
    L, S = len(loads), len(sl or [cfg.seed])
    res = _result_grid(out, (L, S), cfg.impl, slots=cfg.slots,
                       warmup=cfg.warmup, N=t.N)
    if sl is None:
        return [res[li, 0] for li in range(L)]
    return SweepStats(loads=tuple(loads), seeds=tuple(sl),
                      results=tuple(tuple(row) for row in res))


def simulate_scenario_sweep(g: LatticeGraph, pattern: str, scenarios,
                            loads=(0.6,), *,
                            config: SimConfig | None = None,
                            slots: int | None = None,
                            warmup: int | None = None,
                            queue: int | None = None,
                            seed: int | None = None, seeds=None,
                            tables: SimTables | None = None,
                            impl: str | None = None,
                            hist_bins: int | None = None,
                            vcs: int | None = None,
                            credits: int | None = None,
                            links: LinkSpec | None = None):
    """K fault patterns × (loads × seeds) as ONE device program: the
    scenario masks are traced state inputs, so the compiled slot update is
    vmapped over an outermost scenario axis — K patterns cost one trace
    and one compile (pre-PR-4 each pattern was baked into its own
    program and re-compiled).

    All *faulted* scenarios must share the routing policy and
    dead-node-ness (both shape the compiled program); `None`/pristine
    entries mean the fault-free baseline — they adopt the sweep's policy
    (with all channels live every policy routes the DOR minimal port, so
    the baseline lane is policy-independent) and, in a dead-node sweep,
    the dead-node program structure (live-table sampling over all N
    nodes), riding the same traced-mask program with all-live masks.  The PRNG key grid is
    shared across scenarios (common random numbers: result differences
    between patterns are fault effects, not sampling noise), so scenario
    k's results are bitwise-equal to the single-scenario sweep with the
    same loads/seeds.

    Returns a list of length K mirroring `simulate_sweep`'s return for
    each scenario: list[SimResult] per load when `seeds is None`, else a
    `SweepStats`."""
    cfg = SimConfig.from_kwargs(
        config, slots=slots, warmup=warmup, queue=queue, seed=seed,
        tables=tables, impl=impl, hist_bins=hist_bins, vcs=vcs,
        credits=credits, links=links)
    if cfg.scenario is not None or cfg.schedule is not None:
        raise ValueError(
            "simulate_scenario_sweep takes its fault patterns from the "
            "`scenarios` list; leave config.scenario/config.schedule unset")
    scenarios = [s if s is not None else Scenario() for s in scenarios]
    if not scenarios:
        raise ValueError("simulate_scenario_sweep needs >= 1 scenario")
    if cfg.impl not in ("batched", "fused"):
        raise ValueError(
            "simulate_scenario_sweep needs a traced-mask implementation "
            f"(batched | fused), got {cfg.impl!r}")
    policies = sorted({s.policy for s in scenarios if not s.is_trivial})
    if len(policies) > 1:
        raise ValueError(
            f"scenario sweep mixes routing policies {policies}; the policy "
            "shapes the compiled program — sweep each policy separately")
    if policies and policies[0] != "dor":
        # pristine lanes adopt the sweep policy (equivalent routing on an
        # all-live graph) so [None, faulted-adaptive, ...] just works
        scenarios = [s.with_policy(policies[0]) if s.is_trivial else s
                     for s in scenarios]
    faulted = [s for s in scenarios if s.dead_links or s.dead_nodes]
    if len({bool(s.dead_nodes) for s in faulted}) > 1:
        raise ValueError(
            "scenario sweep mixes dead-node and link-only fault patterns; "
            "destination sampling differs structurally — sweep separately")
    loads = [float(l) for l in np.asarray(loads).ravel()]
    sl = _seed_list(cfg.seed, seeds)
    runner, state, keys, t, _ = _sweep_plan(
        g, pattern, loads, slots=cfg.slots, warmup=cfg.warmup,
        queue=cfg.queue, seed=cfg.seed, seed_list=sl, tables=cfg.tables,
        impl=cfg.impl, scenario=None, scenarios=scenarios,
        hist_bins=cfg.hist_bins, vcs=cfg.vcs, credits=cfg.credits,
        links=cfg.links)
    out = runner(state, keys)
    K, L, S = len(scenarios), len(loads), len(sl or [cfg.seed])
    res = _result_grid(out, (K, L, S), cfg.impl, slots=cfg.slots,
                       warmup=cfg.warmup, N=t.N)
    results = []
    for ki in range(K):
        if sl is None:
            results.append([res[ki, li, 0] for li in range(L)])
        else:
            results.append(SweepStats(
                loads=tuple(loads), seeds=tuple(sl),
                results=tuple(tuple(row) for row in res[ki])))
    return results


def simulate_schedule_sweep(g: LatticeGraph, pattern: str, schedules,
                            loads=(0.6,), *,
                            config: SimConfig | None = None,
                            slots: int | None = None,
                            warmup: int | None = None,
                            queue: int | None = None,
                            seed: int | None = None, seeds=None,
                            tables: SimTables | None = None,
                            impl: str | None = None,
                            hist_bins: int | None = None,
                            vcs: int | None = None,
                            credits: int | None = None,
                            links: LinkSpec | None = None):
    """K transient-fault TIMELINES × (loads × seeds) as ONE device
    program — `simulate_scenario_sweep` generalized along the time axis.
    Each schedule compiles to per-epoch mask stacks + a slot→epoch map;
    stacks are padded to the sweep-wide maximum epoch count (padded
    epochs are unreachable) so all K lanes share one trace and one
    compile, and the slot→epoch maps ride the outermost vmap axis as
    traced inputs.

    Entries may be `FaultSchedule`s, static `Scenario`s (wrapped as
    degenerate single-epoch schedules) or `None` (the pristine baseline
    lane).  All lanes must share the routing policy (pristine/static-DOR
    lanes adopt the sweep's policy, which routes identically on an
    all-live graph); dead-node-ness is unified structurally — any lane
    with a node death anywhere in its timeline switches the whole sweep
    to live-table destination sampling.

    The PRNG key grid is shared across lanes (common random numbers), so
    lane k is bitwise-equal to the single-schedule sweep with the same
    loads/seeds, and a lane whose schedule is a degenerate single-epoch
    timeline is bitwise-equal to the STATIC `Scenario` run.  Returns a
    list of length K mirroring `simulate_sweep`'s return; every
    `SimResult` carries its per-slot `SimTimeline`."""
    cfg = SimConfig.from_kwargs(
        config, slots=slots, warmup=warmup, queue=queue, seed=seed,
        tables=tables, impl=impl, hist_bins=hist_bins, vcs=vcs,
        credits=credits, links=links)
    if cfg.scenario is not None or cfg.schedule is not None:
        raise ValueError(
            "simulate_schedule_sweep takes its timelines from the "
            "`schedules` list; leave config.scenario/config.schedule unset")
    schedules = [s if isinstance(s, FaultSchedule)
                 else FaultSchedule.from_scenario(s) for s in schedules]
    if not schedules:
        raise ValueError("simulate_schedule_sweep needs >= 1 schedule")
    if cfg.impl not in ("batched", "fused"):
        raise ValueError(
            "simulate_schedule_sweep needs a traced-mask implementation "
            f"(batched | fused), got {cfg.impl!r}")
    policies = sorted({s.policy for s in schedules
                       if not (s.is_static and s.base.is_trivial)})
    if len(policies) > 1:
        raise ValueError(
            f"schedule sweep mixes routing policies {policies}; the policy "
            "shapes the compiled program — sweep each policy separately")
    if policies and policies[0] != "dor":
        schedules = [s.with_policy(policies[0])
                     if s.is_static and s.base.is_trivial else s
                     for s in schedules]
    loads = [float(l) for l in np.asarray(loads).ravel()]
    sl = _seed_list(cfg.seed, seeds)
    compiled = [ensure_compiled(s, g, cfg.slots, cfg.links)
                for s in schedules]
    runner, state, keys, t, _ = _sweep_plan(
        g, pattern, loads, slots=cfg.slots, warmup=cfg.warmup,
        queue=cfg.queue, seed=cfg.seed, seed_list=sl, tables=cfg.tables,
        impl=cfg.impl, scenario=None, schedules=compiled,
        hist_bins=cfg.hist_bins, vcs=cfg.vcs, credits=cfg.credits,
        links=cfg.links)
    out = runner(state, keys)
    K, L, S = len(compiled), len(loads), len(sl or [cfg.seed])
    res = _result_grid(out, (K, L, S), cfg.impl, slots=cfg.slots,
                       warmup=cfg.warmup, N=t.N)
    results = []
    for ki in range(K):
        if sl is None:
            results.append([res[ki, li, 0] for li in range(L)])
        else:
            results.append(SweepStats(
                loads=tuple(loads), seeds=tuple(sl),
                results=tuple(tuple(row) for row in res[ki])))
    return results


def simulate_load_sweep(g: LatticeGraph, pattern: str, loads, **kw):
    """DEPRECATED pre-PR-3 alias of `simulate_sweep` — identical
    signature and return; new code should call `simulate_sweep` (or pass
    a `SimConfig` via `config=`) directly."""
    warnings.warn(
        "simulate_load_sweep is deprecated; call simulate_sweep (same "
        "arguments) or pass a SimConfig via config=",
        DeprecationWarning, stacklevel=2)
    return simulate_sweep(g, pattern, loads, **kw)


# backwards-compatible name (pre-sweep API); deprecated like the alias
throughput_curve = simulate_load_sweep


def peak_throughput(g: LatticeGraph, pattern: str, loads=None, **kw):
    """Max accepted load over an offered-load sweep (the paper's
    'throughput peak')."""
    loads = loads if loads is not None else np.linspace(0.1, 1.0, 10)
    res = simulate_sweep(g, pattern, loads, **kw)
    best = max(res, key=lambda r: r.accepted_load)
    return best, res


def reference_latency_samples(g: LatticeGraph, pattern: str, load: float,
                              *, slots: int = 512, warmup: int = 128,
                              queue: int = 4, seed: int = 0,
                              tables: SimTables | None = None,
                              scenario: Scenario | None = None,
                              hist_bins: int = 0, vcs: int = 1,
                              credits: int | None = None,
                              links: LinkSpec | None = None):
    """The per-packet latency ORACLE: one reference-impl run that, on top
    of the usual counters (and histogram, when `hist_bins` is given),
    records every delivery's exact age in slots.  Returns
    ``(SimResult, samples)`` where ``samples`` holds two sorted int
    arrays of per-packet ages:

      * ``measured`` — deliveries of packets born at/after warmup (the
        population `lat_sum`/`lat_cnt`/`latency_hist` count), and
      * ``window``  — deliveries at slots ≥ warmup regardless of birth
        (the pre-fix biased population, kept so the warmup-bias
        regression test can demonstrate the difference).

    The run uses the same PRNG key derivation as `simulate(...,
    impl="reference")`, so the samples describe exactly that run —
    percentile accessors are validated cycle-exactly against them.
    Test-scale only: the trace is a (slots, N, 2n) device→host transfer.
    """
    t = tables or build_tables(g, seed)
    ctx = _make_ctx(t, g, pattern, seed, queue, scenario,
                    hist_bins=hist_bins, lat_trace=True, vcs=vcs,
                    credits=credits, links=links)
    runner = _get_runner(t, ctx, slots=slots, warmup=warmup,
                         impl="reference", n_loads=1)
    out = dict(runner(_init_state(ctx, load, "reference", slots),
                      jax.random.PRNGKey(seed + 17)))
    tr = out.pop("lat_trace")
    res = _result(out, slots=slots, warmup=warmup, N=t.N)
    age = np.asarray(tr["age"])                        # (slots, N, P)
    deliv = np.asarray(tr["deliv"]).astype(bool)
    # `meas` is the counted flag from the slot step itself (birth >= warmup
    # at delivery).  It can't be reconstructed host-side as slot+1−age:
    # weighted links fold their +w−1 crossing cost into the age, which
    # would shift reconstructed births across the warmup boundary.
    meas = np.asarray(tr["meas"]).astype(bool)
    slot_idx = np.arange(slots)[:, None, None]
    samples = dict(
        measured=np.sort(age[meas]),
        window=np.sort(age[deliv & (slot_idx >= warmup)]))
    return res, samples


def schedule_recovery_slots(result: SimResult, schedule: FaultSchedule,
                            *, q: float = 0.99, window: int = 64,
                            slack_cycles: float = 0.0) -> int | None:
    """Recovery time of a transient-fault run: slots from the schedule's
    LAST repair event until the windowed q-th latency percentile returns
    to its pre-fault baseline (see `SimTimeline.recovery_slots`).  The
    fault onset is the schedule's first ``*_down`` event, the repair its
    last ``*_up`` event; `result` must come from a `schedule=` run with
    `hist_bins` enabled.  Returns None when the tail never recovers
    inside the run."""
    downs = [s for s, kind, _ in schedule.events if kind.endswith("_down")]
    ups = [s for s, kind, _ in schedule.events if kind.endswith("_up")]
    if not downs or not ups:
        raise ValueError(
            "schedule needs at least one *_down and one *_up event to "
            f"define a fault/repair pair, got events={schedule.events!r}")
    if result.timeline is None:
        raise ValueError("result has no timeline — run with schedule=")
    return result.timeline.recovery_slots(
        min(downs), max(ups), q=q, window=window,
        slack_cycles=slack_cycles)
