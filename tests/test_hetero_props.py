"""Property tests for heterogeneous links (ISSUE 8, satellite 3).

Runs under the offline `tests/_propcheck.py` shim (only `integers`,
`sampled_from`, `@given`, `@settings` from the shimmed subset), so the
properties hold in the hypothesis-free CI image too.

The three properties:

  * ``delivered + in_flight + dropped == injected`` at EVERY slot
    (warmup=0) under random mixed-weight links composed with a random
    `FaultSchedule` link flap — the weighted multi-slot channel hold
    must never mint or lose a packet, even while links die and revive;
  * a pillar mask means ZERO crossings of the masked channels, whatever
    the routing policy — structural holes are dead links to the audit;
  * no delivery is faster than physics: the minimum occupied latency
    bucket is at least the weighted routed distance + 1 injection slot.

Weight/flap values are drawn from small grids so the property run
compiles a handful of programs, not one per example.
"""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (FaultSchedule, LinkSpec, Scenario, SimConfig, Torus,
                        weighted_distance_matrix)
from repro.core.simulation import build_tables, simulate

G = Torus(4, 4)
TABLES = build_tables(G)
G3 = Torus(4, 4, 4)
TABLES3 = build_tables(G3)


@settings(max_examples=10)
@given(w0=st.sampled_from([1, 2, 3]), w1=st.sampled_from([1, 2, 3]),
       down=st.sampled_from([8, 16, 24]), up=st.sampled_from([40, 56]),
       policy=st.sampled_from(["dor", "adaptive"]),
       seed=st.integers(min_value=0, max_value=3))
def test_conservation_every_slot_weights_times_schedule(w0, w1, down, up,
                                                        policy, seed):
    sched = FaultSchedule.link_flap((1, 0), down_at=down, up_at=up,
                                    policy=policy)
    r = simulate(G, "uniform", 0.6,
                 config=SimConfig(slots=72, warmup=0, seed=seed,
                                  links=LinkSpec(dim_weights=(w0, w1)),
                                  schedule=sched, tables=TABLES))
    tl = r.timeline
    assert tl is not None
    assert tl.conservation_ok(), (w0, w1, down, up, policy,
                                  tl.conservation_violations())
    assert tl.dead_crossings.sum() == 0
    assert tl.delivered[-1] == r.delivered
    assert tl.injected[-1] == r.injected


@settings(max_examples=8)
@given(every=st.sampled_from([2, 4]),
       policy=st.sampled_from(["dor", "adaptive"]),
       seed=st.integers(min_value=0, max_value=3))
def test_pillar_channels_never_crossed(every, policy, seed):
    ls = LinkSpec(pillar_dim=2, pillar_every=every)
    r = simulate(G3, "uniform", 0.4,
                 config=SimConfig(slots=64, warmup=0, seed=seed, links=ls,
                                  scenario=Scenario(policy=policy),
                                  tables=TABLES3))
    assert r.delivered + r.in_flight + r.dropped == r.injected
    mask = ls.structural_mask(G3)
    assert r.link_use is not None
    assert int(r.link_use[~mask].sum()) == 0, (every, policy, seed)


@settings(max_examples=8)
@given(w0=st.sampled_from([1, 2]), w1=st.sampled_from([2, 3]),
       seed=st.integers(min_value=0, max_value=3),
       impl=st.sampled_from(["batched", "reference"]))
def test_min_latency_bucket_respects_weighted_distance(w0, w1, seed, impl):
    ls = LinkSpec(dim_weights=(w0, w1))
    r = simulate(G, "uniform", 0.3,
                 config=SimConfig(slots=96, warmup=16, seed=seed, impl=impl,
                                  links=ls, hist_bins=98, tables=TABLES))
    hist = np.asarray(r.latency_hist)
    assert hist.sum() > 0
    d = weighted_distance_matrix(G, ls)
    min_age = int(np.nonzero(hist)[0][0])
    assert min_age >= int(d[d > 0].min()) + 1, (w0, w1, seed, impl, min_age)
