"""Scenario-engine throughput: faulted/adaptive simulation vs the
fault-free batched baseline, plus the multi-seed sweep cost.

The acceptance bar (ISSUE 3): at N=4096 a faulted adaptive-routing run
must stay within 2× of the fault-free batched path — faults and policies
enter the compiled slot update as masks/tables only, so the overhead is
a handful of extra fused elementwise ops, not a different program shape.
Quick mode shrinks to N=512 for CI smoke; emitted `slots_per_s` /
`loadpoints_per_s` metrics are gated by `make bench-check`.
"""
from __future__ import annotations

import time

from repro.core import Scenario, Torus
from repro.core.simulation import build_tables, simulate, simulate_sweep

from .util import emit

REPS = 3


def main(quick: bool = False) -> None:
    g = Torus(8, 8, 4, 2) if quick else Torus(8, 8, 8, 8)
    slots = 192 if quick else 512
    warmup = 48 if quick else 128
    t = build_tables(g)
    scen = Scenario.random_link_faults(g, 8, seed=5, policy="adaptive")

    def run(scenario):
        return simulate(g, "uniform", 0.6, slots=slots, warmup=warmup,
                        seed=1, tables=t, scenario=scenario)

    # compile both, then alternate (fair under machine noise)
    run(None)
    run(scen)
    best = {"fault_free": float("inf"), "faulted_adaptive": float("inf")}
    for _ in range(REPS):
        for name, s in (("fault_free", None), ("faulted_adaptive", scen)):
            t0 = time.perf_counter()
            run(s)
            best[name] = min(best[name], time.perf_counter() - t0)
    for name in best:
        emit(f"scenarios/{name}/N={g.order}", best[name] * 1e6,
             f"slots_per_s={slots / best[name]:.1f};slots={slots}")
    emit(f"scenarios/overhead/N={g.order}", 0.0,
         f"overhead={best['faulted_adaptive'] / best['fault_free']:.2f}x")

    # multi-seed sweep: (loads × seeds) error-bar program, cost per run
    loads, seeds = (0.3, 0.6, 1.0), 2
    kw = dict(slots=slots, warmup=warmup, seed=1, seeds=seeds, tables=t,
              scenario=scen)
    simulate_sweep(g, "uniform", loads, **kw)          # compile
    best_sweep = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        simulate_sweep(g, "uniform", loads, **kw)
        best_sweep = min(best_sweep, time.perf_counter() - t0)
    runs = len(loads) * seeds
    emit(f"scenarios/sweep{len(loads)}x{seeds}/N={g.order}",
         best_sweep * 1e6,
         f"scenario_loadpoints_per_s={runs / best_sweep:.2f};"
         f"per_run_s={best_sweep / runs:.2f}")


if __name__ == "__main__":
    main()
