"""Zamba2-1.2B [arXiv:2411.15242]: Mamba2 backbone + one shared attention
block reused every 6 layers."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_000,
    ssm=SSMConfig(state_size=64),
    hybrid_attn_period=6,
)
