"""Beyond-paper integration: collective cost on crystal pods vs mixed tori
(the DESIGN.md §2 adaptation) + logical-mesh placement dilations."""
from __future__ import annotations

import time

from repro.core import BCC, FCC, PC, Torus
from repro.topology.collective_model import analyze_pod
from repro.topology.placement import best_embedding
from repro.topology.upgrade import migration_stats, upgrade_plan

from .util import emit


def main(quick: bool = False) -> None:
    pods = [("BCC4_256", BCC(4), None), ("T_8_8_4", Torus(8, 8, 4), (8, 8, 4)),
            ("PC8_512", PC(8), None), ("T_16_8_4", Torus(16, 8, 4), (16, 8, 4))]
    if not quick:
        pods += [("FCC8_1024", FCC(8), None),
                 ("T_16_8_8", Torus(16, 8, 8), (16, 8, 8))]
    for name, g, ts in pods:
        t0 = time.perf_counter()
        r = analyze_pod(name, g, ts)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"pod/{name}", us,
             f"D={r.diameter};kbar={r.avg_distance:.3f};"
             f"capacity={r.uniform_capacity:.3f};"
             f"alltoall_256MB_ms={r.alltoall_256MB_ms:.2f}")
    t0 = time.perf_counter()
    be = best_embedding(BCC(4), (16, 16))
    us = (time.perf_counter() - t0) * 1e6
    emit("placement/BCC4_16x16", us,
         f"embedding={be['embedding'].name};"
         f"dil0={be['axis0']['avg']:.2f};dil1={be['axis1']['avg']:.2f}")
    for chips in (256, 512):
        t0 = time.perf_counter()
        st = migration_stats(upgrade_plan(chips))
        us = (time.perf_counter() - t0) * 1e6
        emit(f"upgrade/{chips}to{chips*2}", us,
             f"fresh={st['fresh_chips']};avg_hops={st['avg_hops']:.2f};"
             f"max_hops={st['max_hops']}")


if __name__ == "__main__":
    main()
