"""Paper Table 1: distance properties of cubic crystal graphs vs mixed tori.

Each row is computed twice — BFS (`LatticeGraph`) and the batched routing
engine (norms of all-pairs minimal records) — and both are checked against
the closed forms.  The `engine` flag in the derived column records the
BFS↔engine agreement; `us_per_call` is the engine's warmed all-pairs time
(jit compile excluded — see benchmarks/routing_throughput.py for the
records/sec story)."""
from __future__ import annotations

import time

from repro.core import (BCC, FCC, PC, Torus, bcc_average_distance,
                        bcc_diameter, fcc_average_distance, fcc_diameter,
                        mixed_torus_diameter, pc_average_distance,
                        pc_diameter, torus_average_distance)
from repro.core import make_router
from repro.core.distances import (routed_average_distance, routed_diameter,
                                  routed_distance_profile)

from .util import emit


def main(quick: bool = False) -> None:
    sides = (4, 6, 8) if quick else (4, 6, 8, 10, 12)
    for a in sides:
        rows = [
            (f"PC({a})", PC(a), pc_diameter(a), pc_average_distance(a)),
            (f"T({2*a},{a},{a})", Torus(2 * a, a, a),
             mixed_torus_diameter(2 * a, a, a),
             torus_average_distance(2 * a, a, a)),
            (f"FCC({a})", FCC(a), fcc_diameter(a), fcc_average_distance(a)),
            (f"T({2*a},{2*a},{a})", Torus(2 * a, 2 * a, a),
             mixed_torus_diameter(2 * a, 2 * a, a),
             torus_average_distance(2 * a, 2 * a, a)),
            (f"BCC({a})", BCC(a), bcc_diameter(a), bcc_average_distance(a)),
        ]
        for name, g, d_pred, k_pred in rows:
            d, k = g.diameter, g.average_distance
            router = make_router(g.matrix)
            routed_distance_profile(g, router=router)    # warm the jit
            t0 = time.perf_counter()
            hist = routed_distance_profile(g, router=router)
            us = (time.perf_counter() - t0) * 1e6
            d_eng = routed_diameter(g, profile=hist)
            k_eng = routed_average_distance(g, profile=hist)
            ok = (d == d_pred) and abs(k - k_pred) < 1e-9
            eng_ok = (d_eng == d) and abs(k_eng - k) < 1e-9
            emit(f"table1/{name}", us,
                 f"N={g.order};D={d};kbar={k:.5f};matches_formula={ok};"
                 f"engine={eng_ok}")


if __name__ == "__main__":
    main()
