"""Offline drop-in for the slice of the `hypothesis` API this repo uses.

The test environment may not be able to install `hypothesis` (no network).
`conftest.py` imports the real package when present; otherwise it installs
the fake modules built here under the names ``hypothesis`` and
``hypothesis.strategies`` *before* test collection, so the six
property-test modules import unchanged.

Semantics of the replacement:

  * each strategy samples **deterministically** from a numpy Generator
    seeded per-test (crc32 of the test's qualified name), so failures are
    reproducible run-to-run and machine-to-machine;
  * ``@given`` runs up to ``DEFAULT_EXAMPLES`` (50) examples per test —
    ``@settings(max_examples=...)`` is honoured but capped at 50 to keep
    offline CI fast (real hypothesis, when installed, uses the full count);
  * ``.filter`` is rejection sampling with a bounded retry budget;
  * on a failing example the falsifying inputs are printed to stderr and
    the original exception propagates (no shrinking).

Only the strategies the test-suite actually uses are provided
(`integers`, `lists`, `sets`, `sampled_from`, `booleans`, `floats`,
`tuples`, `just`, `one_of`); extend as tests grow.
"""
from __future__ import annotations

import inspect
import sys
import types
import zlib

import numpy as np

DEFAULT_EXAMPLES = 50
_FILTER_TRIES = 5000


class Strategy:
    """A deterministic sampler: `example(rng)` draws one value."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)

    def map(self, f) -> "Strategy":
        return Strategy(lambda rng: f(self._draw(rng)))

    def filter(self, pred) -> "Strategy":
        def draw(rng):
            for _ in range(_FILTER_TRIES):
                x = self._draw(rng)
                if pred(x):
                    return x
            raise RuntimeError(
                "propcheck: .filter predicate rejected "
                f"{_FILTER_TRIES} consecutive samples")
        return Strategy(draw)

    def flatmap(self, f) -> "Strategy":
        return Strategy(lambda rng: f(self._draw(rng))._draw(rng))


# -- strategies -------------------------------------------------------------

def integers(min_value=None, max_value=None) -> Strategy:
    lo = -(2**31) if min_value is None else int(min_value)
    hi = 2**31 - 1 if max_value is None else int(max_value)
    return Strategy(lambda rng: int(rng.integers(lo, hi + 1)))


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.integers(0, 2)))


def floats(min_value=-1e6, max_value=1e6, allow_nan=False,
           allow_infinity=False) -> Strategy:
    lo = -1e6 if min_value is None else float(min_value)
    hi = 1e6 if max_value is None else float(max_value)
    return Strategy(lambda rng: float(rng.uniform(lo, hi)))


def sampled_from(elements) -> Strategy:
    elems = list(elements)
    if not elems:
        raise ValueError("sampled_from requires a non-empty sequence")
    return Strategy(lambda rng: elems[int(rng.integers(0, len(elems)))])


def lists(elements: Strategy, min_size: int = 0,
          max_size: int | None = None, unique=False) -> Strategy:
    mx = min_size + 10 if max_size is None else max_size

    def draw(rng):
        size = int(rng.integers(min_size, mx + 1))
        if not unique:
            return [elements._draw(rng) for _ in range(size)]
        out: list = []
        for _ in range(_FILTER_TRIES):
            x = elements._draw(rng)
            if x not in out:
                out.append(x)
            if len(out) == size:
                break
        if len(out) < min_size:
            raise RuntimeError(
                f"propcheck: could not draw {min_size} unique elements "
                f"in {_FILTER_TRIES} tries (domain too small?)")
        return out
    return Strategy(draw)


def sets(elements: Strategy, min_size: int = 0,
         max_size: int | None = None) -> Strategy:
    return lists(elements, min_size, max_size, unique=True).map(set)


def tuples(*strategies: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(s._draw(rng) for s in strategies))


def just(value) -> Strategy:
    return Strategy(lambda rng: value)


def one_of(*strategies: Strategy) -> Strategy:
    if len(strategies) == 1 and isinstance(strategies[0], (list, tuple)):
        strategies = tuple(strategies[0])
    return Strategy(
        lambda rng: strategies[int(rng.integers(0, len(strategies)))]._draw(rng))


# -- given / settings / assume ----------------------------------------------

class _Unsatisfied(Exception):
    """Raised by assume(False): skip this example, draw another."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied
    return True


def settings(max_examples: int = DEFAULT_EXAMPLES, deadline=None, **_ignored):
    """Decorator recording per-test options (only max_examples matters)."""
    def deco(f):
        opts = dict(getattr(f, "_propcheck_settings", {}))
        opts["max_examples"] = max_examples
        f._propcheck_settings = opts
        return f
    return deco


def given(*arg_strategies: Strategy, **kw_strategies: Strategy):
    """Drop-in @given: runs the test body over deterministic samples."""
    def deco(f):
        def runner(*fixture_args, **fixture_kwargs):
            opts = getattr(runner, "_propcheck_settings", {})
            n = min(opts.get("max_examples", DEFAULT_EXAMPLES),
                    DEFAULT_EXAMPLES)
            seed = zlib.crc32(f.__qualname__.encode())
            rng = np.random.default_rng(seed)
            done = 0
            budget = n * 20
            while done < n and budget > 0:
                budget -= 1
                try:
                    ex = [s.example(rng) for s in arg_strategies]
                    kwex = {k: s.example(rng)
                            for k, s in kw_strategies.items()}
                except _Unsatisfied:
                    continue
                try:
                    f(*fixture_args, *ex, **kwex, **fixture_kwargs)
                except _Unsatisfied:
                    continue
                except BaseException:
                    sys.stderr.write(
                        f"\npropcheck: falsifying example #{done} of "
                        f"{f.__qualname__}: args={ex!r} kwargs={kwex!r} "
                        f"(seed={seed})\n")
                    raise
                done += 1
            if done < n:
                raise RuntimeError(
                    f"propcheck: assume() rejected too many examples "
                    f"in {f.__qualname__} ({done}/{n} ran)")

        runner.__name__ = f.__name__
        runner.__qualname__ = f.__qualname__
        runner.__doc__ = f.__doc__
        runner.__module__ = f.__module__
        runner._propcheck_settings = dict(
            getattr(f, "_propcheck_settings", {}))
        runner.hypothesis = types.SimpleNamespace(inner_test=f)
        # hide the strategy parameters from pytest's fixture resolution
        runner.__signature__ = inspect.Signature(parameters=[])
        return runner
    return deco


# -- fake module assembly ----------------------------------------------------

def build_modules() -> tuple[types.ModuleType, types.ModuleType]:
    """Create module objects mimicking `hypothesis` and
    `hypothesis.strategies` (register them in sys.modules yourself)."""
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "floats", "sampled_from", "lists",
                 "sets", "tuples", "just", "one_of"):
        setattr(st_mod, name, globals()[name])
    st_mod.SearchStrategy = Strategy

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = st_mod
    hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    hyp.__version__ = "0.propcheck"
    hyp.__propcheck__ = True
    return hyp, st_mod


def install() -> bool:
    """Register the fakes in sys.modules if hypothesis is absent.
    Returns True when the shim was installed."""
    try:
        import hypothesis  # noqa: F401 — real package wins
        return False
    except ImportError:
        pass
    hyp, st_mod = build_modules()
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
    return True
