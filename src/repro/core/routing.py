"""Minimal routing in lattice graphs (paper §5) — numpy reference oracle.

Implements:
  * Algorithm 3 — routing in RTT(a)                (`route_rtt`)
  * Algorithm 2 — routing in FCC(a)                (`route_fcc`)
  * Algorithm 4 — routing in BCC(a)                (`route_bcc`)
  * Algorithm 1 — generic hierarchical routing     (`HierarchicalRouter`)
  * a brute-force CVP oracle for tests             (`minimal_record_bruteforce`)
  * the backend dispatcher                         (`make_router`)

All routers are batched: they take (..., n) integer arrays of differences
v = v_d − v_s and return minimum-Minkowski-norm routing records r with
r ≡ v (mod M).  Component r_i is the signed hop count in dimension i.

**Engine architecture.**  This module is the *reference oracle*: plain
numpy, host-side, written to mirror the paper's pseudocode as closely as
possible, and exercised against the exact BFS/CVP oracles in
tests/test_routing.py.  The hot path lives in `repro.core.routing_engine`:
a `jax.jit` engine that compiles `HierarchicalRouter`'s recursion into
static device tables (cycle labels + copy tables per level) and routes
whole `(B, n)` batches in a single XLA computation, tabulating all-pairs
records for pod-sized graphs.  The contract, enforced by
tests/test_routing_engine.py, is that the engine's deterministic path is
**bitwise-equal** to this module (same records, same tie policy: strict
first-minimum, half-ring ties toward +), and that its keyed path breaks
exact-norm ties with a fair coin (Remark 30).  Use `make_router` to pick a
backend; consumers (`simulation.build_tables`, `throughput.channel_load`,
`distances.routed_distance_profile`, the collective model and benchmarks)
all route through the engine and only fall back here when JAX is absent.

NOTE on the paper's Algorithm 4: as printed it contains two typos
(`ŷ := x + a(z<0)` should read `ŷ := y + a(z<0)`, and `y' := x̂ + 2a(ŷ<0)…`
should read `y' := ŷ + …`).  We implement the corrected version, which is
validated to be minimal against a BFS oracle in tests/test_routing.py.
"""
from __future__ import annotations

import warnings

import numpy as np

from . import intmat
from .lattice import LatticeGraph


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def norm1(r) -> np.ndarray:
    """Minkowski norm |r| = Σ|r_i| (path length of a record)."""
    return np.abs(np.asarray(r)).sum(axis=-1)


def route_ring(a: int, d, rng: np.random.Generator | None = None) -> np.ndarray:
    """Signed shortest hop count in a ring of size a.  For even a the
    half-way distance has two minimal directions; ties are broken toward +
    unless an rng is given (Remark 30: randomize to balance link usage)."""
    d = np.asarray(d, dtype=np.int64)
    r = np.mod(d, a)
    r = np.where(r > a // 2, r - a, r)
    if rng is not None and a % 2 == 0:
        flip = (r == a // 2) & (rng.random(r.shape) < 0.5)
        r = np.where(flip, r - a, r)
    return r


def route_torus(sides, v, rng: np.random.Generator | None = None) -> np.ndarray:
    """Per-dimension ring routing (DOR components) in T(sides)."""
    v = np.asarray(v, dtype=np.int64)
    out = np.empty_like(v)
    for i, a in enumerate(sides):
        out[..., i] = route_ring(int(a), v[..., i], rng)
    return out


# ---------------------------------------------------------------------------
# Algorithm 3: RTT(a) = G([[2a, a], [0, a]])
# ---------------------------------------------------------------------------

def route_rtt(a: int, v) -> np.ndarray:
    """Minimal routing record in the rectangular twisted torus RTT(a)."""
    v = np.asarray(v, dtype=np.int64)
    x, y = v[..., 0], v[..., 1]
    p = np.mod(x + y + a, 2 * a)
    q = np.mod(y - x + a, 2 * a)
    xo = (p - q) // 2
    yo = (p + q - 2 * a) // 2
    return np.stack([xo, yo], axis=-1)


# ---------------------------------------------------------------------------
# Algorithm 2: FCC(a) = G([[2a, a, a], [0, a, 0], [0, 0, a]])
# ---------------------------------------------------------------------------

def route_fcc(a: int, v, rng: np.random.Generator | None = None) -> np.ndarray:
    """Minimal routing record in FCC(a) via two RTT(a) sub-routes."""
    v = np.asarray(v, dtype=np.int64)
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    yneg, zneg = y < 0, z < 0
    y1 = y + a * yneg
    z1 = z + a * zneg
    xh = x + a * (yneg ^ zneg)
    x1 = xh + 2 * a * (xh < 0) - 2 * a * (xh >= 2 * a)
    # (x1, y1, z1) is now in the labelling box L
    xy = np.stack([x1, y1], axis=-1)
    r1 = route_rtt(a, xy)                                  # from (0, 0)
    r2 = route_rtt(a, xy - np.array([a, 0], dtype=np.int64))  # from (a, 0)
    c1 = np.concatenate([r1, z1[..., None]], axis=-1)
    c2 = np.concatenate([r2, (z1 - a)[..., None]], axis=-1)
    return _pick_min(c1, c2, rng)


def _pick_min(c1: np.ndarray, c2: np.ndarray,
              rng: np.random.Generator | None) -> np.ndarray:
    """Choose the lower-norm record; break exact ties randomly when an rng is
    supplied (Remark 30) to balance path usage in edge-symmetric graphs."""
    n1, n2 = norm1(c1), norm1(c2)
    pick = n2 < n1
    if rng is not None:
        tie = (n2 == n1) & (rng.random(n1.shape) < 0.5)
        pick = pick | tie
    return np.where(pick[..., None], c2, c1)


# ---------------------------------------------------------------------------
# Algorithm 4 (corrected): BCC(a) = G([[2a, 0, a], [0, 2a, a], [0, 0, a]])
# ---------------------------------------------------------------------------

def route_bcc(a: int, v, rng: np.random.Generator | None = None) -> np.ndarray:
    """Minimal routing record in BCC(a) via two T(2a, 2a) sub-routes."""
    v = np.asarray(v, dtype=np.int64)
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    zneg = z < 0
    z1 = z + a * zneg
    xh = x + a * zneg
    yh = y + a * zneg
    x1 = xh + 2 * a * (xh < 0) - 2 * a * (xh >= 2 * a)
    y1 = yh + 2 * a * (yh < 0) - 2 * a * (yh >= 2 * a)
    xy = np.stack([x1, y1], axis=-1)
    r1 = route_torus((2 * a, 2 * a), xy, rng)
    r2 = route_torus((2 * a, 2 * a), xy - np.array([a, a], dtype=np.int64), rng)
    c1 = np.concatenate([r1, z1[..., None]], axis=-1)
    c2 = np.concatenate([r2, (z1 - a)[..., None]], axis=-1)
    return _pick_min(c1, c2, rng)


# ---------------------------------------------------------------------------
# Algorithm 1: generic hierarchical routing
# ---------------------------------------------------------------------------

class HierarchicalRouter:
    """Minimal routing for *any* lattice graph G(M) (Theorem 29).

    Routing in G(M) with M ≅ [[B, c], [0, a]] is done by routing along the
    cycle generated by e_n to each of the ord(e_n)/a intersection vertices
    lying in the destination copy of G(B), plus routing inside that copy.
    The recursion bottoms out at rings / diagonal (torus) blocks.
    """

    def __init__(self, M):
        self.H = intmat.hermite_normal_form(M)
        self.n = self.H.shape[0]
        self.diag = np.diagonal(self.H).copy()
        self._is_diagonal = bool(
            np.array_equal(self.H, np.diag(self.diag)))
        if not self._is_diagonal and self.n > 1:
            self.sub = HierarchicalRouter(self.H[: self.n - 1, : self.n - 1])
            self.ord_n, self.cycle_labels, self.copy_table = \
                intmat.cycle_copy_tables(self.H)

    def __call__(self, v) -> np.ndarray:
        """v: (..., n) integer differences → minimal records (..., n)."""
        v = np.asarray(v, dtype=np.int64)
        if self._is_diagonal:
            return route_torus(self.diag.tolist(), v)
        if self.n == 1:
            return route_ring(int(self.diag[0]), v[..., 0])[..., None]
        shape = v.shape
        W = intmat.canonical_label(v.reshape(-1, self.n), self.H)
        y = W[:, self.n - 1]
        best_r = None
        best_norm = None
        for slot in range(self.copy_table.shape[1]):
            k = self.copy_table[y, slot]                  # (B,)
            c = self.cycle_labels[k]                      # (B, n)
            rproj = self.sub(W[:, : self.n - 1] - c[:, : self.n - 1])
            for kk in (k, k - self.ord_n):
                r = np.concatenate([rproj, kk[:, None]], axis=-1)
                nrm = norm1(r)
                if best_r is None:
                    best_r, best_norm = r, nrm
                else:
                    take = (nrm < best_norm)[:, None]
                    best_r = np.where(take, r, best_r)
                    best_norm = np.minimum(best_norm, nrm)
        return best_r.reshape(shape)


# ---------------------------------------------------------------------------
# brute-force oracle (exact CVP in the L1 metric)
# ---------------------------------------------------------------------------

def minimal_record_bruteforce(M, v, box: int | None = None, *,
                              max_box: int | None = None) -> np.ndarray:
    """argmin_{r ≡ v (mod M)} |r|  by enumerating r = v − M·u over a box of
    lattice coefficients u.  Exact when the box is large enough; the default
    bound is derived from ‖M⁻¹‖ and |v| so that every record with
    |r| ≤ |v| is covered (u = 0 always gives the candidate r = v).

    The derived box grows with |v|, and the enumeration is (2·box+1)ⁿ — for
    large differences this is expensive but *correct*.  Pass `max_box` to
    opt into clamping (a warning is emitted when it truncates the search,
    because a clamped box can return a non-minimal record)."""
    M = intmat.as_np(M)
    n = M.shape[0]
    v = np.asarray(v, dtype=np.int64)
    single = v.ndim == 1
    V = v.reshape(-1, n)
    if box is None:
        inv_norm = np.abs(np.linalg.inv(M.astype(np.float64))).sum(axis=1).max()
        box = int(np.ceil(inv_norm * 2 * np.abs(V).sum(axis=-1).max())) + 1
        if max_box is not None and box > max_box:
            warnings.warn(
                f"minimal_record_bruteforce: clamping coefficient box "
                f"{box} → {max_box}; the result may be non-minimal for "
                f"|v| this large", stacklevel=2)
            box = max_box
    rng = np.arange(-box, box + 1)
    grids = np.meshgrid(*([rng] * n), indexing="ij")
    U = np.stack([g.ravel() for g in grids], axis=-1)     # (K, n)
    cand = V[:, None, :] - U[None, :, :] @ M.T            # (B, K, n)
    norms = np.abs(cand).sum(axis=-1)
    idx = norms.argmin(axis=1)
    out = cand[np.arange(V.shape[0]), idx]
    return out[0] if single else out.reshape(v.shape)


# ---------------------------------------------------------------------------
# backend dispatcher
# ---------------------------------------------------------------------------

def make_router(M, backend: str = "auto"):
    """Return a batched minimal-routing callable for G(M).

    backend='jax'   → `repro.core.routing_engine.RoutingEngine` (jitted,
                      tabulated for pod-sized graphs — the hot path),
    backend='numpy' → `HierarchicalRouter` (the reference oracle),
    backend='auto'  → jax when importable, else numpy.

    Both return records identical bitwise on the deterministic path, so
    callers may treat the choice purely as a performance knob."""
    if backend not in ("auto", "jax", "numpy"):
        raise ValueError(f"unknown routing backend {backend!r}")
    if backend == "numpy":
        return HierarchicalRouter(M)
    try:
        from .routing_engine import RoutingEngine
    except ImportError:
        if backend == "jax":
            raise
        return HierarchicalRouter(M)
    return RoutingEngine(M)


# ---------------------------------------------------------------------------
# fault-aware table rebuild (scenario engine)
# ---------------------------------------------------------------------------

def fault_aware_next_hop(g: LatticeGraph, link_ok: np.ndarray,
                         node_ok: np.ndarray | None = None
                         ) -> tuple[np.ndarray, np.ndarray]:
    """All-pairs routing tables for a *degraded* graph.

    Faults break the vertex transitivity the per-delta record tables rely
    on, so the rebuild is a BFS per destination over the masked adjacency
    (host, exact integers):

      * ``dist``     — (N, N) int32, dist[u, d] = length of the shortest
        live path u → d (−1 when unreachable or an endpoint is dead),
      * ``next_hop`` — (N, N) int8, the first (lowest-index) live port
        that steps onto such a shortest path (−1 when there is none).

    `link_ok` is the (N, 2n) channel-liveness mask of
    `Scenario.link_ok` — symmetric by construction, so BFS layers expand
    over undirected live edges.  Consumers: `distances.faulted_*` and
    `throughput.fault_aware_channel_load` rebuild degraded distance
    profiles and saturation bounds from these tables.
    """
    N, P = g.order, 2 * g.n
    nbr = g.neighbor_indices
    link_ok = np.asarray(link_ok, dtype=bool)
    node_ok = (np.ones(N, dtype=bool) if node_ok is None
               else np.asarray(node_ok, dtype=bool))
    dist = np.full((N, N), -1, dtype=np.int32)
    next_hop = np.full((N, N), -1, dtype=np.int8)
    for d in np.flatnonzero(node_ok):
        dd = np.full(N, -1, dtype=np.int32)
        dd[d] = 0
        frontier = np.array([d], dtype=np.int64)
        level = 0
        while frontier.size:
            level += 1
            nxt = []
            for p in range(P):
                v = nbr[frontier, p]
                ok = link_ok[frontier, p] & node_ok[v] & (dd[v] < 0)
                nxt.append(v[ok])
            frontier = np.unique(np.concatenate(nxt))
            frontier = frontier[dd[frontier] < 0]
            dd[frontier] = level
        dist[:, d] = dd
        # first live port one step closer to d
        dn = dd[nbr]                                       # (N, P)
        cand = link_ok & (dn == (dd - 1)[:, None]) & (dn >= 0)
        cand &= (dd > 0)[:, None]
        has = cand.any(axis=1)
        next_hop[:, d] = np.where(has, cand.argmax(axis=1), -1)
    return dist, next_hop


# device multi-source BFS --------------------------------------------------

_FAULT_BFS_CACHE: dict = {}
_BFS_INF = 1 << 30


def _get_fault_bfs(N: int, P: int, with_next_hop: bool = True,
                   weights: tuple[int, ...] | None = None):
    """Compiled min-plus BFS relaxation for an (N, P)-shaped fabric:
    all-pairs distances (+ first-live-port next hops unless
    `with_next_hop=False` — the sweep path skips them) on a masked
    adjacency, iterated to the fixed point under `lax.while_loop`
    (~diameter iterations, each a batch of 2n neighbor gathers over the
    (N, N) distance front — no scatters, no host loop).

    `weights` (static per-port slot costs, heterogeneous `LinkSpec`
    fabrics) turns the relaxation min-plus over ``cand + w[p]`` — the
    fixed point is then the weighted shortest-path cost, and the
    next-hop rule becomes ``dn == dist - w[p]``.  None keeps the
    unit-cost program (same cache entry as before this axis existed)."""
    key = (N, P, with_next_hop, weights)
    if key not in _FAULT_BFS_CACHE:
        import jax
        import jax.numpy as jnp

        # per-port costs baked as Python ints: the unit-cost program is
        # literally `cand + 1`, unchanged from the pre-weighted build
        w_of = [1] * P if weights is None else [int(w) for w in weights]

        def relax(nbr, eff_ok, link_ok, src_live):
            # dist[u, d]: length of the shortest all-live path u → d.
            # eff_ok masks edges by link AND endpoint-node liveness, so a
            # relaxation step can never route through a dead node.
            eye = jnp.arange(N)[:, None] == jnp.arange(N)[None, :]
            dist0 = jnp.where(eye & src_live[:, None], 0, _BFS_INF)

            def step(carry):
                dist, _ = carry
                new = dist
                for p in range(P):      # static, 2n small
                    cand = jnp.where(eff_ok[:, p][:, None],
                                     dist[nbr[:, p]], _BFS_INF)
                    new = jnp.minimum(new, cand + w_of[p])
                return new, jnp.any(new != dist)

            dist, _ = jax.lax.while_loop(
                lambda c: c[1], step, (dist0, jnp.bool_(True)))
            out = jnp.where(dist >= _BFS_INF, -1, dist).astype(jnp.int32)
            if not with_next_hop:
                return out
            # first (lowest-index) live port one step closer — same rule
            # as the host rebuild (reversed overwrite ⇒ lowest index wins)
            reach = (dist > 0) & (dist < _BFS_INF)
            nh = jnp.full((N, N), -1, jnp.int8)
            for p in range(P - 1, -1, -1):
                dn = dist[nbr[:, p]]
                ok = (link_ok[:, p][:, None] & (dn == dist - w_of[p])
                      & (dn < _BFS_INF) & reach)
                nh = jnp.where(ok, jnp.int8(p), nh)
            return out, nh

        _FAULT_BFS_CACHE[key] = jax.jit(relax)
    return _FAULT_BFS_CACHE[key]


def _get_fault_bfs_stacked(N: int, P: int,
                           weights: tuple[int, ...] | None = None):
    """`lax.map` of the min-plus relaxation over a leading epoch/scenario
    axis of stacked masks: the relaxation body compiles ONCE and the map
    runs it sequentially per mask set, so the (N, N) distance front is
    resident once — the epoch-stacked mode `fault_aware_next_hop_device`
    exposes for per-epoch curves of a `FaultSchedule`."""
    key = (N, P, "stacked", weights)
    if key not in _FAULT_BFS_CACHE:
        import jax
        relax = _get_fault_bfs(N, P, weights=weights)

        def stacked(nbr, eff_ok, link_ok, node_ok):
            return jax.lax.map(
                lambda m: relax(nbr, m[0], m[1], m[2]),
                (eff_ok, link_ok, node_ok))

        _FAULT_BFS_CACHE[key] = jax.jit(stacked)
    return _FAULT_BFS_CACHE[key]


def fault_aware_next_hop_device(g: LatticeGraph, link_ok: np.ndarray,
                                node_ok: np.ndarray | None = None,
                                *, link_spec=None
                                ) -> tuple[np.ndarray, np.ndarray]:
    """`fault_aware_next_hop` computed ON DEVICE: the per-destination BFS
    layers become a multi-source min-plus relaxation — all N distance
    columns advance together through 2n masked neighbor gathers per
    `lax.while_loop` iteration (~diameter iterations total), with the
    next-hop extraction as 2n more gathers at the fixed point.  Results
    are exactly the host tables (same distances, same first-live-port
    rule); the win is scale — the host loop is N sequential BFS passes in
    Python, this is one compiled program, so datacenter-sized fault
    sweeps (`distances.faulted_distance_sweep`) become feasible.

    STACKED-EPOCH mode: pass `link_ok` of shape (E, N, 2n) (and
    optionally `node_ok` of shape (E, N)) — e.g. the per-epoch masks of a
    `fault_schedule.CompiledSchedule` — and the relaxation runs under
    `lax.map` over the E mask sets in ONE compiled program, returning
    (E, N, N) dist / next-hop stacks.  `distances.faulted_schedule_stats`
    and `throughput.fault_aware_schedule_load` build their per-epoch
    curves on this path.

    HETEROGENEOUS fabrics: pass `link_spec=` (a non-trivial
    `core.link_spec.LinkSpec`) and the relaxation runs over the EXTENDED
    port axis with per-port slot costs — `dist` becomes the weighted
    shortest-path cost, `next_hop` indexes the P = 2n + 2·X extended
    ports.  A base-shaped (…, N, 2n) `link_ok` input gets its express
    columns appended all-live; an already-extended (…, N, 2n+2X) mask —
    e.g. `Scenario.link_ok(g, link_spec)` — is consumed as-is, so
    express channels fault like any link.  A pillar mask is AND-ed into
    the base columns either way."""
    import jax.numpy as jnp

    N, P = g.order, 2 * g.n
    link_ok = np.asarray(link_ok, dtype=bool)
    nbr = g.neighbor_indices.astype(np.int32)
    weights = None
    if link_spec is not None and not link_spec.is_trivial:
        link_spec.validate(g.n)
        P = link_spec.num_ports(g.n)
        nbr = link_spec.extended_neighbors(g).astype(np.int32)
        if link_spec.weighted:
            weights = tuple(int(w) for w in link_spec.port_weights(g.n))
        structural = link_spec.structural_mask(g)
        if structural is not None:
            link_ok = link_ok & structural
        if P > 2 * g.n and link_ok.shape[-1] == 2 * g.n:
            ext = np.ones(link_ok.shape[:-1] + (P - 2 * g.n,), dtype=bool)
            link_ok = np.concatenate([link_ok, ext], axis=-1)
    if link_ok.ndim == 3:                                  # (E, N, P)
        E = link_ok.shape[0]
        node_ok = (np.ones((E, N), dtype=bool) if node_ok is None
                   else np.asarray(node_ok, dtype=bool))
        if node_ok.ndim == 1:
            node_ok = np.broadcast_to(node_ok, (E, N))
        eff_ok = link_ok & node_ok[:, :, None] & node_ok[:, nbr]
        dist, nh = _get_fault_bfs_stacked(N, P, weights=weights)(
            jnp.asarray(nbr), jnp.asarray(eff_ok), jnp.asarray(link_ok),
            jnp.asarray(node_ok))
        return np.asarray(dist), np.asarray(nh)
    node_ok = (np.ones(N, dtype=bool) if node_ok is None
               else np.asarray(node_ok, dtype=bool))
    eff_ok = link_ok & node_ok[:, None] & node_ok[nbr]
    dist, nh = _get_fault_bfs(N, P, weights=weights)(
        jnp.asarray(nbr), jnp.asarray(eff_ok), jnp.asarray(link_ok),
        jnp.asarray(node_ok))
    return np.asarray(dist), np.asarray(nh)
