"""Constructors for the paper's graph zoo (§3, §4).

Cubic crystal lattices (Theorem 12 + §3):
  PC(a)   primitive cubic      = 3D torus,            a³ nodes
  FCC(a)  face-centered cubic  ≅ PDTT(a),            2a³ nodes
  BCC(a)  body-centered cubic  (new in the paper),   4a³ nodes
Lifts and hybrids (§4): 4D-FCC, 4D-BCC, Lip, boxplus (Theorem 24).
"""
from __future__ import annotations

import numpy as np

from . import intmat
from .lattice import LatticeGraph


# ---------------------------------------------------------------------------
# generating matrices
# ---------------------------------------------------------------------------

def torus_matrix(*sides: int) -> np.ndarray:
    return np.diag(np.array(sides, dtype=np.int64))


def pc_matrix(a: int) -> np.ndarray:
    return torus_matrix(a, a, a)


def fcc_matrix(a: int) -> np.ndarray:
    # Hermite form of [[a,a,0],[a,0,a],[0,a,a]]
    return np.array([[2 * a, a, a], [0, a, 0], [0, 0, a]], dtype=np.int64)


def bcc_matrix(a: int) -> np.ndarray:
    # Hermite form of [[-a,a,a],[a,-a,a],[a,a,-a]]
    return np.array([[2 * a, 0, a], [0, 2 * a, a], [0, 0, a]], dtype=np.int64)


def rtt_matrix(a: int) -> np.ndarray:
    """Rectangular twisted torus RTT(a) = projection of FCC(a)."""
    return np.array([[2 * a, a], [0, a]], dtype=np.int64)


def dtt_matrix(a: int) -> np.ndarray:
    """2D doubly twisted torus from the tree in Figure 4 ([[a,-a],[a,a]]-type)."""
    return np.array([[a, -a], [a, a]], dtype=np.int64)


def fourd_bcc_matrix(a: int) -> np.ndarray:
    return np.array(
        [[2 * a, 0, 0, a],
         [0, 2 * a, 0, a],
         [0, 0, 2 * a, a],
         [0, 0, 0, a]], dtype=np.int64)


def fourd_fcc_matrix(a: int) -> np.ndarray:
    return np.array(
        [[2 * a, a, a, a],
         [0, a, 0, 0],
         [0, 0, a, 0],
         [0, 0, 0, a]], dtype=np.int64)


def lip_matrix(a: int) -> np.ndarray:
    """Lipschitz graph Lip(a) (Proposition 19): symmetric lift of FCC(2a)."""
    return np.array(
        [[a, -a, -a, -a],
         [a, a, -a, a],
         [a, a, a, -a],
         [a, -a, a, a]], dtype=np.int64)


def nd_pc_matrix(a: int, n: int) -> np.ndarray:
    return np.diag(np.full(n, a, dtype=np.int64))


def nd_bcc_matrix(a: int, n: int) -> np.ndarray:
    """nD-BCC: diag(2a, ..., 2a) with last column (a, ..., a)ᵀ (Figure 4)."""
    M = np.diag(np.full(n, 2 * a, dtype=np.int64))
    M[:, n - 1] = a
    M[n - 1, n - 1] = a
    return M


def nd_fcc_matrix(a: int, n: int) -> np.ndarray:
    """nD-FCC: [[2a, a, ..., a], [0, aI]] (Figure 4 right branch)."""
    M = np.diag(np.full(n, a, dtype=np.int64))
    M[0, :] = a
    M[0, 0] = 2 * a
    return M


def direct_sum(M1, M2) -> np.ndarray:
    A, B = intmat.as_np(M1), intmat.as_np(M2)
    n1, n2 = A.shape[0], B.shape[0]
    out = np.zeros((n1 + n2, n1 + n2), dtype=np.int64)
    out[:n1, :n1] = A
    out[n1:, n1:] = B
    return out


def boxplus(M1, M2) -> np.ndarray:
    """Common lift M1 ⊞ M2 (Theorem 24): overlap the longest common leading
    Hermite block C, producing a lift of minimal dimension with both G(M1)
    and G(M2) as projections."""
    H1 = intmat.hermite_normal_form(M1)
    H2 = intmat.hermite_normal_form(M2)
    n1, n2 = H1.shape[0], H2.shape[0]
    k = 0
    for t in range(1, min(n1, n2) + 1):
        if np.array_equal(H1[:t, :t], H2[:t, :t]):
            k = t
        else:
            break
    C = H1[:k, :k]
    RA, A = H1[:k, k:], H1[k:, k:]
    RB, B = H2[:k, k:], H2[k:, k:]
    da, db = n1 - k, n2 - k
    n = k + da + db
    out = np.zeros((n, n), dtype=np.int64)
    out[:k, :k] = C
    out[:k, k:k + da] = RA
    out[k:k + da, k:k + da] = A
    out[:k, k + da:] = RB
    out[k + da:, k + da:] = B
    return out


# ---------------------------------------------------------------------------
# graph constructors
# ---------------------------------------------------------------------------

def Torus(*sides: int) -> LatticeGraph:
    return LatticeGraph(torus_matrix(*sides))


def PC(a: int) -> LatticeGraph:
    return LatticeGraph(pc_matrix(a))


def FCC(a: int) -> LatticeGraph:
    return LatticeGraph(fcc_matrix(a))


def BCC(a: int) -> LatticeGraph:
    return LatticeGraph(bcc_matrix(a))


def RTT(a: int) -> LatticeGraph:
    return LatticeGraph(rtt_matrix(a))


def FourD_FCC(a: int) -> LatticeGraph:
    return LatticeGraph(fourd_fcc_matrix(a))


def FourD_BCC(a: int) -> LatticeGraph:
    return LatticeGraph(fourd_bcc_matrix(a))


def Lip(a: int) -> LatticeGraph:
    return LatticeGraph(lip_matrix(a))


# ---------------------------------------------------------------------------
# the power-of-two upgrade path (§3.4): 2^{3t} → 2^{3t+1} → 2^{3t+2} → 2^{3t+3}
# ---------------------------------------------------------------------------

def crystal_for_order(num_nodes: int) -> LatticeGraph:
    """The symmetric cubic crystal with exactly `num_nodes` nodes, when
    num_nodes is a power of two ≥ 8 (paper §3.4 upgrade path)."""
    n = int(num_nodes)
    if n < 8 or n & (n - 1):
        raise ValueError(f"{num_nodes} is not a power of two ≥ 8")
    t = n.bit_length() - 1  # n = 2^t
    q, r = divmod(t, 3)
    if r == 0:
        return PC(2 ** q)
    if r == 1:
        return FCC(2 ** q)
    return BCC(2 ** q)


def upgrade_path(start_order: int, steps: int) -> list[LatticeGraph]:
    """PC(a) → FCC(a) → BCC(a) → PC(2a) → ...  each step doubles the size."""
    out = []
    order = start_order
    for _ in range(steps + 1):
        out.append(crystal_for_order(order))
        order *= 2
    return out
