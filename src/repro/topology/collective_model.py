"""Collective cost model over lattice-graph pod topologies.

This is where the paper meets the TPU: the ICI network of a pod is modelled
as a cubic crystal lattice graph (256 chips = BCC(4), 512 = PC(8), 1024 =
FCC(8) — the §3.4 upgrade path), and the cost of each collective pattern is
priced from the topology's distance/throughput properties:

  * ring collectives (all-reduce / all-gather / reduce-scatter along one
    logical mesh axis) — bandwidth-optimal ring schedules, slowed by the
    *dilation* of the embedded ring (physical hops per logical edge),
  * all-to-all (MoE dispatch) — bounded by the paper's uniform-traffic
    capacity Δ/k̄ for edge-symmetric graphs and Δ/(n·k̄_max) for mixed-radix
    tori (§3.4), which is exactly where FCC/BCC beat same-size tori by
    71% / 37%.

Hardware constants default to TPU v5e: 50 GB/s per ICI link per direction.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from repro.core import LatticeGraph, NetworkCondition
from repro.core.throughput import (measured_saturation_throughput,
                                   mixed_torus_throughput_bound,
                                   saturation, symmetric_throughput_bound)

LINK_BW = 50e9          # bytes/s per link per direction (ICI)
PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s


@dataclass(frozen=True)
class RingCost:
    axis: str
    size: int
    dilation: float       # physical hops per logical ring edge (avg)
    seconds_per_byte: float


def ring_all_reduce_time(bytes_per_chip: float, ring_size: int,
                         dilation: float = 1.0, link_bw: float = LINK_BW) -> float:
    """Bandwidth-optimal ring all-reduce: 2·(k−1)/k passes of the buffer over
    each logical edge; a dilated edge shares `dilation` physical links."""
    if ring_size <= 1:
        return 0.0
    return 2.0 * (ring_size - 1) / ring_size * bytes_per_chip * dilation / link_bw


def ring_all_gather_time(shard_bytes: float, ring_size: int,
                         dilation: float = 1.0, link_bw: float = LINK_BW) -> float:
    """Ring all-gather of one `shard_bytes` shard per chip: each edge carries
    (k−1) shards."""
    if ring_size <= 1:
        return 0.0
    return (ring_size - 1) * shard_bytes * dilation / link_bw


def uniform_capacity_phits(g: LatticeGraph) -> float:
    """Uniform-traffic capacity in phits/cycle/node: Δ/k̄ (§3.4)."""
    return symmetric_throughput_bound(g)


def all_to_all_time(g: LatticeGraph, bytes_per_chip_total: float,
                    link_bw: float = LINK_BW, edge_symmetric: bool = True,
                    torus_sides: tuple[int, ...] | None = None) -> float:
    """Time for every chip to exchange `bytes_per_chip_total` (sum over all
    peers) under minimal routing — the MoE dispatch/combine pattern.

    Per-node injection bandwidth under uniform traffic is capped by the
    paper's bound: (Δ/k̄)·link_bw for symmetric graphs,
    (Δ/(n·k̄_max))·link_bw for mixed-radix tori."""
    if edge_symmetric:
        cap = symmetric_throughput_bound(g)
    else:
        assert torus_sides is not None
        cap = mixed_torus_throughput_bound(*torus_sides)
    return bytes_per_chip_total / (cap * link_bw)


@dataclass(frozen=True)
class PodTopologyReport:
    name: str
    chips: int
    diameter: int
    avg_distance: float
    bisection_links: int
    uniform_capacity: float          # phits/cycle/node (analytic Δ/k̄ bound)
    allreduce_256MB_ms: float
    alltoall_256MB_ms: float
    routed_capacity: float | None = None   # measured 1/max-link-load
    # degraded-graph capacity under a fault scenario (1/max-link-load with
    # traffic rerouted around the faults) — None when no scenario given
    faulted_capacity: float | None = None
    # peak ACCEPTED load from the slot-level simulator (queue contention,
    # bubble rule, VC credit flow) — None unless a SimConfig was given
    simulated_capacity: float | None = None
    # heterogeneous-fabric capacity: 1/max(load·weight) over the extended
    # (base + express) port axis under a LinkSpec — express overlays RAISE
    # it, slow Z-weights lower it.  None when no link_spec given.
    hetero_capacity: float | None = None


@dataclass(frozen=True)
class PodOptions:
    """Frozen bundle of `analyze_pod`'s measurement knobs (what to measure
    and how hard — the fabric *state* lives on a `NetworkCondition`, the
    simulator shape on a `SimConfig`).

      * ``measure_routed`` — also measure the empirical 1/max-link-load
        saturation (`routed_pairs` pairs, `routed_backend` engine);
      * ``sim_loads``      — offered-load grid for the slot-level
        simulated-capacity sweep (used when a `sim_config` is given).
    """

    measure_routed: bool = False
    routed_pairs: int = 20_000
    routed_backend: str = "auto"
    sim_loads: tuple[float, ...] = (0.2, 0.4, 0.6, 0.8)

    def __post_init__(self):
        if self.routed_pairs <= 0:
            raise ValueError(
                f"routed_pairs must be positive, got {self.routed_pairs}")
        if self.routed_backend not in ("auto", "jax", "numpy"):
            raise ValueError(
                f"unknown routed backend {self.routed_backend!r}")
        if not self.sim_loads:
            raise ValueError("sim_loads must name at least one load")

    @classmethod
    def from_kwargs(cls, options: "PodOptions | None" = None,
                    **kwargs) -> "PodOptions":
        """Resolve `options=` plus legacy per-call kwargs into one
        `PodOptions` — the `SimConfig.from_kwargs` contract: kwargs
        valued None mean "not passed", and a real kwarg alongside an
        `options` object raises (the call is ambiguous)."""
        given = {k: v for k, v in kwargs.items() if v is not None}
        if options is None:
            return cls(**given)
        if not isinstance(options, cls):
            raise TypeError(
                f"options= expects a PodOptions, got "
                f"{type(options).__name__}")
        if given:
            raise ValueError(
                f"both options= and legacy kwarg(s) {sorted(given)} were "
                "passed; put every measurement knob on the PodOptions "
                "(e.g. replace(options, ...)) or drop options= and use "
                "kwargs")
        return options

    def replace(self, **changes) -> "PodOptions":
        return replace(self, **changes)


def analyze_pod(name: str, g: LatticeGraph,
                torus_sides: tuple[int, ...] | None = None, *,
                condition: NetworkCondition | None = None,
                sim_config=None,
                options: PodOptions | None = None,
                measure_routed: bool | None = None,
                routed_pairs: int | None = None,
                routed_backend: str | None = None,
                sim_loads: tuple[float, ...] | None = None,
                scenario=None,
                link_spec=None) -> PodTopologyReport:
    """Price a pod topology.

    The fabric state rides on ONE `repro.core.NetworkCondition`: its
    `scenario` adds the degraded capacity (uniform live-pair traffic
    walked over fault-aware rebuilt routing tables — how much all-to-all
    headroom the pod keeps after losing links or chips), its `links`
    adds the heterogeneous capacity (weighted shortest-path walk over
    the extended port axis, reduced to ``1/max(load·weight)``), and both
    compose.  A `repro.core.SimConfig` in `sim_config` adds the
    slot-level simulator's peak accepted load over `options.sim_loads` —
    the dynamic saturation point under queue contention (and, for
    ``sim_config.vcs > 1``, the VC credit-flow router).  `options`
    (a `PodOptions`) bundles the measurement knobs: with
    ``measure_routed=True`` the analytic capacity bound is accompanied
    by an empirical saturation throughput (`routed_pairs` uniform pairs
    routed through the batched engine and reduced to 1/max
    directional-link load; ``routed_backend="numpy"`` forces the host
    oracle end-to-end).

    The historical kwargs (`measure_routed`, `routed_pairs`,
    `routed_backend`, `sim_loads`, `scenario`, `link_spec`) remain as a
    conflict-raising shim over `PodOptions.from_kwargs` /
    `NetworkCondition.from_kwargs` — passing one alongside the matching
    bundle raises, exactly like the `SimConfig` migration."""
    opts = PodOptions.from_kwargs(
        options, measure_routed=measure_routed, routed_pairs=routed_pairs,
        routed_backend=routed_backend,
        sim_loads=tuple(sim_loads) if sim_loads is not None else None)
    cond = NetworkCondition.from_kwargs(
        condition, scenario=scenario, links=link_spec)
    if condition is None:
        # legacy path priced capacities with `routed_pairs` draws; an
        # explicit condition= keeps its own Monte-Carlo sample size
        cond = cond.replace(pairs=opts.routed_pairs)
    sym = torus_sides is None
    test_bytes = 256 * 2**20
    cap = (symmetric_throughput_bound(g) if sym
           else mixed_torus_throughput_bound(*torus_sides))
    faulted = None
    if cond.scenario is not None and not cond.scenario.is_trivial:
        faulted = float(saturation(g, cond.replace(links=None)))
    elif cond.schedule is not None:
        # a fault timeline prices as its WORST epoch — the capacity floor
        # the pod is guaranteed across the whole schedule
        faulted = float(np.min(saturation(g, cond.replace(links=None))))
    simulated = None
    if sim_config is not None:
        from repro.core.throughput import simulated_saturation_load
        simulated = simulated_saturation_load(g, opts.sim_loads,
                                              config=sim_config)
    hetero = None
    if cond.links is not None and not cond.links.is_trivial:
        hetero = float(saturation(
            g, cond.replace(scenario=None)))
    return PodTopologyReport(
        name=name,
        chips=g.order,
        diameter=g.diameter,
        avg_distance=g.average_distance,
        bisection_links=bisection_links(g),
        uniform_capacity=cap,
        allreduce_256MB_ms=1e3 * ring_all_reduce_time(test_bytes, g.order),
        alltoall_256MB_ms=1e3 * all_to_all_time(
            g, test_bytes, edge_symmetric=sym, torus_sides=torus_sides),
        routed_capacity=(measured_saturation_throughput(
            g, opts.routed_pairs, backend=opts.routed_backend)
            if opts.measure_routed else None),
        faulted_capacity=faulted,
        simulated_capacity=simulated,
        hetero_capacity=hetero)


def bisection_links(g: LatticeGraph) -> int:
    """Directional links crossing the halving plane of the first Hermite
    dimension (a standard—if not tight for twisted graphs (§3.4)—measure)."""
    labels = g.labels
    half = int(g.sides[0]) // 2
    side_a = labels[:, 0] < half
    nbr = g.neighbor_indices
    crossings = 0
    for p in range(nbr.shape[1]):
        dst_side = side_a[nbr[:, p]]
        crossings += int((side_a != dst_side).sum())
    return crossings // 2


def collective_term_refined(collective_bytes_per_chip: float,
                            pod: LatticeGraph,
                            pattern: str = "ring",
                            axis_size: int = 16,
                            dilation: float = 1.0,
                            link_bw: float = LINK_BW) -> float:
    """Topology-refined collective roofline term (seconds).

    `pattern="ring"`: the traffic is ring reductions along mesh axes —
    effective rate is one link per direction × dilation penalty.
    `pattern="uniform"`: the traffic is all-to-all-like — rate capped by the
    paper's Δ/k̄ capacity."""
    if pattern == "uniform":
        cap = symmetric_throughput_bound(pod)       # phits/cycle/node
        return collective_bytes_per_chip / (cap * link_bw)
    return collective_bytes_per_chip * dilation / link_bw
