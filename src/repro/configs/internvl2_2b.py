"""InternVL2-2B [arXiv:2404.16821]: InternLM2-1.8B backbone; the InternViT
frontend is a stub — input_specs() provides precomputed patch embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    num_patch_tokens=256,
)
