"""Unified analytic evaluation condition (`NetworkCondition`).

PRs 3–9 grew the analytic layer eleven entry points — `faulted_*`,
`weighted_*`, `fault_aware_*`, `fault_aware_schedule_*` — one per
combination of {static faults, fault timeline, heterogeneous links} ×
{distances, channel loads, saturation}.  `NetworkCondition` bundles the
*condition* of the fabric into ONE frozen value object, and the three
facades dispatch on it:

    cond = NetworkCondition(scenario=Scenario.random_link_faults(g, 4),
                            links=LinkSpec(dim_weights=(1, 1, 2)))
    distances.distance_stats(g, condition=cond)
    throughput.channel_load_stats(g, condition=cond)
    throughput.saturation(g, condition=cond)

This mirrors the PR 7 `SimConfig` migration exactly: the facades also
accept the condition fields as keyword arguments, resolved through
`NetworkCondition.from_kwargs`, which raises when a kwarg is passed
ALONGSIDE a condition carrying the same field (an ambiguous call is a
bug at the call site, never a silent preference).  Validation that used
to be duplicated per entry point (`scenario`/`schedule` mutual
exclusion, backend vocabulary) lives once in `__post_init__`.

`SimConfig` names *how to run the simulator*; `NetworkCondition` names
*what state the fabric is in* — the two compose (e.g. the explorer's
evaluator holds one of each per candidate).
"""
from __future__ import annotations

from dataclasses import dataclass, fields, replace

from .fault_schedule import CompiledSchedule, FaultSchedule
from .link_spec import LinkSpec
from .scenario import Scenario

BFS_BACKENDS = ("auto", "device", "host")

# fields a facade may also receive as a keyword argument; used by
# `from_kwargs` to build the condition and to name conflicts precisely
_FIELD_NAMES: tuple[str, ...] = (
    "scenario", "schedule", "links", "slots", "pairs", "seed", "backend")


@dataclass(frozen=True)
class NetworkCondition:
    """Frozen bundle of every fabric-state parameter the analytic layer
    dispatches on (the per-call inputs — the graph itself — stay call
    arguments: they name *what* to evaluate, the condition names *under
    which faults/links/sampling*).

      * ``scenario`` — static fault pattern (`repro.core.Scenario`);
      * ``schedule`` — transient fault timeline (`FaultSchedule` or an
        already-compiled `CompiledSchedule`); mutually exclusive with
        ``scenario``, and switches every facade to per-epoch output;
      * ``links``    — heterogeneous `LinkSpec` (weights / pillars /
        express), composable with either of the above;
      * ``slots``    — timeline horizon used to compile a ``schedule``;
      * ``pairs``/``seed`` — Monte-Carlo sample size and RNG seed for
        the channel-load walks;
      * ``backend``  — "auto" | "device" | "host" for the BFS table
        rebuilds and the pristine routing walk.
    """

    scenario: Scenario | None = None
    schedule: FaultSchedule | CompiledSchedule | None = None
    links: LinkSpec | None = None
    slots: int = 512
    pairs: int = 20_000
    seed: int = 0
    backend: str = "auto"

    def __post_init__(self):
        if self.scenario is not None and self.schedule is not None:
            # same home, same message as SimConfig's exclusivity check
            raise ValueError("pass either scenario= or schedule=, not both")
        if self.scenario is not None and not isinstance(self.scenario,
                                                        Scenario):
            raise TypeError(
                f"scenario= expects a Scenario, got "
                f"{type(self.scenario).__name__}")
        if self.schedule is not None and not isinstance(
                self.schedule, (FaultSchedule, CompiledSchedule)):
            raise TypeError(
                f"schedule= expects a FaultSchedule or CompiledSchedule, "
                f"got {type(self.schedule).__name__}")
        if self.links is not None and not isinstance(self.links, LinkSpec):
            raise TypeError(
                f"links= expects a LinkSpec, got "
                f"{type(self.links).__name__}")
        if self.slots <= 0:
            raise ValueError(f"slots must be positive, got {self.slots}")
        if self.pairs <= 0:
            raise ValueError(f"pairs must be positive, got {self.pairs}")
        if self.backend not in BFS_BACKENDS:
            raise ValueError(
                f"unknown analytic backend {self.backend!r}; expected one "
                f"of {BFS_BACKENDS}")

    # -- dispatch helpers ---------------------------------------------------
    @property
    def is_pristine(self) -> bool:
        """No faults, no timeline, no (non-trivial) heterogeneity."""
        return ((self.scenario is None or self.scenario.is_trivial)
                and self.schedule is None
                and (self.links is None or self.links.is_trivial))

    @property
    def router_backend(self) -> str:
        """This condition's backend in `routing.make_router` vocabulary
        ("host" → the numpy oracle, "device" → the jitted engine)."""
        return {"auto": "auto", "device": "jax", "host": "numpy"}[self.backend]

    # -- the facade-kwarg shim ----------------------------------------------
    @classmethod
    def from_kwargs(cls, condition: "NetworkCondition | None" = None,
                    **kwargs) -> "NetworkCondition":
        """Resolve `condition=` plus per-call kwargs into one
        `NetworkCondition`.  kwargs valued None mean "not passed"; passing
        a real value for a field while also passing `condition` raises —
        the call is ambiguous, and silently preferring either side would
        hide bugs (the `SimConfig.from_kwargs` contract)."""
        unknown = set(kwargs) - set(_FIELD_NAMES)
        if unknown:
            raise TypeError(
                f"unknown condition kwargs: {sorted(unknown)}; "
                f"NetworkCondition fields are {list(_FIELD_NAMES)}")
        given = {k: v for k, v in kwargs.items() if v is not None}
        if condition is None:
            return cls(**given)
        if not isinstance(condition, cls):
            raise TypeError(
                f"condition= expects a NetworkCondition, got "
                f"{type(condition).__name__}")
        if given:
            raise ValueError(
                f"both condition= and kwarg(s) {sorted(given)} were "
                "passed; put every fabric parameter on the "
                "NetworkCondition (e.g. replace(condition, ...)) or drop "
                "condition= and use kwargs")
        return condition

    def replace(self, **changes) -> "NetworkCondition":
        """`dataclasses.replace` convenience (re-validates)."""
        return replace(self, **changes)

    def as_kwargs(self) -> dict:
        """The condition as a keyword dict (field name → value)."""
        return {f.name: getattr(self, f.name) for f in fields(self)}
