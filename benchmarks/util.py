"""Benchmark harness utilities: timing + the `name,us_per_call,derived` CSV
contract shared by every benchmark module."""
from __future__ import annotations

import time
from contextlib import contextmanager

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


@contextmanager
def timed(name: str, derived_fn=lambda: ""):
    t0 = time.perf_counter()
    yield
    emit(name, (time.perf_counter() - t0) * 1e6, derived_fn())


def header():
    print("name,us_per_call,derived", flush=True)
