"""Hybrid (Zamba2) grouped-scan path ≡ unrolled path (forward/prefill/decode),
including non-divisible layer tails."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import decode_step, forward, init_params, prefill

KEY = jax.random.PRNGKey(0)


def _cfg(num_layers):
    r = get_config("zamba2-1.2b").reduced()   # period 2
    return dataclasses.replace(r, num_layers=num_layers)


@pytest.mark.parametrize("L", [4, 5])          # even groups + tail case
def test_grouped_forward_matches_unrolled(L):
    r = _cfg(L)
    params = init_params(r, KEY)
    tokens = jax.random.randint(jax.random.fold_in(KEY, 1), (2, 32), 0,
                                r.vocab_size)
    l0, _ = forward(params, r, tokens, unroll=0)
    l1, _ = forward(params, r, tokens, unroll=1)
    assert float(jnp.abs(l0.astype(jnp.float32) -
                         l1.astype(jnp.float32)).max()) < 5e-2


@pytest.mark.parametrize("L", [4, 5])
def test_grouped_prefill_decode_matches_unrolled(L):
    r = _cfg(L)
    params = init_params(r, KEY)
    tokens = jax.random.randint(jax.random.fold_in(KEY, 2), (2, 32), 0,
                                r.vocab_size)
    lp0, c0 = prefill(params, r, tokens, max_len=40, unroll=0)
    lp1, c1 = prefill(params, r, tokens, max_len=40, unroll=1)
    assert float(jnp.abs(lp0.astype(jnp.float32) -
                         lp1.astype(jnp.float32)).max()) < 5e-2
    tok = tokens[:, -1:]
    d0, _ = decode_step(params, r, tok, c0, jnp.int32(32), unroll=0)
    d1g, _ = decode_step(params, r, tok, c1, jnp.int32(32), unroll=1)
    d1x, _ = decode_step(params, r, tok, c1, jnp.int32(32), unroll=0)
    # grouped caches are layer-compatible with the unrolled path and
    # grouped decode agrees with unrolled decode
    assert float(jnp.abs(d0.astype(jnp.float32) -
                         d1g.astype(jnp.float32)).max()) < 5e-2
    assert float(jnp.abs(d1g.astype(jnp.float32) -
                         d1x.astype(jnp.float32)).max()) < 5e-2


def test_grouped_train_grads_finite():
    r = _cfg(4)
    params = init_params(r, KEY)
    tokens = jax.random.randint(KEY, (2, 32), 0, r.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss(p):
        from repro.models.common import cross_entropy
        logits, aux = forward(p, r, tokens, unroll=1, remat="full")
        return cross_entropy(logits, labels) + aux

    val, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(val))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
