"""Post-SPMD HLO analysis: collective-traffic accounting for the roofline.

`collective_bytes` parses the optimized (per-device) HLO text and sums the
operand bytes of every communication op: all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (+ their async -start
forms).  cost_analysis() does not report these — this is the third roofline
term's source of truth.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+[a-z0-9]*|pred)\[([0-9,]*)\]")

# ordered by specificity: -start forms first; -done lines are skipped
_OPS = [
    "all-reduce-start", "all-gather-start", "collective-permute-start",
    "reduce-scatter-start", "all-to-all-start",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
]


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    count_by_op: dict[str, int] = field(default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def as_dict(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "total_count": self.total_count,
            "bytes_by_op": dict(self.bytes_by_op),
            "count_by_op": dict(self.count_by_op),
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Per-device collective traffic from optimized HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done." in line:
            continue
        for op in _OPS:
            idx = line.find(f" {op}(")
            if idx < 0:
                continue
            left, right = line[:idx], line[idx:]
            out_b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(left))
            in_b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(right))
            name = op.removesuffix("-start")
            stats.bytes_by_op[name] += in_b if in_b else out_b
            stats.count_by_op[name] += 1
            break
    return stats


def collective_bytes(hlo_text: str) -> int:
    return collective_stats(hlo_text).total_bytes


def top_collectives(hlo_text: str, k: int = 12) -> list[dict]:
    """Aggregate collective traffic by (op, operand shape) — the profile the
    perf loop iterates on."""
    agg: dict[tuple[str, str], int] = defaultdict(int)
    cnt: dict[tuple[str, str], int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done." in line:
            continue
        for op in _OPS:
            idx = line.find(f" {op}(")
            if idx < 0:
                continue
            right_shapes = _SHAPE_RE.findall(line[idx:])
            left_shapes = _SHAPE_RE.findall(line[:idx])
            in_b = sum(_shape_bytes(d, s) for d, s in right_shapes)
            out_b = sum(_shape_bytes(d, s) for d, s in left_shapes)
            b = in_b if in_b else out_b
            sig_src = right_shapes or left_shapes
            sig = f"{sig_src[0][0]}[{sig_src[0][1]}]" if sig_src else "?"
            key = (op.removesuffix("-start"), sig)
            agg[key] += b
            cnt[key] += 1
            break
    rows = sorted(agg.items(), key=lambda kv: -kv[1])[:k]
    return [{"op": op, "shape": sig, "bytes": b, "count": cnt[(op, sig)]}
            for (op, sig), b in rows]
