"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

from .base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
                   MoEConfig, ModelConfig, ShapeSpec, SSMConfig, shapes_for,
                   skipped_shapes_for)
from .command_r_plus_104b import CONFIG as command_r_plus_104b
from .deepseek_moe_16b import CONFIG as deepseek_moe_16b
from .internvl2_2b import CONFIG as internvl2_2b
from .mamba2_2p7b import CONFIG as mamba2_2p7b
from .olmo_1b import CONFIG as olmo_1b
from .phi3_mini_3p8b import CONFIG as phi3_mini_3p8b
from .phi35_moe_42b import CONFIG as phi35_moe_42b
from .qwen3_4b import CONFIG as qwen3_4b
from .whisper_base import CONFIG as whisper_base
from .zamba2_1p2b import CONFIG as zamba2_1p2b

REGISTRY: dict[str, ModelConfig] = {
    "deepseek-moe-16b": deepseek_moe_16b,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b,
    "phi3-mini-3.8b": phi3_mini_3p8b,
    "qwen3-4b": qwen3_4b,
    "olmo-1b": olmo_1b,
    "command-r-plus-104b": command_r_plus_104b,
    "zamba2-1.2b": zamba2_1p2b,
    "mamba2-2.7b": mamba2_2p7b,
    "internvl2-2b": internvl2_2b,
    "whisper-base": whisper_base,
}

SHAPES: dict[str, ShapeSpec] = {s.name: s for s in ALL_SHAPES}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "ShapeSpec",
    "REGISTRY", "SHAPES", "get_config", "get_shape", "shapes_for",
    "skipped_shapes_for", "ALL_SHAPES",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]
