"""Records/sec of the batched JAX routing engine vs the numpy oracle.

Sweeps batch sizes 10³–10⁶ over FCC(8), BCC(4) and a random Hermite-normal-
form G(M), timing three paths:

  * `numpy`   — the reference `HierarchicalRouter` (host, per-copy loop),
  * `engine`  — `RoutingEngine.__call__` (jitted; all-pairs table + gather
    for these pod-sized graphs), including host↔device transfers,
  * `engine_rec` — the unrolled Algorithm-1 recursion on device, i.e. the
    path taken by graphs too large to tabulate.

The acceptance bar of this repo's ISSUE 1 is engine ≥ 10× numpy at
batch ≥ 10⁵ on CPU.  Timings exclude jit compilation (same-shape warmup).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import HierarchicalRouter, LatticeGraph, bcc_matrix, fcc_matrix
from repro.core.routing_engine import RoutingEngine

from .util import emit

# a mid-sized non-crystal HNF (det 120): exercises the generic recursion
RANDOM_HNF = [[6, 3, 1], [0, 5, 2], [0, 0, 4]]


def _time(f, reps: int) -> float:
    """Best-of-reps: min is the robust throughput estimator on shared
    runners (load spikes only ever make a rep slower)."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        f()
        best = min(best, time.perf_counter() - t0)
    return best


def main(quick: bool = False) -> None:
    batches = (10**3, 10**5) if quick else (10**3, 10**4, 10**5, 10**6)
    graphs = [("FCC(8)", fcc_matrix(8)), ("BCC(4)", bcc_matrix(4)),
              ("G(randHNF)", RANDOM_HNF)]
    rng = np.random.default_rng(0)
    for name, M in graphs:
        g = LatticeGraph(M)
        hr = HierarchicalRouter(M)
        eng = RoutingEngine(M)
        for B in batches:
            v = (g.labels[rng.integers(0, g.order, B)]
                 - g.labels[rng.integers(0, g.order, B)])
            eng(v)                      # same-shape warmup (compile)
            eng.route_recursive(v)
            reps = min(max(3, int(2e6 // B)), 50)
            t_np = _time(lambda: hr(v), 2 if B >= 10**5 else 3)
            t_eng = _time(lambda: eng(v), reps)
            t_rec = _time(lambda: eng.route_recursive(v), max(reps // 4, 2))
            emit(f"routing/{name}/B={B}", t_eng * 1e6,
                 f"numpy_Mrec_s={B / t_np / 1e6:.2f};"
                 f"engine_Mrec_s={B / t_eng / 1e6:.2f};"
                 f"engine_rec_Mrec_s={B / t_rec / 1e6:.2f};"
                 f"speedup={t_np / t_eng:.1f}x;"
                 f"speedup_rec={t_np / t_rec:.1f}x")


if __name__ == "__main__":
    main()
