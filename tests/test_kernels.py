"""Per-kernel shape/dtype sweeps: interpret-mode Pallas vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


def _fold(t):
    B, S, H, hd = t.shape
    return t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,hd,bq,bk", [
    (1, 128, 2, 2, 64, 64, 64),
    (2, 256, 4, 2, 64, 128, 64),
    (1, 256, 8, 1, 128, 64, 128),   # heavy GQA
    (2, 512, 2, 2, 32, 128, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(dtype, B, S, H, KV, hd, bq, bk, causal):
    q = jax.random.normal(KEY, (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    kr = jnp.repeat(k, H // KV, axis=2)
    vr = jnp.repeat(v, H // KV, axis=2)
    expect = ref.flash_attention(_fold(q), _fold(kr), _fold(vr), causal=causal)
    expect = expect.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), **_tol(dtype))


@given(st.integers(1, 3), st.sampled_from([64, 128, 256]),
       st.sampled_from([32, 64]))
@settings(max_examples=8, deadline=None)
def test_flash_attention_property(B, S, hd):
    H = 2
    q = jax.random.normal(KEY, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    expect = ref.flash_attention(_fold(q), _fold(k), _fold(v), causal=True)
    expect = expect.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, expect, atol=3e-5, rtol=3e-5)


def test_flash_attention_rows_are_convex_combinations():
    """Softmax output rows must lie in the convex hull of V rows: max |out|
    bounded by max |v| (sanity property independent of the oracle)."""
    B, S, H, hd = 1, 128, 2, 32
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, hd))
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4
    # first row attends only to itself
    np.testing.assert_allclose(out[:, 0], v[:, 0], atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S_max,pos,bk", [
    (512, 0, 128), (512, 511, 128), (1024, 700, 256), (2048, 33, 512),
])
def test_decode_attention_sweep(dtype, S_max, pos, bk):
    B, H, KV, hd = 2, 4, 2, 64
    q = jax.random.normal(KEY, (B, 1, H, hd), dtype)
    kc = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S_max, KV, hd), dtype)
    vc = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S_max, KV, hd), dtype)
    out = ops.decode_attention(q, kc, vc, jnp.int32(pos), block_k=bk)
    kr = jnp.repeat(kc, H // KV, axis=2)
    vr = jnp.repeat(vc, H // KV, axis=2)
    expect = ref.decode_attention(_fold(q), _fold(kr), _fold(vr), pos)
    expect = expect.reshape(B, H, 1, hd).transpose(0, 2, 1, 3).reshape(B, 1, H * hd)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), **_tol(dtype))


def test_decode_matches_flash_last_row():
    """Decoding token S-1 with a full cache equals the last row of causal
    flash attention over the same sequence."""
    B, S, H, hd = 1, 256, 2, 64
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, hd))
    full = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    dec = ops.decode_attention(q[:, -1:], k, v, jnp.int32(S - 1), block_k=128)
    np.testing.assert_allclose(
        dec, full[:, -1:].reshape(B, 1, H * hd), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,H,P,N,G,chunk", [
    (128, 4, 16, 8, 1, 32),
    (256, 2, 32, 16, 1, 64),
    (96, 4, 16, 8, 2, 32),     # grouped B/C
    (100, 2, 16, 8, 1, 32),    # non-chunk-aligned
])
def test_ssd_kernel_sweep(dtype, S, H, P, N, G, chunk):
    B = 2
    xdt = (jax.random.normal(KEY, (B, S, H, P)) * 0.1).astype(dtype)
    Adt = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H)))
    Bm = (jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, G, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, G, N)) * 0.3).astype(dtype)
    y, final = ops.ssd(xdt, Adt, Bm, Cm, chunk=chunk)
    from repro.models.ssm import ssd_reference
    y2, f2 = ssd_reference(xdt, Adt, Bm, Cm)
    np.testing.assert_allclose(
        y.astype(jnp.float32), y2.astype(jnp.float32), atol=5e-2, rtol=5e-2)
    np.testing.assert_allclose(
        final.astype(jnp.float32), f2.astype(jnp.float32), atol=5e-2, rtol=5e-2)


def test_ssd_intra_chunk_vs_oracle():
    BH, nc, Q, P, N = 3, 4, 32, 16, 8
    xdt = jax.random.normal(KEY, (BH, nc, Q, P)) * 0.1
    Adt = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1), (BH, nc, Q)))
    Bm = jax.random.normal(jax.random.fold_in(KEY, 2), (BH, nc, Q, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(KEY, 3), (BH, nc, Q, N)) * 0.3
    from repro.kernels.ssd_scan import ssd_intra_chunk
    y, st_, cs = ssd_intra_chunk(xdt, Adt, Bm, Cm, interpret=True)
    y2, st2, cs2 = ref.ssd_intra_chunk(xdt, Adt, Bm, Cm)
    np.testing.assert_allclose(y, y2, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(st_, st2, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(cs, cs2, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,block", [
    ((8, 128), 4), ((4, 37, 128), 256), ((2, 3, 5, 64), 1), ((256, 512), 64),
])
def test_rmsnorm_sweep(dtype, shape, block):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 7), (shape[-1],), jnp.float32)
    out = ops.rmsnorm(x, w, block_rows=block)
    expect = ref.rmsnorm(x, w)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32), **_tol(dtype))


@given(st.sampled_from([64, 128, 256]), st.integers(1, 64))
@settings(max_examples=10, deadline=None)
def test_rmsnorm_property_unit_scale(D, rows):
    """RMSNorm with unit weight produces rows with mean-square ≈ 1."""
    x = jax.random.normal(KEY, (rows, D)) * 3.0 + 1.0
    out = ops.rmsnorm(x, jnp.ones((D,)))
    ms = jnp.mean(out.astype(jnp.float32) ** 2, axis=-1)
    np.testing.assert_allclose(ms, np.ones(rows), atol=1e-3)


# ---------------------------------------------------------------------------
# model integration: pallas impl == xla impl
# ---------------------------------------------------------------------------

def test_model_forward_pallas_matches_xla():
    from repro.configs import get_config
    from repro.models import forward, init_params
    r = get_config("qwen3-4b").reduced()
    params = init_params(r, KEY)
    tokens = jax.random.randint(KEY, (2, 64), 0, r.vocab_size)
    l_xla, _ = forward(params, r, tokens, impl="xla")
    l_pal, _ = forward(params, r, tokens, impl="pallas")
    np.testing.assert_allclose(
        l_xla.astype(jnp.float32), l_pal.astype(jnp.float32),
        atol=5e-2, rtol=5e-2)


def test_mamba_forward_pallas_matches_xla():
    from repro.configs import get_config
    from repro.models import forward, init_params
    r = get_config("mamba2-2.7b").reduced()
    params = init_params(r, KEY)
    tokens = jax.random.randint(KEY, (2, 64), 0, r.vocab_size)
    l_xla, _ = forward(params, r, tokens, impl="xla")
    l_pal, _ = forward(params, r, tokens, impl="pallas")
    np.testing.assert_allclose(
        l_xla.astype(jnp.float32), l_pal.astype(jnp.float32),
        atol=5e-2, rtol=5e-2)
