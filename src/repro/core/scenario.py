"""Fault-injection & adaptive-routing scenarios for lattice-graph fabrics.

A `Scenario` describes the *degraded* regime the paper's §6.2 evaluation
does not cover: dead links, dead nodes, and non-DOR escape routing — the
operating points where a symmetric crystal fabric must still beat a
mixed-radix torus to justify itself as a practical interconnect.

The spec is deliberately declarative: a scenario is nothing but

  * ``dead_links`` — undirected faults, given as (node, port) pairs
    (killing (u, p) also kills the reverse channel (v, p XOR 1) of the
    neighbour v behind port p),
  * ``dead_nodes`` — every incident channel of the node dies, the node
    never injects, and it is excluded as a traffic destination,
  * ``policy`` — the routing policy packets follow:

      - ``"dor"``       dimension-order over the minimal record (the
                        baseline; packets whose required channel is dead
                        block in place),
      - ``"adaptive"``  minimal-adaptive: at every hop the packet takes
                        the first *live* productive port (any dimension
                        whose record component is nonzero), i.e. it picks
                        among the equal-norm minimal ports,
      - ``"escape"``    adaptive with a non-minimal escape hop: when every
                        productive port is dead, the packet takes the
                        first live port of any dimension (its record grows
                        by the misroute and shrinks again later).  On
                        odd/n=1 rings the misroute can livelock at load;
                        the VC credit-flow router (``vcs >= 2`` on a
                        `repro.core.SimConfig`) supersedes this heuristic
                        with a restricted-DOR escape *lane* that is
                        provably deadlock-free and livelock-free — prefer
                        it when simulating faulted fabrics.

Downstream consumers turn the spec into **masks and tables** (never
Python branching in a hot loop): the simulator threads ``link_ok`` /
``inj_ok`` / ``dst_ok`` through both slot-update implementations
(`repro.core.simulation`), and the analytic layers rebuild fault-aware
BFS routing tables (`repro.core.routing.fault_aware_next_hop`,
`repro.core.distances.faulted_*`, `repro.core.throughput.fault_aware_*`)
so saturation bounds and load curves reflect the degraded graph.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from .lattice import LatticeGraph

POLICIES = ("dor", "adaptive", "escape")


@dataclass(frozen=True)
class Scenario:
    """Declarative fault + routing-policy spec (see module docstring)."""

    dead_links: tuple[tuple[int, int], ...] = ()   # (node, port), undirected
    dead_nodes: tuple[int, ...] = ()
    policy: str = "dor"
    name: str = "baseline"

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(
                f"unknown policy {self.policy!r}; expected one of {POLICIES}")
        object.__setattr__(self, "dead_links",
                           tuple((int(u), int(p)) for u, p in self.dead_links))
        object.__setattr__(self, "dead_nodes",
                           tuple(int(u) for u in self.dead_nodes))

    # -- triviality ---------------------------------------------------------
    @property
    def is_trivial(self) -> bool:
        """True iff the scenario is the pristine DOR baseline: downstream
        code paths then stay bitwise-identical to the scenario-less ones."""
        return (not self.dead_links and not self.dead_nodes
                and self.policy == "dor")

    def with_policy(self, policy: str) -> "Scenario":
        return replace(self, policy=policy,
                       name=f"{self.name}/{policy}")

    # -- masks --------------------------------------------------------------
    def link_ok(self, g: LatticeGraph, link_spec=None) -> np.ndarray:
        """(N, P) bool: channel (u, p) is alive.  Symmetric by
        construction: killing (u, p) kills (v, p^1) too, and a dead node
        takes every incident channel (both directions) down with it.

        P is 2n on the base lattice; passing a `LinkSpec` with express
        overlays extends the axis to 2n+2X (`extended_neighbors` port
        layout), so express channels die and repair like any link —
        dead_links may then name express ports, and dead nodes take
        their express channels down too."""
        if link_spec is not None and getattr(link_spec, "express", ()):
            nbr = np.asarray(link_spec.extended_neighbors(g))
        else:
            nbr = np.asarray(g.neighbor_indices)
        P = nbr.shape[1]
        ok = np.ones((g.order, P), dtype=bool)
        for u, p in self.dead_links:
            if p >= P:
                raise ValueError(
                    f"dead link ({u}, {p}) names port {p} but this fabric "
                    f"has only {P} ports (express ports need the matching "
                    f"LinkSpec passed through SimConfig(links=...))")
            v = int(nbr[u, p])
            ok[u, p] = False
            ok[v, p ^ 1] = False
        for u in self.dead_nodes:
            ok[u, :] = False
            for p in range(P):
                ok[int(nbr[u, p]), p ^ 1] = False
        return ok

    def node_ok(self, g: LatticeGraph) -> np.ndarray:
        """(N,) bool: node is alive (injects traffic, valid destination)."""
        ok = np.ones(g.order, dtype=bool)
        ok[list(self.dead_nodes)] = False
        return ok

    def fingerprint(self, g: LatticeGraph) -> tuple:
        """Hashable identity for compiled-runner caches.  Spec-based (not
        mask-bytes) so a scenario naming express ports fingerprints
        without needing the LinkSpec; two spellings of the same
        undirected fault may compile twice, never wrongly share."""
        if self.is_trivial:
            return ("trivial",)
        return (self.policy, tuple(sorted(self.dead_links)),
                tuple(sorted(self.dead_nodes)))

    # -- constructors -------------------------------------------------------
    @classmethod
    def random_link_faults(cls, g: LatticeGraph, k: int, seed: int = 0,
                           policy: str = "adaptive") -> "Scenario":
        """k distinct undirected link faults sampled uniformly."""
        max_links = g.order * g.n          # N·2n directed / 2
        if k > max_links:
            raise ValueError(
                f"k={k} exceeds the {max_links} distinct undirected links "
                f"of this graph")
        rng = np.random.default_rng(seed)
        seen: set[tuple[int, int]] = set()
        links: list[tuple[int, int]] = []
        nbr = g.neighbor_indices
        while len(links) < k:
            u = int(rng.integers(0, g.order))
            p = int(rng.integers(0, 2 * g.n))
            v = int(nbr[u, p])
            key = min((u, p), (v, p ^ 1))
            if key in seen:
                continue
            seen.add(key)
            links.append((u, p))
        return cls(dead_links=tuple(links), policy=policy,
                   name=f"links{k}@{seed}")

    @classmethod
    def random_node_faults(cls, g: LatticeGraph, k: int, seed: int = 0,
                           policy: str = "adaptive") -> "Scenario":
        """k distinct dead nodes sampled uniformly (origin kept alive so
        fixed patterns anchored at 0 stay meaningful)."""
        rng = np.random.default_rng(seed)
        nodes = rng.choice(np.arange(1, g.order), size=k, replace=False)
        return cls(dead_nodes=tuple(int(x) for x in nodes), policy=policy,
                   name=f"nodes{k}@{seed}")


def scenario_connected(g: LatticeGraph, scenario: Scenario) -> bool:
    """True iff the live nodes form one connected component under the live
    links — the sanity check tests use before asserting delivery."""
    link_ok = scenario.link_ok(g)
    node_ok = scenario.node_ok(g)
    live = np.flatnonzero(node_ok)
    if live.size == 0:
        return False
    seen = np.zeros(g.order, dtype=bool)
    seen[live[0]] = True
    frontier = np.array([live[0]])
    nbr = g.neighbor_indices
    while frontier.size:
        nxt = []
        for p in range(2 * g.n):
            dst = nbr[frontier, p]
            ok = link_ok[frontier, p] & ~seen[dst]
            nxt.append(dst[ok])
        frontier = np.unique(np.concatenate(nxt)) if nxt else np.array([], int)
        seen[frontier] = True
    return bool(seen[live].all())
