"""Exact integer-matrix machinery for lattice graphs (paper §2).

All arithmetic is exact (Python ints).  Matrices are lists of lists (rows) or
numpy arrays with small entries; every public function accepts either and
returns numpy int64 arrays unless noted.

Conventions follow the paper:
  * right-equivalence  M1 ≅ M2  ⇔  M1 = M2 · P with P unimodular (column ops),
  * Hermite normal form H is upper triangular, positive diagonal, and
    0 ≤ H[i, j] < H[i, i] for j > i  (Definition 8),
  * the labelling set of G(M) is the Hermite box {x : 0 ≤ x_i < H_ii}
    (Definition 26 with the Hermite labelling).
"""
from __future__ import annotations

import numpy as np

Int = int


def as_pyint_matrix(M) -> list[list[Int]]:
    """Copy M into a list-of-lists of Python ints (exact arithmetic)."""
    A = np.asarray(M)
    return [[int(x) for x in row] for row in A]


def as_np(M) -> np.ndarray:
    return np.array([[int(x) for x in row] for row in M], dtype=np.int64)


# ---------------------------------------------------------------------------
# determinant / adjugate (exact)
# ---------------------------------------------------------------------------

def det(M) -> Int:
    """Exact integer determinant via fraction-free (Bareiss) elimination."""
    A = as_pyint_matrix(M)
    n = len(A)
    sign = 1
    prev = 1
    for k in range(n - 1):
        if A[k][k] == 0:  # pivot search
            for i in range(k + 1, n):
                if A[i][k] != 0:
                    A[k], A[i] = A[i], A[k]
                    sign = -sign
                    break
            else:
                return 0
        for i in range(k + 1, n):
            for j in range(k + 1, n):
                A[i][j] = (A[i][j] * A[k][k] - A[i][k] * A[k][j]) // prev
            A[i][k] = 0
        prev = A[k][k]
    return sign * A[n - 1][n - 1]


def _minor(A: list[list[Int]], i: int, j: int) -> list[list[Int]]:
    return [[A[r][c] for c in range(len(A)) if c != j]
            for r in range(len(A)) if r != i]


def adjugate(M) -> np.ndarray:
    """adj(M) with M @ adj(M) = det(M) * I, exact."""
    A = as_pyint_matrix(M)
    n = len(A)
    if n == 1:
        return np.array([[1]], dtype=np.int64)
    adj = [[0] * n for _ in range(n)]
    for i in range(n):
        for j in range(n):
            c = det(_minor(A, i, j))
            adj[j][i] = (-c if (i + j) % 2 else c)  # note transpose
    return as_np(adj)


# ---------------------------------------------------------------------------
# Hermite normal form (column operations → upper triangular)
# ---------------------------------------------------------------------------

def hermite_normal_form(M) -> np.ndarray:
    """Column-style HNF: returns H upper-triangular with positive diagonal and
    0 ≤ H[i, j] < H[i, i] for j > i, such that H = M · U for unimodular U.

    G(H) ≅ G(M) (right-equivalent matrices generate isomorphic graphs)."""
    A = as_pyint_matrix(M)
    n = len(A)
    # process rows bottom-up; columns 0..i are the active set for row i
    for i in range(n - 1, -1, -1):
        # gcd-reduce row i over active columns 0..i until one nonzero remains
        while True:
            nz = [j for j in range(i + 1) if A[i][j] != 0]
            if not nz:
                raise ValueError("singular matrix has no HNF for our purposes")
            if len(nz) == 1:
                p = nz[0]
                break
            # pick pivot column with min |A[i][j]|, reduce the others mod it
            p = min(nz, key=lambda j: abs(A[i][j]))
            for j in nz:
                if j == p:
                    continue
                q = A[i][j] // A[i][p]  # floor division keeps remainders small
                if q:
                    for r in range(n):
                        A[r][j] -= q * A[r][p]
        # move pivot column into position i
        if p != i:
            for r in range(n):
                A[r][p], A[r][i] = A[r][i], A[r][p]
        # make diagonal positive
        if A[i][i] < 0:
            for r in range(n):
                A[r][i] = -A[r][i]
        # reduce columns to the right of i so 0 <= A[i][j] < A[i][i]
        for j in range(i + 1, n):
            q = A[i][j] // A[i][i]
            if q:
                for r in range(n):
                    A[r][j] -= q * A[r][i]
    return as_np(A)


def is_unimodular(U) -> bool:
    return abs(det(U)) == 1


def right_equivalent(M1, M2) -> bool:
    """M1 ≅ M2 ⇔ same Hermite normal form (Definition 6)."""
    return bool(np.array_equal(hermite_normal_form(M1), hermite_normal_form(M2)))


# ---------------------------------------------------------------------------
# Smith normal form (group invariants of Z^n / M Z^n)
# ---------------------------------------------------------------------------

def smith_invariants(M) -> tuple[Int, ...]:
    """Invariant factors d_1 | d_2 | ... | d_n of Z^n / M Z^n (all positive)."""
    A = as_pyint_matrix(M)
    n = len(A)
    res: list[Int] = []
    t = 0
    while t < n:
        # find a nonzero pivot in A[t:, t:]
        piv = None
        for i in range(t, n):
            for j in range(t, n):
                if A[i][j] != 0:
                    piv = (i, j)
                    break
            if piv:
                break
        if piv is None:
            raise ValueError("singular matrix")
        while True:
            # move smallest nonzero entry of the submatrix to (t, t)
            bi, bj, bv = t, t, 0
            for i in range(t, n):
                for j in range(t, n):
                    if A[i][j] != 0 and (bv == 0 or abs(A[i][j]) < bv):
                        bi, bj, bv = i, j, abs(A[i][j])
            A[t], A[bi] = A[bi], A[t]
            for r in range(n):
                A[r][t], A[r][bj] = A[r][bj], A[r][t]
            done = True
            for i in range(t + 1, n):
                q = A[i][t] // A[t][t]
                if A[i][t] % A[t][t]:
                    done = False
                for j in range(t, n):
                    A[i][j] -= q * A[t][j]
            for j in range(t + 1, n):
                q = A[t][j] // A[t][t]
                if A[t][j] % A[t][t]:
                    done = False
                for i in range(t, n):
                    A[i][j] -= q * A[i][t]
            if done:
                # ensure pivot divides every remaining entry
                ok = True
                for i in range(t + 1, n):
                    for j in range(t + 1, n):
                        if A[i][j] % A[t][t]:
                            # add row i to row t and restart reduction
                            for c in range(t, n):
                                A[t][c] += A[i][c]
                            ok = False
                            break
                    if not ok:
                        break
                if ok:
                    break
        res.append(abs(A[t][t]))
        t += 1
    res.sort()
    return tuple(res)


# ---------------------------------------------------------------------------
# residues / labelling
# ---------------------------------------------------------------------------

def canonical_label(v, H) -> np.ndarray:
    """Reduce vector(s) v modulo M into the Hermite labelling box of H=HNF(M).

    v: (..., n) int array.  Returns array of the same shape with
    0 ≤ out[..., i] < H[i, i].  Vectorised (numpy)."""
    H = np.asarray(H, dtype=np.int64)
    n = H.shape[0]
    out = np.array(v, dtype=np.int64, copy=True)
    vec = out.reshape(-1, n)
    for i in range(n - 1, -1, -1):
        q = vec[:, i] // H[i, i]          # floor division → remainder in [0, H_ii)
        vec -= q[:, None] * H[:, i][None, :]
    return out


def element_order(x, M) -> Int:
    """ord(x) in Z^n/MZ^n  =  det/gcd(det, gcd(det·M⁻¹·x))   (paper §2)."""
    d = abs(det(M))
    adjM = adjugate(M)
    s = np.sign(det(M))
    w = (s * adjM) @ np.asarray(x, dtype=np.int64)   # = det·M⁻¹·x (up to sign fix)
    g = 0
    for c in w.tolist():
        g = np.gcd(g, abs(int(c)))
    g = int(np.gcd(d, g))
    return d // g if g else 1


def cycle_copy_tables(H) -> tuple[Int, np.ndarray, np.ndarray]:
    """Static routing tables of one level of the Algorithm-1 recursion for a
    non-diagonal Hermite block H (m ≥ 2):

      * ``order``        — ord(e_m) in Z^m / H Z^m,
      * ``cycle_labels`` — (order, m) canonical labels of k·e_m,
      * ``copy_table``   — (side, order//side) cycle positions k grouped by
        the copy (last label component) they intersect (Remark 33).

    Shared by the numpy `HierarchicalRouter` and the JAX `RoutingEngine` so
    their bitwise-equality contract rests on one table construction."""
    H = np.asarray(H, dtype=np.int64)
    m = H.shape[0]
    side = int(H[m - 1, m - 1])
    e_m = np.zeros(m, dtype=np.int64)
    e_m[m - 1] = 1
    order = element_order(e_m, H)
    cyc = canonical_label(np.arange(order, dtype=np.int64)[:, None]
                          * e_m[None, :], H)
    per_copy = order // side
    table = np.zeros((side, per_copy), dtype=np.int64)
    fill = np.zeros(side, dtype=np.int64)
    for k in range(order):
        y = int(cyc[k, m - 1])
        table[y, fill[y]] = k
        fill[y] += 1
    assert (fill == per_copy).all(), "cycle does not cover copies evenly"
    return order, cyc, table


def gcd_vec(v) -> Int:
    g = 0
    for c in np.asarray(v).ravel().tolist():
        g = int(np.gcd(g, abs(int(c))))
    return g
