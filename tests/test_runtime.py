"""Substrate tests: optimizer, data pipeline, checkpointing, fault
tolerance, gradient compression, topology layer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint.checkpointing import (AsyncCheckpointer, latest_step,
                                            restore_checkpoint,
                                            save_checkpoint)
from repro.data.pipeline import DataConfig, SyntheticLMStream, reassign_shards
from repro.optim import adamw
from repro.parallel import compression
from repro.runtime.fault_tolerance import (FailureDetector, RunSupervisor,
                                           StepTimeMonitor)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = adamw.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state = adamw.update(grads, state, params, lr=5e-2,
                                     weight_decay=0.0)
    assert float(loss(params)) < 1e-2
    assert int(state.step) == 200


def test_adamw_grad_clip():
    params = {"w": jnp.ones(4)}
    state = adamw.init(params)
    huge = {"w": jnp.full(4, 1e9)}
    new_params, _ = adamw.update(huge, state, params, lr=1e-3, grad_clip=1.0)
    assert bool(jnp.isfinite(new_params["w"]).all())
    assert float(jnp.abs(new_params["w"] - params["w"]).max()) < 1e-2


def test_cosine_schedule_shape():
    sched = adamw.cosine_schedule(1e-3, warmup=10, total=100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert float(sched(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(sched(jnp.int32(100))) == pytest.approx(0.0, abs=1e-9)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=8)
    a = SyntheticLMStream(cfg, num_shards=2, shard=0).batch(7)
    b = SyntheticLMStream(cfg, num_shards=2, shard=0).batch(7)
    assert np.array_equal(a["tokens"], b["tokens"])        # reproducible
    c = SyntheticLMStream(cfg, num_shards=2, shard=1).batch(7)
    assert not np.array_equal(a["tokens"], c["tokens"])    # shards differ
    full = SyntheticLMStream(cfg, num_shards=2).global_batch(7)
    assert full["tokens"].shape == (8, 32)
    assert np.array_equal(full["tokens"][:4], a["tokens"])
    # labels are next tokens
    assert np.array_equal(full["labels"][:, :-1], full["tokens"][:, 1:])


@given(st.integers(2, 16), st.sets(st.integers(0, 15), max_size=8))
@settings(max_examples=30, deadline=None)
def test_reassign_shards_covers_everything(n, dead):
    dead = {d for d in dead if d < n}
    if len(dead) >= n:
        return
    plan = reassign_shards(n, dead)
    covered = sorted(s for lst in plan.values() for s in lst)
    assert covered == list(range(n))                 # no shard lost
    assert all(h not in dead for h in plan)          # no dead host works


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    save_checkpoint(tmp_path, 3, tree)
    save_checkpoint(tmp_path, 7, jax.tree.map(lambda x: x * 2, tree))
    assert latest_step(tmp_path) == 7
    restored = restore_checkpoint(tmp_path, 7, tree)
    assert np.allclose(np.asarray(restored["a"]), np.arange(6).reshape(2, 3) * 2)
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomic_no_partial(tmp_path):
    tree = {"a": jnp.ones(3)}
    save_checkpoint(tmp_path, 1, tree)
    # simulate crash leftovers: a tmp dir must be ignored
    (tmp_path / ".tmp_step_00000009").mkdir()
    assert latest_step(tmp_path) == 1


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    tree = {"w": jnp.arange(10).astype(jnp.float32)}
    for s in (10, 20):
        ck.save(s, jax.tree.map(lambda x: x + s, tree))
    ck.close()
    assert latest_step(tmp_path) == 20
    out = restore_checkpoint(tmp_path, 20, tree)
    assert np.allclose(np.asarray(out["w"]), np.arange(10) + 20)


def test_restore_is_elastic_shape_checked(tmp_path):
    tree = {"w": jnp.ones((4, 4))}
    save_checkpoint(tmp_path, 0, tree)
    with pytest.raises(AssertionError):
        restore_checkpoint(tmp_path, 0, {"w": jnp.ones((2, 2))})


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_straggler_detection():
    mon = StepTimeMonitor(num_hosts=4, warmup_steps=3)
    for step in range(6):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 3.0)
    assert mon.stragglers() == [2]


def test_failure_detector_with_fake_clock():
    now = [0.0]
    det = FailureDetector(num_hosts=3, timeout_s=10.0, clock=lambda: now[0])
    now[0] = 5.0
    det.heartbeat(0)
    det.heartbeat(1)
    now[0] = 12.0
    assert det.dead() == {2}


def test_supervisor_policy_end_to_end():
    now = [0.0]
    sup = RunSupervisor(
        num_hosts=4,
        monitor=StepTimeMonitor(4, warmup_steps=2),
        detector=FailureDetector(4, timeout_s=10.0, clock=lambda: now[0]))
    for _ in range(4):
        for h in range(4):
            sup.monitor.record(h, 4.0 if h == 1 else 1.0)
    now[0] = 20.0
    for h in (0, 1, 2):
        sup.detector.heartbeat(h)
    events = sup.poll()
    kinds = {e.kind for e in events}
    assert "failure" in kinds and "straggler" in kinds
    fail = next(e for e in events if e.kind == "failure")
    assert fail.detail["dead"] == [3]
    covered = sorted(s for v in fail.detail["shard_plan"].values() for s in v)
    assert covered == [0, 1, 2, 3]
    ev = sup.propose_rescale(512)
    assert ev.detail["migration"]["fresh_chips"] == 256


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_error_feedback_quantization_unbiased_over_steps():
    """Error feedback: the accumulated dequantized sum converges to the true
    gradient sum (residual carries the error forward)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    state = compression.init_state({"g": g})
    total_q = jnp.zeros_like(g)
    steps = 50
    for _ in range(steps):
        qs, scales, state = compression.compress({"g": g}, state)
        total_q = total_q + compression.decompress(qs, scales)["g"]
    err = float(jnp.abs(total_q - g * steps).max())
    assert err < float(jnp.abs(g).max()) * 0.2      # bounded drift
    # one-step error is bounded by the quantization step
    qs, scales, _ = compression.compress({"g": g}, compression.init_state({"g": g}))
    one = compression.decompress(qs, scales)["g"]
    assert float(jnp.abs(one - g).max()) <= float(scales["g"]) * 0.51


# ---------------------------------------------------------------------------
# topology layer
# ---------------------------------------------------------------------------

def test_pod_capacity_matches_paper_gains():
    from repro.core import BCC, FCC, Torus
    from repro.topology.collective_model import analyze_pod
    bcc = analyze_pod("bcc", BCC(4))
    tor = analyze_pod("t", Torus(8, 8, 4), (8, 8, 4))
    assert bcc.uniform_capacity / tor.uniform_capacity == pytest.approx(1.39, abs=0.05)
    fcc = analyze_pod("fcc", FCC(8))
    tor2 = analyze_pod("t2", Torus(16, 8, 8), (16, 8, 8))
    assert fcc.uniform_capacity / tor2.uniform_capacity == pytest.approx(1.72, abs=0.05)


def test_placement_dilation_small():
    from repro.core import BCC
    from repro.topology.placement import best_embedding
    be = best_embedding(BCC(4), (16, 16))
    assert be["axis0"]["avg"] <= 2.0
    assert be["axis1"]["avg"] <= 1.5


def test_upgrade_boxes_nest_and_cover():
    from repro.topology.upgrade import migration_stats, upgrade_plan
    for chips in (64, 128, 256):
        plan = upgrade_plan(chips)
        assert plan.new.order == chips * 2
        assert int(plan.new_is_old.sum()) == chips
        st = migration_stats(plan)
        assert st["max_hops"] <= plan.new.diameter
        assert st["avg_hops"] <= 4.0


def test_training_loss_falls_quickly():
    """Mini end-to-end: 30 steps of the reduced olmo on synthetic data."""
    from repro.launch.train import main as train_main
    out = train_main(["--arch", "olmo-1b", "--reduced", "--steps", "30",
                      "--batch", "8", "--seq", "64", "--log-every", "100"])
    assert out["last_loss"] < out["first_loss"]
