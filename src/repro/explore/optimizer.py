"""Seeded evolutionary search over the topology design space.

Dependency-free (numpy only) and offline-friendly: the loop is a plain
(mu + lambda)-style archive evolution — each generation draws a fresh
`np.random.default_rng([seed, generation])` stream, mutates archive
members (or samples fresh when the archive is thin), scores them
through the memoised `Evaluator`, and offers them to the epsilon-Pareto
`ParetoArchive`.

Determinism contract (tested): the per-generation RNG streams plus the
JSON-round-trip-exact archive/memo mean

  * the same seed produces byte-identical archive JSON, and
  * killing the run after any generation and resuming from its
    checkpoint produces the SAME final archive as the uninterrupted run.

Checkpoints are a single JSON file: archive + evaluator memo + the next
generation index + the settings fingerprint (resume refuses a
checkpoint recorded under different settings/seed — silently mixing
protocols would corrupt the front).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

import numpy as np

from .evaluate import EvalSettings, Evaluator
from .pareto import ParetoArchive
from .space import SearchSpace

CHECKPOINT_VERSION = 1


@dataclass(frozen=True)
class ExploreResult:
    archive: ParetoArchive
    generations: int            # generations actually completed (total)
    evaluations: int            # fresh (non-memoised) evaluations this run
    candidates: int             # candidates offered this run (incl. memo hits)


def _checkpoint_payload(archive: ParetoArchive, evaluator: Evaluator,
                        next_generation: int, seed: int) -> dict:
    return {"version": CHECKPOINT_VERSION,
            "seed": seed,
            "settings": evaluator.settings.to_json(),
            "generation": next_generation,
            "archive": archive.to_json(),
            "memo": evaluator.memo_to_json()}


def _write_checkpoint(path: str, payload: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, path)       # atomic: a killed run never half-writes


def load_checkpoint(path: str, settings: EvalSettings,
                    seed: int) -> tuple[ParetoArchive, list, int]:
    """Read and validate a checkpoint; returns (archive, memo-items,
    next generation).  Raises ValueError on a protocol mismatch."""
    with open(path) as f:
        d = json.load(f)
    if d.get("version") != CHECKPOINT_VERSION:
        raise ValueError(
            f"checkpoint version {d.get('version')!r} != "
            f"{CHECKPOINT_VERSION}")
    if int(d["seed"]) != seed:
        raise ValueError(
            f"checkpoint seed {d['seed']} != requested seed {seed}")
    if EvalSettings.from_json(d["settings"]) != settings:
        raise ValueError(
            "checkpoint was recorded under different EvalSettings; "
            "refusing to resume a different protocol")
    return (ParetoArchive.from_json(d["archive"]), d["memo"],
            int(d["generation"]))


def explore(space: SearchSpace | None = None,
            settings: EvalSettings | None = None, *,
            generations: int = 8, population: int = 8, seed: int = 0,
            eps: float = 1e-3, checkpoint: str | None = None,
            resume: bool = False, progress=None) -> ExploreResult:
    """Run (or resume) the evolutionary loop and return the archive.

    `progress`, when given, is called once per completed generation with
    ``(generation, archive)`` — the CLI uses it for its per-generation
    front line; tests leave it None.
    """
    space = space or SearchSpace()
    settings = settings or EvalSettings()
    evaluator = Evaluator(settings)

    start_gen = 0
    if resume and checkpoint and os.path.exists(checkpoint):
        archive, memo_items, start_gen = load_checkpoint(
            checkpoint, settings, seed)
        evaluator.load_memo(memo_items)
    else:
        archive = ParetoArchive(eps=eps)
        # score + pin the paper's reference points before generation 0
        for b in space.baselines():
            archive.add(b, evaluator.evaluate(b), baseline=True)

    offered = 0
    for gen in range(start_gen, generations):
        rng = np.random.default_rng([seed, gen])
        parents = archive.discovered()
        for _ in range(population):
            if parents and rng.integers(0, 3) > 0:   # exploit 2/3 of draws
                parent = parents[int(rng.integers(0, len(parents)))]
                cand = space.mutate(parent.candidate, rng)
            else:                                    # explore the rest
                cand = space.sample(rng)
            archive.add(cand, evaluator.evaluate(cand))
            offered += 1
            parents = archive.discovered()
        if checkpoint:
            _write_checkpoint(checkpoint, _checkpoint_payload(
                archive, evaluator, gen + 1, seed))
        if progress is not None:
            progress(gen, archive)
    return ExploreResult(archive=archive, generations=generations,
                         evaluations=evaluator.evaluations,
                         candidates=offered)
