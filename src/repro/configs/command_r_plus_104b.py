"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-plus]: GQA kv=8, no bias,
parallel attention/FFN blocks."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12_288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33_792,
    vocab_size=256_000,
    parallel_block=True,
)
