"""Abstract (ShapeDtypeStruct) inputs, params, optimizer state and caches for
every (architecture × input-shape) cell — the dry-run lowers against these,
so nothing is ever allocated.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, ShapeSpec
from repro.models import init_cache, init_params
from repro.optim import adamw


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every *data* input of the step.

    train/prefill: {tokens[, labels][, patch_embeds][, enc_frames]}
    decode:        {token, position}  (the cache comes from cache_specs)."""
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((B, 1), i32),
                "position": jax.ShapeDtypeStruct((), i32)}
    out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    if cfg.family == "vlm":
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patch_tokens, cfg.d_model), f32)
    if cfg.is_encdec:
        out["enc_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq_len, cfg.d_model), f32)
    return out


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))


def abstract_opt_state(cfg: ModelConfig):
    return jax.eval_shape(adamw.init, abstract_params(cfg))


def abstract_cache(cfg: ModelConfig, shape: ShapeSpec):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, shape.global_batch, shape.seq_len))


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS: 6·N·D for training (fwd+bwd), 2·N·D for inference,
    with N = active parameter count and D = tokens processed."""
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def active_param_count(cfg: ModelConfig) -> int:
    """Exact parameter count, with MoE experts scaled to the active top-k."""
    import math
    counts = jax.tree.map(lambda s: math.prod(s.shape),
                          abstract_params(cfg))
    total = sum(jax.tree.leaves(counts))
    if cfg.moe is not None:
        mc = cfg.moe
        fe = mc.expert_d_ff or cfg.d_ff
        per_expert = 3 * cfg.d_model * fe
        all_experts = cfg.num_layers * mc.num_experts * per_expert
        active_experts = cfg.num_layers * mc.top_k * per_expert
        total = total - all_experts + active_experts
    return total
