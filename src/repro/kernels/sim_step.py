"""Fused §6.2 simulator slot step as a Pallas kernel (`impl="fused"`).

One `pallas_call` per simulated slot fuses the three phases the batched
XLA implementation (`repro.core.simulation._make_slot_step_batched`)
expresses as separate fused families:

  1. **winner arbitration** — the segmented min over encoded priority
     keys (segment id = node·2n + requested port), realized as 2n static
     masked column-min reductions so no `(N, 2nQ, 2n)` candidate tensor
     (and no scatter) ever exists,
  2. **port-level acceptance** — the sequential same-slot space-reuse
     fixed point, unrolled over the 2n port levels on an (N, 2n) carry
     (bitwise the reference sweep's acceptance),
  3. **apply** — the one-hot clears + transit + injection where-chains
     writing the next (rec, birth, port) state.

Layout/validation contract (mirrors `repro.kernels.ops`): the wrapper
runs the kernel in interpret mode off-TPU (`interpret=not _on_tpu()` at
the call site in `repro.core.simulation`), and the differential suite
validates it against the `reference` oracle; given identical pre-drawn
traffic the fused step is bitwise-equal to `impl="batched"`.

CAVEAT — real-TPU lowering is UNVALIDATED: this container is CPU-only,
so CI exercises interpret mode exclusively.  The kernel body leans on
rank-1 iota, multi-index gathers (`flat_rec[sender, in_widx]`) and
`take_along_axis`, which Mosaic may reject or lower poorly; expect a
porting pass (2-D iota shims, gather → dynamic-slice loops, halo-tiled
phases) the first time `interpret=False` runs on hardware.  See the
ROADMAP fused-kernel frontier item.

VIRTUAL CHANNELS — this kernel is V=1-only.  The VC credit-flow router
(``SimConfig(vcs>=2)``) carries an (N, 2n, V, Q) state plus per-(port,
VC) credit counters that this kernel's flat (N, 2nQ) layout does not
model; `repro.core.simulation._get_runner` rejects `impl="fused"` with
`vcs > 1` with a clear error.  Run VC configurations with
`impl="batched"` (vectorized credit router) or `impl="reference"` (the
per-(port, VC) oracle) — see docs/simulator.md, "Virtual channels &
credit flow".

Transient faults (`repro.core.fault_schedule.FaultSchedule`) need NO
kernel changes: the kernel is epoch-oblivious by design.  The fused slot
step in `repro.core.simulation` resolves the current epoch inside the
`lax.scan` carry — gathering that slot's `link_ok` / `dst_live_fixed`
slices from the traced (E, …) stacks, dropping packets enqueued at
just-died nodes, and re-consulting `policy_ports` for stale carried
ports — and hands this kernel exactly the static-shaped per-slot masks
it has always taken.  That keeps the bitwise-parity contract with the
batched step intact under schedules (tests/test_transient_sim.py runs
the scheduled parity cells).

Tiling: the grid walks node tiles of `block_nodes` rows for the heavy
phase-3 writes — the `(tile, 2n, Q, n)` state tensors are the kernel's
big residents, so VMEM holds one tile of them at a time.  Phases 1–2 are
global (arbitration and acceptance couple every node to its neighbours
through the sender/receiver gathers) but touch only (N, 2nQ)-sized
fields, which fit VMEM comfortably for pod-scale N; with the default
`block_nodes=None` (one tile = all nodes) no work is duplicated.  Faults
and policies enter exactly as in the batched path: a `link_ok` mask
excludes dead channels from arbitration and `policy_ports` picks the
carried output port.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.core.routing_engine import policy_ports

from ._compat import CompilerParams


def _first_port(rec):
    """DOR next hop via the simulator's own `_next_port` (shared, not
    duplicated: the rule is under the bitwise-parity contract)."""
    from repro.core.simulation import _next_port
    port, _, _ = _next_port(rec)
    return port.astype(jnp.int32)


def _slot_step_kernel(rec_ref, birth_ref, port_ref, prio_ref, slot_ref,
                      want_ref, tr_r_ref, tr_p_ref, tr_v_ref, nbr_ref,
                      hop_ref, link_ok_ref, dst_live_ref,
                      # outputs
                      nrec_ref, nbirth_ref, nport_ref, deliver_ref, lat_ref,
                      can_ref, drop_ref, depp_ref,
                      *, n: int, N: int, P: int, Q: int, policy: str,
                      trivial: bool, block_nodes: int):
    # CONTRACT: this kernel mirrors `simulation._make_slot_step_batched`
    # phase for phase and must stay BITWISE-equal to it — any change to
    # the winner encoding, acceptance recurrence or apply masks there
    # must land here too (a kernel can't call the XLA step's closures, so
    # the logic is necessarily duplicated).  tests/test_fused_impl.py
    # enforces the equality on every scenario × pattern cell in CI.
    PQ = P * Q
    key_dtype = jnp.int16 if PQ <= 127 else jnp.int32
    BIG = key_dtype(np.iinfo(np.dtype(key_dtype)).max)
    NO_PORT = jnp.int8(P)
    ports = jnp.arange(P)
    ports8 = jnp.arange(P, dtype=jnp.int8)
    qi = jnp.arange(Q)[None, None, :]
    i = pl.program_id(0)
    r0 = i * block_nodes

    rec = rec_ref[...]
    birth = birth_ref[...]
    port = port_ref[...]
    prio = prio_ref[...]
    slot = slot_ref[0]
    nbr = nbr_ref[...]
    link_ok = None if trivial else link_ok_ref[...] != 0

    opp = jnp.arange(P) ^ 1
    sender = nbr[:, opp]                               # (N, P)
    receiver = nbr
    hop = hop_ref[...]                                 # (P, n) unit hops

    occ = birth >= 0
    portv = jnp.where(occ, port, NO_PORT)
    port_flat = portv.reshape(N, PQ)

    def gather_port(per_port, fill, idx):
        padded = jnp.concatenate(
            [per_port, jnp.full((N, 1), fill, per_port.dtype)], axis=1)
        return jnp.take_along_axis(padded, idx.astype(jnp.int32), axis=1)

    # ---- phase 1: winner per (node, out-port), segmented min ----
    rot = (jnp.arange(PQ, dtype=jnp.int32)[None, :] + slot) % PQ
    enc = prio.astype(key_dtype) * key_dtype(PQ) + rot.astype(key_dtype)
    w_enc = jnp.stack(
        [jnp.min(jnp.where(port_flat == ports8[p], enc, BIG), axis=1)
         for p in range(P)], axis=1)                   # (N, P)
    if link_ok is not None:
        w_enc = jnp.where(link_ok, w_enc, BIG)
    whas = w_enc < BIG
    widx = jnp.where(whas,
                     (w_enc.astype(jnp.int32) % PQ - slot) % PQ, 0)
    w_srcq = widx // Q
    is_winner = gather_port(w_enc, BIG, port_flat) == enc

    flat_rec = rec.reshape(N, PQ, n)
    flat_birth = birth.reshape(N, PQ)

    # per-link view at the receiver of in-port p
    in_has = whas[sender, ports]
    in_widx = widx[sender, ports]
    in_rec = flat_rec[sender, in_widx]                 # (N, P, n)
    in_birth = flat_birth[sender, in_widx]
    in_srcq = in_widx // Q
    rec_after = in_rec - hop[None]
    done = jnp.abs(rec_after.astype(jnp.int32)).sum(-1) == 0
    deliver = in_has & done
    turning = in_srcq != ports[None]
    need = jnp.where(turning, 2, 1)
    free0 = Q - occ.sum(axis=2)

    # ---- phase 2: acceptance fixed point, unrolled over port levels ----
    vac = jnp.zeros((N, P), jnp.int32)
    accs = []
    for p in range(P):
        acc_p = in_has[:, p] & ~done[:, p] & (
            free0[:, p] + vac[:, p] >= need[:, p])
        dep_w = (deliver[:, p] | acc_p)[receiver[:, p]] & whas[:, p]
        vac = vac + jnp.where(
            dep_w[:, None] & (w_srcq[:, p][:, None] == ports[None, :]), 1, 0)
        accs.append(acc_p)
    acc = jnp.stack(accs, axis=1)                      # (N, P)
    moved = deliver | acc
    lat = jnp.where(deliver, slot + 1 - in_birth, 0).astype(jnp.int32)

    # ---- phase 3: clears + transit/injection one-hot writes (tiled) ----
    dep_port = moved[receiver, ports] & whas
    dep_slot = is_winner & (gather_port(dep_port.astype(jnp.int8), 0,
                                        port_flat) != 0)
    birth_cleared = jnp.where(dep_slot, -1, flat_birth).reshape(N, P, Q)
    free_mask = birth_cleared < 0
    slot_f = jnp.argmax(free_mask, axis=2)
    slot_l = (Q - 1) - jnp.argmax(free_mask[:, :, ::-1], axis=2)
    if trivial:
        port_in = _first_port(rec_after)
    else:
        port_in = policy_ports(rec_after, link_ok[:, None, :], policy)

    want = want_ref[...] != 0
    tr_p = tr_p_ref[...].astype(jnp.int32)
    tr_v = tr_v_ref[...] != 0
    depcnt = dep_slot.reshape(N, P, Q).sum(axis=2)
    freeq_post = free0 + depcnt - acc
    if trivial:
        drop = jnp.zeros((N,), bool)
        can = want & (jnp.take_along_axis(
            freeq_post, tr_p[:, None], axis=1)[:, 0] >= 2) & tr_v
    else:
        drop = want & ~(dst_live_ref[...] != 0)
        ipc = jnp.minimum(tr_p, P - 1)
        can = (want & ~drop & (jnp.take_along_axis(
            freeq_post, ipc[:, None], axis=1)[:, 0] >= 2)
            & tr_v & (tr_p < P))

    def tile(a):
        return jax.lax.dynamic_slice_in_dim(a, r0, block_nodes, axis=0)

    wmask_t = tile(acc)[:, :, None] & (qi == tile(slot_f)[:, :, None])
    imask_t = (tile(can)[:, None, None]
               & (ports8[None, :, None] == tile(tr_p).astype(jnp.int8)
                  [:, None, None])
               & (qi == tile(slot_l)[:, :, None]))
    # portv (not raw port): free slots carry NO_PORT in the next state,
    # exactly like the batched step's re-bound port array
    rec_t, birth_t, port_t = tile(rec), tile(birth_cleared), tile(portv)
    nrec_ref[...] = jnp.where(
        imask_t[..., None], tile(tr_r_ref[...])[:, None, None, :],
        jnp.where(wmask_t[..., None], tile(rec_after)[:, :, None, :], rec_t))
    nbirth_ref[...] = jnp.where(
        imask_t, slot.astype(birth.dtype),
        jnp.where(wmask_t, tile(in_birth)[:, :, None].astype(birth.dtype),
                  birth_t))
    nport_ref[...] = jnp.where(
        imask_t, tile(tr_p).astype(jnp.int8)[:, None, None],
        jnp.where(wmask_t, tile(port_in)[:, :, None].astype(jnp.int8),
                  port_t))
    deliver_ref[...] = tile(deliver).astype(jnp.int8)
    lat_ref[...] = tile(lat)
    can_ref[...] = tile(can).astype(jnp.int8)
    drop_ref[...] = tile(drop).astype(jnp.int8)
    depp_ref[...] = tile(dep_port).astype(jnp.int8)


def fused_slot_step(rec, birth, port, prio, slot, want, tr_r, tr_p, tr_v,
                    nbr, link_ok=None, dst_live_fixed=None, *,
                    policy: str = "dor", block_nodes: int | None = None,
                    interpret: bool = True):
    """One fused simulator slot: (rec, birth, port) state + this slot's
    pre-drawn traffic → next state and the per-node/per-port outcome
    fields the caller reduces into counters.

    rec: (N, 2n, Q, n); birth: (N, 2n, Q); port: (N, 2n, Q) int8;
    prio: (N, 2nQ) uint8; slot: () int32; want: (N,) bool (injection
    desire incl. backlog); tr_r: (N, n) records; tr_p: (N,) int8 ports;
    tr_v: (N,) bool validity; nbr: (N, 2n) int32.  `link_ok` (N, 2n) and
    `dst_live_fixed` (N,) switch on the scenario path (both or neither).

    Returns (new_rec, new_birth, new_port, deliver, lat, can, drop,
    dep_port) — deliver/can/drop/dep_port as int8 masks, lat as int32
    latency contributions.  Bitwise-equal to the batched slot update.

    CONTRACT (latency telemetry): `lat` is slot+1−birth exactly where
    `deliver` is set and 0 elsewhere, so the wrapper reconstructs each
    delivered packet's birth as slot+1−lat.  The measured-window filter
    (birth ≥ warmup) and the age-bucket histogram both run OUTSIDE the
    kernel on these two outputs — keep them intact when changing the
    kernel, or the wrapper-side telemetry (and its bitwise parity with
    the batched step) silently breaks."""
    N, P, Q, n = rec.shape
    trivial = link_ok is None
    if block_nodes is None or N % block_nodes:
        block_nodes = N
    grid = (N // block_nodes,)
    to8 = lambda a: a.astype(jnp.int8)  # noqa: E731
    hop = np.zeros((P, n), np.int64)
    hop[np.arange(P), np.arange(P) // 2] = 1 - 2 * (np.arange(P) % 2)
    inputs = [rec, birth, port, prio, jnp.asarray(slot, jnp.int32)[None],
              to8(want), tr_r, tr_p.astype(jnp.int8), to8(tr_v), nbr,
              jnp.asarray(hop, rec.dtype),
              (jnp.ones((N, P), jnp.int8) if trivial else to8(link_ok)),
              (jnp.ones((N,), jnp.int8) if trivial
               else to8(dst_live_fixed))]

    def full_spec(a):
        return pl.BlockSpec(a.shape, lambda i, nd=a.ndim: (0,) * nd)

    def node_spec(shape):
        return pl.BlockSpec((block_nodes,) + shape[1:],
                            lambda i, nd=len(shape): (i,) + (0,) * (nd - 1))

    out_shapes = [
        jax.ShapeDtypeStruct(rec.shape, rec.dtype),
        jax.ShapeDtypeStruct(birth.shape, birth.dtype),
        jax.ShapeDtypeStruct(port.shape, jnp.int8),
        jax.ShapeDtypeStruct((N, P), jnp.int8),     # deliver
        jax.ShapeDtypeStruct((N, P), jnp.int32),    # lat
        jax.ShapeDtypeStruct((N,), jnp.int8),       # can
        jax.ShapeDtypeStruct((N,), jnp.int8),       # drop
        jax.ShapeDtypeStruct((N, P), jnp.int8),     # dep_port
    ]
    kern = functools.partial(
        _slot_step_kernel, n=n, N=N, P=P, Q=Q, policy=policy,
        trivial=trivial, block_nodes=block_nodes)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[full_spec(a) for a in inputs],
        out_specs=[node_spec(s.shape) for s in out_shapes],
        out_shape=out_shapes,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*inputs)
