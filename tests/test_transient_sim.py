"""Transient-fault timeline engine (ISSUE 5): the `FaultSchedule` time
axis threaded through all three `slot_step` implementations.

Pins the tentpole contracts:
  * a degenerate single-epoch schedule is BITWISE-equal to the static
    `Scenario` run on every scenario × pattern differential cell;
  * `delivered + in_flight + dropped == injected` holds at EVERY slot
    (warmup=0), including across link flaps and node deaths with packets
    enqueued, and no packet ever crosses a currently-dead channel;
  * a K=8-schedule `simulate_schedule_sweep` compiles exactly once
    (TRACE_COUNTS) and each lane is bitwise-equal to its single-schedule
    run;
  * `impl="fused"` stays bitwise-equal to `impl="batched"` under a
    schedule; `impl="reference"` remains the per-slot semantic oracle
    (statistical agreement).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import FaultSchedule, Scenario, Torus
from repro.core.simulation import (TRACE_COUNTS, _RUNNER_CACHE, build_tables,
                                   simulate, simulate_schedule_sweep)

G = Torus(4, 4)
TABLES = build_tables(G)
KW = dict(slots=96, warmup=0, seed=2, tables=TABLES)


def counters(r):
    return (r.delivered, r.injected, r.dropped, r.in_flight)


def check_timeline(r):
    tl = r.timeline
    assert tl is not None
    assert tl.conservation_ok(), tl.conservation_violations()
    assert tl.dead_crossings.sum() == 0
    # the timeline's final sample must agree with the run counters
    assert tl.delivered[-1] == r.delivered
    assert tl.injected[-1] == r.injected
    assert tl.dropped[-1] == r.dropped
    assert tl.in_flight[-1] == r.in_flight


# ---- the degenerate single-epoch differential cells -----------------------

CELLS = [
    (Scenario.random_link_faults(G, 2, seed=3, policy="dor"), "uniform"),
    (Scenario.random_link_faults(G, 3, seed=4, policy="adaptive"),
     "randompairings"),
    (Scenario.random_link_faults(G, 2, seed=5, policy="escape"),
     "centralsymmetric"),
    (Scenario.random_node_faults(G, 2, seed=6, policy="adaptive"),
     "uniform"),
    (Scenario.random_node_faults(G, 1, seed=7, policy="adaptive"),
     "antipodal"),
]


@pytest.mark.parametrize("scen,pattern", CELLS,
                         ids=[f"{s.policy}-{p}" for s, p in CELLS])
def test_single_epoch_schedule_bitwise_equals_static(scen, pattern):
    """E=1 schedule ≡ static scenario, counter for counter and crossing
    for crossing — the static engine is the E=1 special case."""
    static = simulate(G, pattern, 0.6, scenario=scen, **KW)
    sched = simulate(G, pattern, 0.6,
                     schedule=FaultSchedule.from_scenario(scen), **KW)
    assert counters(static) == counters(sched)
    assert np.array_equal(static.link_use, sched.link_use)
    check_timeline(sched)


def test_pristine_single_epoch_schedule_conserves():
    r = simulate(G, "uniform", 0.5, schedule=FaultSchedule(), **KW)
    check_timeline(r)
    assert r.dropped == 0


# ---- per-slot conservation under churn ------------------------------------

def test_mid_run_link_flap_conserves_every_slot():
    """The acceptance cell: a link dies mid-run and is repaired later;
    conservation is an every-slot integer identity, under both DOR
    (blocking) and adaptive (re-routing)."""
    for policy in ("dor", "adaptive"):
        flap = FaultSchedule.link_flap((1, 0), down_at=24, up_at=60,
                                       policy=policy)
        r = simulate(G, "uniform", 0.8, schedule=flap, **KW)
        check_timeline(r)


def test_node_death_drops_enqueued_packets():
    """A node dying mid-run takes its queued packets with it: they move
    from in_flight to dropped THAT slot, and conservation never breaks."""
    sched = FaultSchedule(events=((40, "node_down", 5),),
                          base=Scenario(policy="adaptive"))
    r = simulate(G, "uniform", 1.0, schedule=sched, **KW)
    check_timeline(r)
    assert r.dropped > 0
    # drops can only start at the death slot
    assert r.timeline.dropped[:40].sum() == 0
    # the dead node's channels are never crossed after death: link_use on
    # its ports equals the pre-death crossings, which the audit already
    # bounds; the exact invariant is the per-slot dead_crossings == 0
    # inside check_timeline


def test_dead_node_stops_injecting_from_backlog():
    """A node that dies with positive injection backlog must NOT keep
    injecting while dead: its backlog (pending demand, not packets) dies
    with it.  Regression: `want = want_new | backlog>0` used to bypass
    the per-epoch injection mask, so a DOR node whose links were cut
    (backlog building) injected one doomed packet per slot after death —
    +1 injected and +1 dropped every slot."""
    s = 40
    # cut every link of node 5 early so its backlog builds (DOR blocks
    # at the dead ports but demand keeps arriving at load 1.0), then
    # kill the node itself
    cut = tuple((4, "link_down", (5, p)) for p in range(2 * G.n))
    sched = FaultSchedule(events=cut + ((s, "node_down", 5),),
                          base=Scenario(policy="dor"))
    r = simulate(G, "uniform", 1.0, schedule=sched, **KW)
    check_timeline(r)
    tl = r.timeline
    # queue drops happen AT the death slot only; afterwards the dead node
    # must stay silent (no injected-then-dropped stream)
    assert tl.dropped[-1] == tl.dropped[s]
    # fused path takes the same semantics, bitwise
    rf = simulate(G, "uniform", 1.0, schedule=sched, impl="fused", **KW)
    assert counters(r) == counters(rf)
    # and the reference oracle agrees that drops stop at the death slot
    rr = simulate(G, "uniform", 1.0, schedule=sched, impl="reference", **KW)
    check_timeline(rr)
    assert rr.timeline.dropped[-1] == rr.timeline.dropped[s]


def test_fail_repair_fail_in_simulation():
    sched = FaultSchedule(events=((16, "link_down", (1, 0)),
                                  (40, "link_up", (1, 0)),
                                  (64, "link_down", (1, 0))),
                          base=Scenario(policy="dor"))
    r = simulate(G, "uniform", 0.8, schedule=sched, **KW)
    check_timeline(r)
    # while the link is dead the static audit cannot apply (it is live at
    # other times); the per-slot dead_crossings audit in check_timeline
    # is the exact guarantee


def test_epoch_boundary_off_by_one_in_simulation():
    """Kill a fixed pattern's destination at slot s: injection drops
    start EXACTLY at s (the whole of slot s sees the new world)."""
    s = 32
    sched = FaultSchedule(events=((s, "node_down", 5),),
                          base=Scenario(policy="adaptive"))
    # centralsymmetric maps some live source onto node 5, and load 1.0
    # makes that source want a packet every slot
    r = simulate(G, "centralsymmetric", 1.0, schedule=sched, **KW)
    check_timeline(r)
    tl = r.timeline
    assert tl.dropped[:s].sum() == 0
    assert tl.dropped[s] > 0


# ---- sweep: K timelines, one compile --------------------------------------

def test_k8_schedule_sweep_compiles_once_with_flaps():
    """The acceptance criterion: K=8 timelines (mid-run link flaps) ×
    one load through ONE trace/compile, per-slot conservation in every
    lane."""
    _RUNNER_CACHE.clear()
    scheds = [FaultSchedule.link_flap((i, 0), 20 + i, 50 + i,
                                      policy="adaptive")
              for i in range(8)]
    n0 = TRACE_COUNTS["batched"]
    res = simulate_schedule_sweep(G, "uniform", scheds, loads=(0.7,), **KW)
    assert TRACE_COUNTS["batched"] - n0 == 1
    assert len(res) == 8
    for rl in res:
        check_timeline(rl[0])


def test_sweep_lane_bitwise_equals_single_schedule_run():
    scheds = [FaultSchedule(events=tuple(
        (10 + j, "link_down", (4 * i + j, 0)) for j in range(3)),
        base=Scenario(policy="dor"), name=f"s{i}") for i in range(3)]
    res = simulate_schedule_sweep(G, "uniform", scheds, loads=(0.8,), **KW)
    for sched, rl in zip(scheds, res):
        single = simulate(G, "uniform", 0.8, schedule=sched, **KW)
        assert counters(single) == counters(rl[0])
        assert np.array_equal(single.timeline.delivered,
                              rl[0].timeline.delivered)


def test_sweep_pads_mixed_epoch_counts_and_seed_axis():
    """Schedules of differing E share one program (stacks padded to the
    max); loads × seeds axes nest under the schedule axis."""
    scheds = [FaultSchedule(),                                   # E=1
              FaultSchedule.link_flap((1, 0), 24, 60,
                                      policy="adaptive"),        # E=3
              FaultSchedule(events=((30, "link_down", (2, 0)),),
                            base=Scenario(policy="adaptive"))]   # E=2
    res = simulate_schedule_sweep(G, "uniform", scheds,
                                  loads=(0.4, 0.9), seeds=2, **KW)
    for st_ in res:
        assert st_.accepted().shape == (2, 2)
        for row in st_.results:
            for r in row:
                check_timeline(r)
    # the pristine lane (adopting the sweep policy) dominates the flapped
    # one on every (load, seed) cell or ties within noise; just assert
    # its exact conservation held (above) and the lane count
    assert len(res) == 3


def test_sweep_lane_with_degenerate_schedule_equals_static_scenario():
    """A static `Scenario` entry rides the schedule sweep as an E=1 lane
    and reproduces the static scenario run bitwise."""
    scen = Scenario.random_link_faults(G, 2, seed=9, policy="adaptive")
    res = simulate_schedule_sweep(
        G, "uniform", [scen, FaultSchedule.link_flap((1, 0), 24, 60,
                                                     policy="adaptive")],
        loads=(0.6,), **KW)
    static = simulate(G, "uniform", 0.6, scenario=scen, **KW)
    assert counters(res[0][0]) == counters(static)
    assert np.array_equal(res[0][0].link_use, static.link_use)


def test_schedule_node_sweep_with_dead_node_structure():
    """Dead-node timelines force live-table destination sampling for the
    whole sweep; a node-free lane shares the program and conserves."""
    scheds = [FaultSchedule(),
              FaultSchedule(events=((20, "node_down", 5),
                                    (60, "node_up", 5)),
                            base=Scenario(policy="adaptive"))]
    res = simulate_schedule_sweep(G, "uniform", scheds, loads=(0.8,), **KW)
    for rl in res:
        check_timeline(rl[0])


def test_schedule_sweep_validation():
    with pytest.raises(ValueError, match="polic"):
        simulate_schedule_sweep(
            G, "uniform",
            [FaultSchedule.link_flap((1, 0), 8, 16, policy="adaptive"),
             FaultSchedule.link_flap((1, 0), 8, 16, policy="escape")],
            **KW)
    with pytest.raises(ValueError, match="traced-mask"):
        simulate_schedule_sweep(G, "uniform", [FaultSchedule()],
                                impl="reference", **KW)
    with pytest.raises(ValueError, match=">= 1"):
        simulate_schedule_sweep(G, "uniform", [], **KW)
    with pytest.raises(ValueError, match="not both"):
        simulate(G, "uniform", 0.5, scenario=Scenario(),
                 schedule=FaultSchedule(), **KW)


# ---- cross-implementation --------------------------------------------------

def test_fused_is_bitwise_equal_under_schedule():
    sched = FaultSchedule(events=((12, "link_down", (1, 0)),
                                  (20, "node_down", 5),
                                  (40, "link_up", (1, 0)),
                                  (50, "node_up", 5)),
                          base=Scenario(policy="adaptive"))
    kw = dict(KW, slots=64)
    rb = simulate(G, "uniform", 0.7, schedule=sched, **kw)
    rf = simulate(G, "uniform", 0.7, schedule=sched, impl="fused", **kw)
    assert counters(rb) == counters(rf)
    assert np.array_equal(rb.link_use, rf.link_use)
    for k in ("delivered", "injected", "dropped", "in_flight",
              "dead_crossings"):
        assert np.array_equal(getattr(rb.timeline, k),
                              getattr(rf.timeline, k)), k


def test_reference_oracle_conserves_and_agrees():
    """The per-port reference sweep under the same schedule: exact
    conservation + audit, and statistical agreement with batched on the
    seed-averaged accepted load (different arbitration randomness)."""
    flap = FaultSchedule.link_flap((1, 0), 32, 96, policy="adaptive")
    kw = dict(KW, slots=160)
    seeds = (2, 3, 4, 5)
    acc_b, acc_r = [], []
    for s in seeds:
        kws = dict(kw, seed=s)
        rr = simulate(G, "uniform", 0.6, schedule=flap, impl="reference",
                      **kws)
        check_timeline(rr)
        rb = simulate(G, "uniform", 0.6, schedule=flap, **kws)
        acc_r.append(rr.accepted_load)
        acc_b.append(rb.accepted_load)
    mb, mr = np.mean(acc_b), np.mean(acc_r)
    assert abs(mb - mr) <= max(0.08 * mb, 0.03), (mb, mr)


def test_single_run_reference_changed_schedule_recompiles():
    """Reference keeps baked masks: a different timeline is a different
    program (full-fingerprint cache key) — documenting the contract that
    only batched/fused trace the time axis."""
    _RUNNER_CACHE.clear()
    a = FaultSchedule.link_flap((1, 0), 8, 16, policy="adaptive")
    b = FaultSchedule.link_flap((2, 0), 8, 16, policy="adaptive")
    kw = dict(KW, slots=32)
    simulate(G, "uniform", 0.5, schedule=a, impl="reference", **kw)
    n_ref = len(_RUNNER_CACHE)
    simulate(G, "uniform", 0.5, schedule=b, impl="reference", **kw)
    assert len(_RUNNER_CACHE) == n_ref + 1
    # ... while batched reuses one runner for both timelines
    n0 = TRACE_COUNTS["batched"]
    simulate(G, "uniform", 0.5, schedule=a, **kw)
    simulate(G, "uniform", 0.5, schedule=b, **kw)
    assert TRACE_COUNTS["batched"] - n0 <= 1


# ---- propcheck property: random timelines conserve -------------------------

FLAP_EVENT = st.tuples(
    st.sampled_from([0, 12, 24]),                 # bounded epoch count
    st.sampled_from(["link_down", "link_up"]),
    st.integers(min_value=0, max_value=G.order * 2 * G.n - 1))


@given(st.lists(FLAP_EVENT, min_size=0, max_size=4))
@settings(max_examples=25)
def test_random_link_timelines_conserve(raw_events):
    """Property (propcheck-shim subset): ANY link-event timeline keeps
    the per-slot conservation identity and the dead-crossing audit.
    Event slots are drawn from {0, 12, 24} so the handful of epoch-count
    structures compile once and every example reuses them."""
    events = tuple((s, k, (t // (2 * G.n), t % (2 * G.n)))
                   for s, k, t in raw_events)
    sched = FaultSchedule(events=events, base=Scenario(policy="adaptive"))
    r = simulate(G, "uniform", 0.7, schedule=sched,
                 slots=48, warmup=0, seed=1, tables=TABLES)
    check_timeline(r)
