"""OLMo-1B [arXiv:2402.00838]: non-parametric LayerNorm."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50_304,
    nonparametric_norm=True,
)
