"""VC credit-flow router (ISSUE 7): deadlock-freedom enumeration, credit
invariants, per-VC conservation, the V=1 bitwise contract, and the n=1-ring
cell that used to carry the escape-livelock caveat.

Deadlock freedom is checked the Duato way: enumerate the ESCAPE lane's
channel-dependence graph and show it cannot cycle.  VC0 only ever carries
dimension-ordered traffic (`credit_vc_select` requests it through the DOR
port — the first nonzero record dimension), records never grow under the
VC router, and a record's low dimensions stay zero once corrected.  So
every escape transition either continues the SAME directed ring (need=1,
protected by the bubble invariant: entering a ring costs 2 credits, so a
ring never fills completely) or turns into a STRICTLY higher dimension.
Contracting each directed ring to one node therefore yields a DAG — the
test walks every (source, record-table) DOR path, collects the channel
transitions, asserts the dimension monotonicity hop-by-hop, and runs a
topological sort over the ring-quotient graph on T(4,4,4,4), RTT, FCC
and BCC.  Dimension monotonicity depends only on (node, first nonzero
dim), never on magnitudes, so it also covers records partially consumed
by adaptive-lane hops before falling back to the escape lane.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import BCC, FCC, RTT, Scenario, SimConfig, Torus
from repro.core.routing_engine import credit_vc_select
from repro.core.simulation import (_init_state, _make_ctx,
                                   _make_slot_step_vc_batched,
                                   _make_traffic, build_tables, simulate)

# ---------------------------------------------------------------------------
# escape-CDG acyclicity (the deadlock-freedom enumeration)
# ---------------------------------------------------------------------------

_CDG_GRAPHS = {
    "T4444": Torus(4, 4, 4, 4),
    "RTT4": RTT(4),
    "FCC2": FCC(2),
    "BCC2": BCC(2),
}


def _ring_ids(nbr: np.ndarray) -> np.ndarray:
    """(N, P) id of the directed ring each channel (node, port) belongs
    to: the orbit of `node` under the port-p neighbor permutation."""
    N, P = nbr.shape
    rid = np.full((N, P), -1, np.int64)
    nxt = 0
    for p in range(P):
        for w in range(N):
            if rid[w, p] >= 0:
                continue
            c = w
            while rid[c, p] < 0:
                rid[c, p] = nxt
                c = int(nbr[c, p])
            nxt += 1
    return rid


def _escape_edges(g):
    """All channel-dependence edges ((w1,p1) → (w2,p2)) of escape-lane
    walks from every source × every injectable record (both Remark-30
    minimal tables), plus the neighbor table."""
    t = build_tables(g)
    nbr, n = t.neighbors, t.n
    N = t.N
    edges = set()
    for table in (t.records_a, t.records_b):
        # start every delta from every source (vertex-transitive, but the
        # channel ids are per-node — enumerate them all)
        di = np.arange(N)
        src = np.repeat(np.arange(N), N)
        rec = np.tile(table[di], (N, 1)).reshape(N * N, n).copy()
        cur = src.copy()
        prev_ch = np.full(N * N, -1, np.int64)
        while True:
            live = np.abs(rec).sum(axis=1) > 0
            if not live.any():
                break
            cur, rec, prev_ch = cur[live], rec[live], prev_ch[live]
            d = np.argmax(np.abs(rec) > 0, axis=1)
            s = rec[np.arange(len(rec)), d]
            p = 2 * d + (s < 0)
            ch = cur * (2 * n) + p
            has_prev = prev_ch >= 0
            edges.update(zip(prev_ch[has_prev].tolist(),
                             ch[has_prev].tolist()))
            cur = nbr[cur, p]
            rec[np.arange(len(rec)), d] -= np.sign(s)
            prev_ch = ch
    return edges, nbr


@pytest.mark.parametrize("name", sorted(_CDG_GRAPHS))
def test_escape_cdg_acyclic(name):
    g = _CDG_GRAPHS[name]
    edges, nbr = _escape_edges(g)
    assert edges, "escape walks produced no channel dependencies"
    P = nbr.shape[1]
    rid = _ring_ids(nbr)
    quotient = set()
    for c1, c2 in edges:
        w1, p1 = divmod(c1, P)
        w2, p2 = divmod(c2, P)
        assert w2 == nbr[w1, p1]          # a dependence follows the hop
        if p1 == p2:
            # same-ring continuation — the bubble's territory, and
            # genuinely the same directed ring
            assert rid[w1, p1] == rid[w2, p2]
            continue
        # leaving a ring must climb the dimension order strictly (DOR
        # corrects the first nonzero dimension; low dims stay zero)
        assert p2 // 2 > p1 // 2, (name, (w1, p1), (w2, p2))
        quotient.add((rid[w1, p1], rid[w2, p2]))
    # ring-quotient graph must topologically sort (Kahn) — acyclicity
    nodes = {r for e in quotient for r in e}
    indeg = {r: 0 for r in nodes}
    succ = {r: [] for r in nodes}
    for a, b in quotient:
        indeg[b] += 1
        succ[a].append(b)
    ready = [r for r in nodes if indeg[r] == 0]
    seen = 0
    while ready:
        r = ready.pop()
        seen += 1
        for b in succ[r]:
            indeg[b] -= 1
            if indeg[b] == 0:
                ready.append(b)
    assert seen == len(nodes), f"{name}: escape ring-quotient has a cycle"


# ---------------------------------------------------------------------------
# credit accounting invariants, slot by slot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("credits", [None, 3])
def test_credit_invariant_per_slot(credits):
    """credit[w,p,v] == credit_init − occupancy(w,p,v) after EVERY slot,
    never below 0, never above the advertised window."""
    g = Torus(4, 4)
    t = build_tables(g)
    ctx = _make_ctx(t, g, "uniform", 0, 4, Scenario(), vcs=2,
                    credits=credits)
    state = _init_state(ctx, 0.6, "batched")
    slots = 48
    tr = _make_traffic(ctx, state, jax.random.PRNGKey(7), slots)
    step = jax.jit(_make_slot_step_vc_batched(ctx, 0))
    cinit = ctx["credit_init"]
    for s in range(slots):
        state, _ = step(state, {k: v[s] for k, v in tr.items()})
        credit = np.asarray(state["credit"])
        occ = (np.asarray(state["birth"]) >= 0).sum(axis=3)
        assert (credit == cinit - occ).all(), f"slot {s}"
        assert credit.min() >= 0 and credit.max() <= cinit, f"slot {s}"
    assert int(state["delivered"]) > 0    # the run actually moved traffic


# ---------------------------------------------------------------------------
# per-VC conservation + batched/reference oracle agreement
# ---------------------------------------------------------------------------

_T44 = Torus(4, 4)
_T44_TAB = build_tables(_T44)
_FAULTS = Scenario(dead_links=((5, 0), (9, 2)), policy="adaptive")


def _vc_run(impl, vcs=2, scenario=None, load=0.4, credits=None):
    # warmup=0: the conservation ledger only balances when every
    # injection is counted (warmup-gated counters skip pre-warmup births)
    cfg = SimConfig(slots=160, warmup=0, seed=5, tables=_T44_TAB,
                    impl=impl, vcs=vcs, credits=credits, scenario=scenario)
    return simulate(_T44, "uniform", load, config=cfg)


@pytest.mark.parametrize("impl", ["batched", "reference"])
@pytest.mark.parametrize("scenario", [None, _FAULTS])
@pytest.mark.parametrize("vcs", [2, 3])
def test_vc_conservation(impl, scenario, vcs):
    r = _vc_run(impl, vcs=vcs, scenario=scenario)
    assert r.delivered + r.in_flight + r.dropped == r.injected
    assert r.vc_delivered.shape == (vcs,)
    # packets switch lanes hop to hop, so only the V-sums are conserved
    assert int(r.vc_delivered.sum()) == r.delivered
    assert int(r.vc_injected.sum()) == r.injected + r.dropped
    assert int(r.vc_in_flight.sum()) == r.in_flight
    assert r.delivered > 0


def test_vc_batched_vs_reference_statistical():
    """Independent arbitration streams, same physics: accepted load of
    the two VC implementations agrees within a loose band."""
    a = _vc_run("batched", load=0.5)
    b = _vc_run("reference", load=0.5)
    assert abs(a.accepted_load - b.accepted_load) < 0.06, (
        a.accepted_load, b.accepted_load)


# ---------------------------------------------------------------------------
# V=1 bitwise contract (pre-PR goldens, recorded at PR 6)
# ---------------------------------------------------------------------------

_GOLDEN_CELLS = {
    "t444_uniform": (Torus(4, 4, 4), "uniform", 0.45,
                     dict(slots=192, warmup=32, seed=1), None),
    "t444_antipodal": (Torus(4, 4, 4), "antipodal", 0.3,
                       dict(slots=192, warmup=32, seed=2), None),
    "ring_escape": (Torus(8), "uniform", 0.25,
                    dict(slots=256, warmup=0, seed=3),
                    Scenario(dead_links=((0, 0),), policy="escape")),
    "t44_adaptive_faults": (Torus(4, 4), "uniform", 0.4,
                            dict(slots=160, warmup=16, seed=5),
                            Scenario(dead_links=((5, 0), (9, 2)),
                                     policy="adaptive")),
    "t44_deadnode_dor": (Torus(4, 4), "uniform", 0.35,
                         dict(slots=160, warmup=16, seed=7),
                         Scenario(dead_nodes=(6,), policy="adaptive")),
    "fcc2_hist": (FCC(2), "uniform", 0.4,
                  dict(slots=160, warmup=16, seed=4, hist_bins=24), None),
}

# every counter of the pre-VC batched simulator on the cells above —
# recorded at ef9ac4d (PR 6), BEFORE the VC router landed.  vcs=1 +
# credits=None must keep reproducing them bit for bit.
_GOLDENS = {
    "t444_uniform": dict(delivered=4604, injected=4585, dropped=0,
                         in_flight=88, lat_count=4497,
                         accepted_load=0.449609375,
                         avg_latency_cycles=69.59306204136091),
    "t444_antipodal": dict(delivered=3160, injected=3139, dropped=0,
                           in_flight=120, lat_count=3019,
                           accepted_load=0.30859375,
                           avg_latency_cycles=122.10135806558463),
    "ring_escape": dict(delivered=175, injected=235, dropped=0,
                        in_flight=60, lat_count=175,
                        accepted_load=0.08544921875,
                        avg_latency_cycles=73.32571428571428),
    "t44_adaptive_faults": dict(delivered=810, injected=875, dropped=0,
                                in_flight=84, lat_count=795,
                                accepted_load=0.3515625,
                                avg_latency_cycles=52.548427672955974),
    "t44_deadnode_dor": dict(delivered=731, injected=743, dropped=0,
                             in_flight=30, lat_count=715,
                             accepted_load=0.3172743055555556,
                             avg_latency_cycles=51.55804195804196),
    "fcc2_hist": dict(delivered=908, injected=913, dropped=0,
                      in_flight=15, lat_count=898,
                      accepted_load=0.3940972222222222,
                      avg_latency_cycles=44.0445434298441),
}
_FCC2_HIST = np.zeros(24, np.int64)
_FCC2_HIST[2:6] = (375, 390, 113, 20)


@pytest.mark.parametrize("cell", sorted(_GOLDEN_CELLS))
def test_v1_bitwise_matches_pre_vc_goldens(cell):
    g, pattern, load, kw, scen = _GOLDEN_CELLS[cell]
    r = simulate(g, pattern, load, scenario=scen, **kw)
    gold = _GOLDENS[cell]
    for k, v in gold.items():
        got = getattr(r, k)
        if isinstance(v, float):
            assert got == v, (cell, k, got, v)     # bitwise, not approx
        else:
            assert int(got) == v, (cell, k, got, v)
    assert r.vc_delivered is None and r.vc_in_flight is None
    if "hist_bins" in kw:
        np.testing.assert_array_equal(r.latency_hist, _FCC2_HIST)
    # the SimConfig path compiles the same program: identical results
    cfg = SimConfig(scenario=scen, **kw)
    r2 = simulate(g, pattern, load, config=cfg)
    assert (r2.delivered, r2.injected, r2.accepted_load) == \
        (r.delivered, r.injected, r.accepted_load)


# ---------------------------------------------------------------------------
# the n=1-ring cell: escape lane vs the misroute heuristic
# ---------------------------------------------------------------------------

def test_ring_dead_link_vc_beats_escape_misroute():
    """T(8) with one dead link was the ROADMAP livelock caveat: the V=1
    "escape" policy ping-pongs packets trapped against the fault (60 of
    235 injected never arrive).  The VC router's restricted-DOR escape
    lane routes them out — strictly more deliveries at the same offered
    load, with conservation intact."""
    ring = Torus(8)
    rt = build_tables(ring)
    cfg = SimConfig(slots=256, warmup=0, seed=3, tables=rt)
    esc = simulate(ring, "uniform", 0.25, config=cfg.replace(
        scenario=Scenario(dead_links=((0, 0),), policy="escape")))
    vc = simulate(ring, "uniform", 0.25, config=cfg.replace(
        scenario=Scenario(dead_links=((0, 0),), policy="adaptive"), vcs=2))
    assert esc.delivered == 175                    # the caveat, pinned
    assert vc.delivered >= 2 * esc.delivered
    assert vc.accepted_load > 2 * esc.accepted_load
    assert vc.delivered + vc.in_flight + vc.dropped == vc.injected


# ---------------------------------------------------------------------------
# livelock/starvation property: low-load packets always drain
# ---------------------------------------------------------------------------

@settings(max_examples=4)
@given(seed=st.integers(0, 5), link=st.sampled_from([(0, 0), (3, 1), (9, 2)]))
def test_no_starvation_at_low_load(seed, link):
    """At low load every injected packet is eventually delivered: running
    the same seed twice as long must not accumulate in-flight packets
    (bounded drain ⇒ no livelocked/starved packet under the VC router)."""
    scen = Scenario(dead_links=(link,), policy="adaptive")
    cfg = SimConfig(warmup=0, seed=seed, tables=_T44_TAB, vcs=2,
                    scenario=scen, slots=200)
    short = simulate(_T44, "uniform", 0.05, config=cfg)
    long = simulate(_T44, "uniform", 0.05, config=cfg.replace(slots=400))
    bound = 2 * _T44.order                         # transit residue only
    assert short.in_flight <= bound
    assert long.in_flight <= bound
    assert long.delivered > short.delivered        # traffic keeps moving
    assert long.delivered + long.in_flight + long.dropped == long.injected


# ---------------------------------------------------------------------------
# credit_vc_select unit behavior
# ---------------------------------------------------------------------------

def test_credit_vc_select_prefers_max_credit_adaptive_lane():
    rec = np.array([2, -1])                        # productive: +x (0), -y (3)
    link_ok = np.ones(4, bool)
    credit = np.zeros((4, 2), np.int32)
    credit[3, 1] = 3                               # best adaptive candidate
    credit[0, 1] = 1
    port, vc = credit_vc_select(rec, link_ok, credit, "adaptive")
    assert (int(port), int(vc)) == (3, 1)


def test_credit_vc_select_falls_back_to_escape():
    rec = np.array([2, -1])
    link_ok = np.ones(4, bool)
    credit = np.zeros((4, 2), np.int32)            # no adaptive credit
    port, vc = credit_vc_select(rec, link_ok, credit, "adaptive")
    assert (int(port), int(vc)) == (0, 0)          # DOR port, escape lane
    # a dead productive port drops out of the adaptive candidate set
    credit[:, 1] = 3
    live = np.array([False, True, True, True])     # +x dead, -y alive
    port, vc = credit_vc_select(rec, live, credit, "adaptive")
    assert (int(port), int(vc)) == (3, 1)          # only live minimal port


def test_credit_vc_select_dor_stays_dimension_ordered():
    rec = np.array([0, 3])
    credit = np.arange(8, dtype=np.int32).reshape(4, 2)
    port, vc = credit_vc_select(rec, np.ones(4, bool), credit, "dor")
    assert int(port) == 2                          # first nonzero dim, +y
    assert int(vc) == 1                            # max-credit lane of it


def test_credit_vc_select_rejects_v1():
    with pytest.raises(ValueError, match="V >= 2"):
        credit_vc_select(np.array([1, 0]), np.ones(4, bool),
                         np.ones((4, 1), np.int32), "adaptive")
