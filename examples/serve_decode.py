"""Batched serving example: prefill a prompt batch, decode with KV caches.

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--arch", "qwen3-4b", "--reduced", "--batch", "4",
                "--prompt-len", "64", "--gen", "32"])
    serve_main(["--arch", "mamba2-2.7b", "--reduced", "--batch", "2",
                "--prompt-len", "64", "--gen", "16"])
