"""Pallas TPU kernels for the perf-critical compute hot-spots:
flash attention (prefill/train), decode attention (long-KV serve),
SSD intra-chunk (Mamba2), fused RMSNorm.  Each has a pure-jnp oracle in
ref.py; ops.py holds the jit'd model-facing wrappers."""
from . import ops, ref
