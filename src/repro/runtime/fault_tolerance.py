"""Fault tolerance at fleet scale: step-time monitoring, straggler
detection, failure handling policy, and the elastic rescale decision loop.

On real pods this wraps jax.distributed heartbeats; here every component is
driven through injectable clocks/timings so the logic is fully unit-tested
on CPU.  The policy pieces:

  * `StepTimeMonitor` — per-host EWMA of step durations; flags hosts whose
    EWMA exceeds `threshold ×` fleet median (stragglers),
  * `FailureDetector` — missed-heartbeat counting,
  * `RunSupervisor` — ties it together: on straggler → reassign data shards
    (repro.data.reassign_shards); on failure → restore from the latest
    checkpoint onto the surviving mesh, possibly a smaller/larger crystal
    from the §3.4 upgrade path (topology.upgrade gives the shard-migration
    plan).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


class StepTimeMonitor:
    """EWMA step-time tracker with median-based straggler flags."""

    def __init__(self, num_hosts: int, alpha: float = 0.2,
                 threshold: float = 1.5, warmup_steps: int = 5):
        self.num_hosts = num_hosts
        self.alpha = alpha
        self.threshold = threshold
        self.warmup_steps = warmup_steps
        self.ewma = [0.0] * num_hosts
        self.count = [0] * num_hosts

    def record(self, host: int, seconds: float):
        if self.count[host] == 0:
            self.ewma[host] = seconds
        else:
            self.ewma[host] = (1 - self.alpha) * self.ewma[host] + \
                self.alpha * seconds
        self.count[host] += 1

    def stragglers(self) -> list[int]:
        ready = [h for h in range(self.num_hosts)
                 if self.count[h] >= self.warmup_steps]
        if len(ready) < 2:
            return []
        vals = sorted(self.ewma[h] for h in ready)
        median = vals[len(vals) // 2]
        return [h for h in ready if self.ewma[h] > self.threshold * median]


class FailureDetector:
    """Missed-heartbeat failure detection with an injectable clock."""

    def __init__(self, num_hosts: int, timeout_s: float = 60.0,
                 clock=time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self.last_seen = {h: clock() for h in range(num_hosts)}

    def heartbeat(self, host: int):
        self.last_seen[host] = self.clock()

    def dead(self) -> set[int]:
        now = self.clock()
        return {h for h, t in self.last_seen.items()
                if now - t > self.timeout_s}


@dataclass
class SupervisorEvent:
    kind: str                      # "straggler" | "failure" | "rescale"
    detail: dict = field(default_factory=dict)


class RunSupervisor:
    """Policy loop: consume monitor signals, emit recovery actions.

    Actions are descriptions (pure data) — the launcher applies them; this
    keeps the policy deterministic and testable."""

    def __init__(self, num_hosts: int, monitor: StepTimeMonitor | None = None,
                 detector: FailureDetector | None = None):
        self.num_hosts = num_hosts
        self.monitor = monitor or StepTimeMonitor(num_hosts)
        self.detector = detector or FailureDetector(num_hosts)
        self.shard_plan = {h: [h] for h in range(num_hosts)}
        self.events: list[SupervisorEvent] = []

    def poll(self) -> list[SupervisorEvent]:
        out: list[SupervisorEvent] = []
        dead = self.detector.dead()
        if dead:
            from repro.data.pipeline import reassign_shards
            self.shard_plan = reassign_shards(self.num_hosts, dead)
            out.append(SupervisorEvent(
                "failure",
                {"dead": sorted(dead),
                 "action": "restore latest checkpoint on surviving mesh",
                 "shard_plan": self.shard_plan}))
        stragglers = [h for h in self.monitor.stragglers() if h not in dead]
        if stragglers:
            from repro.data.pipeline import reassign_shards
            plan = reassign_shards(self.num_hosts, set(stragglers))
            out.append(SupervisorEvent(
                "straggler",
                {"hosts": stragglers,
                 "action": "shed data shards from stragglers",
                 "shard_plan": plan}))
        self.events.extend(out)
        return out

    def propose_rescale(self, target_chips: int) -> SupervisorEvent:
        """Elastic rescale along the crystal upgrade path (§3.4)."""
        from repro.topology.upgrade import migration_stats, upgrade_plan
        plan = upgrade_plan(target_chips // 2) if target_chips >= 16 else None
        stats = migration_stats(plan) if plan else {}
        ev = SupervisorEvent(
            "rescale",
            {"target_chips": target_chips,
             "topology": f"crystal_for_order({target_chips})",
             "migration": stats,
             "action": "checkpoint, re-mesh, reshard (checkpoint.reshard_for_mesh)"})
        self.events.append(ev)
        return ev
