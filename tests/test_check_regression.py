"""Tests for the bench-regression gate (`benchmarks.check_regression`) —
the gate that guards the committed perf numbers is itself gated: an
injected regression must fail, a one-off load spike must be tolerated by
the best-of-runs merge, and malformed inputs must error cleanly.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))

from benchmarks.check_regression import _load, compare, main, merge_best  # noqa: E402


def doc(**metrics_by_row):
    """{'routing/x': {'engine_Mrec_s': 50}, ...} → a --json document."""
    return {"rows": [
        {"name": name, "us_per_call": 1.0, "derived": dict(derived)}
        for name, derived in metrics_by_row.items()]}


BASE = doc(**{
    "routing/FCC(8)/B=100000": {"engine_Mrec_s": 50.0, "speedup": 60.0},
    "sim/batched/N=512": {"slots_per_s": 100.0},
    "sim/sweep3/N=512": {"sweep_loadpoints_per_s": 2.0},
    "routing/FCC(8)/B=1000": {"engine_Mrec_s": 3.0},
})

# the ISSUE 4 rows: fused-impl slots/s, the K-scenario one-compile sweep
# and the device fault-BFS sweep must be covered by the suffix markers
NEW_ROWS = doc(**{
    "sim/fused/N=512": {"slots_per_s": 80.0},
    "scenarios/scen_sweep8/N=512": {"scen_sweep_loadpoints_per_s": 3.0,
                                    "speedup_vs_seq_cold": 5.0},
    "scenarios/bfs_sweep4/N=512": {"bfs_scenarios_per_s": 10.0,
                                   "device_vs_host": 7.0},
})


def test_new_pr4_rows_are_gated():
    """fused / scenario-sweep / BFS-sweep throughput metrics regress ⇒
    the gate fails; their ratio metrics stay ungated by design."""
    cur = json.loads(json.dumps(NEW_ROWS))
    for row in cur["rows"]:
        for k in row["derived"]:
            row["derived"][k] *= 0.5                     # 2× slowdown
    failures, _ = compare(NEW_ROWS, cur, tolerance=0.30)
    assert sorted(f.split(" ")[0] for f in failures) == [
        "scenarios/bfs_sweep4/N=512:bfs_scenarios_per_s",
        "scenarios/scen_sweep8/N=512:scen_sweep_loadpoints_per_s",
        "sim/fused/N=512:slots_per_s",
    ], failures


# the ISSUE 5 rows: the transient timeline run, the K-schedule
# one-compile sweep and the epoch-stacked device BFS
TRANSIENT_ROWS = doc(**{
    "transient/timeline/N=512": {"timeline_slots_per_s": 700.0,
                                 "overhead_vs_static": 1.2},
    "transient/sched_sweep8/N=512": {"sched_loadpoints_per_s": 3.0,
                                     "speedup_vs_seq_cold": 5.0},
    "transient/bfs_epochs16/N=4096": {"bfs_epochs_per_s": 0.7,
                                      "device_vs_host": 10.0},
})


def test_transient_rows_are_gated():
    """Timeline slots/s, schedule-sweep loadpoints/s and the new
    epochs_per_s suffix all gate; the overhead/speedup ratios do not."""
    cur = json.loads(json.dumps(TRANSIENT_ROWS))
    for row in cur["rows"]:
        for k in row["derived"]:
            row["derived"][k] *= 0.5
    failures, _ = compare(TRANSIENT_ROWS, cur, tolerance=0.30)
    assert sorted(f.split(" ")[0] for f in failures) == [
        "transient/bfs_epochs16/N=4096:bfs_epochs_per_s",
        "transient/sched_sweep8/N=512:sched_loadpoints_per_s",
        "transient/timeline/N=512:timeline_slots_per_s",
    ], failures


def test_transient_rows_within_tolerance_pass():
    cur = json.loads(json.dumps(TRANSIENT_ROWS))
    for row in cur["rows"]:
        for k in row["derived"]:
            row["derived"][k] *= 0.85                    # 15% < 30%
    failures, _ = compare(TRANSIENT_ROWS, cur, tolerance=0.30)
    assert failures == []


def test_injected_regression_fails():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][1]["derived"]["slots_per_s"] = 40.0      # 2.5× slowdown
    failures, _ = compare(BASE, cur, tolerance=0.30)
    assert len(failures) == 1 and "slots_per_s" in failures[0]


def test_within_tolerance_passes():
    cur = json.loads(json.dumps(BASE))
    for row in cur["rows"]:
        for k in row["derived"]:
            row["derived"][k] *= 0.75                    # 25% < 30%
    failures, notes = compare(BASE, cur, tolerance=0.30)
    assert failures == []
    assert any(n.startswith("ok ") for n in notes)


def test_speedup_ratios_and_micro_rows_not_gated():
    cur = json.loads(json.dumps(BASE))
    cur["rows"][0]["derived"]["speedup"] = 1.0           # ratio: ungated
    cur["rows"][3]["derived"]["engine_Mrec_s"] = 0.1     # B=1000: ungated
    failures, _ = compare(BASE, cur, tolerance=0.30)
    assert failures == []


def test_one_off_spike_tolerated_by_merge_best():
    """A load spike slows ONE run; per-metric best-of-runs recovers."""
    spiked = json.loads(json.dumps(BASE))
    spiked["rows"][1]["derived"]["slots_per_s"] = 30.0
    clean = json.loads(json.dumps(BASE))
    merged = merge_best([spiked, clean])
    failures, _ = compare(BASE, merged, tolerance=0.30)
    assert failures == []
    # but a regression present in BOTH runs still fails
    both = merge_best([spiked, json.loads(json.dumps(spiked))])
    failures, _ = compare(BASE, both, tolerance=0.30)
    assert len(failures) == 1


def test_rows_only_on_one_side_never_fail():
    cur = json.loads(json.dumps(BASE))
    cur["rows"] = cur["rows"][:2] + [
        {"name": "scenario/new", "us_per_call": 1.0,
         "derived": {"slots_per_s": 1.0}}]
    failures, notes = compare(BASE, cur, tolerance=0.30)
    assert failures == []
    assert any("missing from current" in n for n in notes)
    assert any("new row" in n for n in notes)


# ---------------------------------------------------------------------------
# CLI robustness
# ---------------------------------------------------------------------------

def run_main(argv):
    old = sys.argv
    sys.argv = ["check_regression"] + argv
    try:
        main()
    finally:
        sys.argv = old


def write(tmp_path, name, text):
    p = tmp_path / name
    p.write_text(text)
    return str(p)


def test_main_fails_exit1_on_regression(tmp_path, capsys):
    cur = json.loads(json.dumps(BASE))
    cur["rows"][0]["derived"]["engine_Mrec_s"] = 1.0
    b = write(tmp_path, "base.json", json.dumps(BASE))
    c = write(tmp_path, "cur.json", json.dumps(cur))
    with pytest.raises(SystemExit) as ei:
        run_main(["--baseline", b, "--current", c])
    assert ei.value.code == 1
    assert "BENCH REGRESSION" in capsys.readouterr().err


def test_main_passes_exit0_on_identical(tmp_path, capsys):
    b = write(tmp_path, "base.json", json.dumps(BASE))
    c = write(tmp_path, "cur.json", json.dumps(BASE))
    run_main(["--baseline", b, "--current", c])
    assert "bench-check passed" in capsys.readouterr().out


def test_malformed_json_errors_cleanly(tmp_path, capsys):
    """Infrastructure failures exit 2 — distinct from exit 1, which means
    a genuine regression — with a one-line message, not a traceback."""
    bad = write(tmp_path, "bad.json", "{not json!!")
    good = write(tmp_path, "good.json", json.dumps(BASE))
    for argv in (["--baseline", bad, "--current", good],
                 ["--baseline", good, "--current", bad]):
        with pytest.raises(SystemExit) as ei:
            run_main(argv)
        assert ei.value.code == 2
        assert "invalid JSON" in capsys.readouterr().err


def test_shapeless_document_errors_cleanly(tmp_path, capsys):
    norows = write(tmp_path, "norows.json", json.dumps({"meta": {}}))
    with pytest.raises(SystemExit) as ei:
        _load(norows)
    assert ei.value.code == 2
    assert "no 'rows'" in capsys.readouterr().err


def test_missing_file_errors_cleanly(tmp_path, capsys):
    good = write(tmp_path, "good.json", json.dumps(BASE))
    with pytest.raises(SystemExit) as ei:
        run_main(["--baseline", str(tmp_path / "nope.json"),
                  "--current", good])
    assert ei.value.code == 2
    assert "cannot read" in capsys.readouterr().err
