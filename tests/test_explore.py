"""Topology-explorer tests (ISSUE 10 satellite 3): seeded determinism,
Pareto-archive invariants, checkpoint/resume equivalence, and the
propcheck property that every sampled/mutated HNF candidate is valid.

All explorer runs here use analytic mode + host BFS + tiny Monte-Carlo
budgets: deterministic and fast (no per-candidate device compiles)."""
import json
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import intmat
from repro.explore import (Candidate, EvalSettings, Evaluator, Objectives,
                           ParetoArchive, SearchSpace, dominates, explore)

FAST = EvalSettings(mode="analytic", pairs=512, slots=128, fault_links=2)


def tiny_run(seed=0, generations=2, population=3, **kw):
    return explore(SearchSpace(), FAST, generations=generations,
                   population=population, seed=seed, **kw)


# ---------------------------------------------------------------------------
# dominance + archive invariants
# ---------------------------------------------------------------------------

def obj(t, p, f):
    return Objectives(throughput=t, p99=p, faulted=f)


def test_dominates_basics():
    a, b = obj(0.8, 10.0, 0.6), obj(0.5, 17.0, 0.4)
    assert dominates(a, b) and not dominates(b, a)
    assert not dominates(a, a)                    # needs a strict axis
    assert not dominates(obj(0.9, 20.0, 0.6), a)  # trade-off: incomparable


def test_nonfinite_objectives_never_dominate():
    bad = obj(math.nan, math.inf, 0.9)
    assert not dominates(bad, obj(0.1, 100.0, 0.1))
    assert dominates(obj(0.1, 100.0, 0.1), Objectives.worst())


def cand(seed):
    return SearchSpace().sample(np.random.default_rng(seed))


def test_archive_rejects_dominated_keeps_nondominated():
    a = ParetoArchive()
    assert a.add(cand(1), obj(0.8, 10.0, 0.6))
    assert not a.add(cand(2), obj(0.5, 17.0, 0.4))   # dominated: rejected
    assert a.add(cand(3), obj(0.9, 20.0, 0.6))       # trade-off: kept
    assert len(a.discovered()) == 2


def test_archive_evicts_newly_dominated():
    a = ParetoArchive()
    a.add(cand(1), obj(0.5, 17.0, 0.4))
    a.add(cand(2), obj(0.8, 10.0, 0.6))              # dominates cand(1)
    assert len(a.discovered()) == 1
    assert a.discovered()[0].objectives.throughput == 0.8


def test_archive_never_retains_a_dominated_point():
    rng = np.random.default_rng(7)
    a = ParetoArchive()
    for i in range(60):
        a.add(cand(i), obj(float(rng.uniform(0.1, 1)),
                           float(rng.uniform(5, 30)),
                           float(rng.uniform(0.1, 1))))
    disc = a.discovered()
    for x in disc:
        for y in disc:
            assert not dominates(x.objectives, y.objectives, a.eps) \
                or x is y


def test_baselines_pinned_never_evicted_never_block():
    a = ParetoArchive()
    base = cand(1)
    a.add(base, obj(0.9, 5.0, 0.9), baseline=True)
    # a baseline dominating a newcomer must NOT block it
    assert a.add(cand(2), obj(0.2, 20.0, 0.2))
    # a newcomer dominating the baseline must NOT evict it
    assert a.add(cand(3), obj(0.95, 4.0, 0.95))
    assert len([e for e in a.entries if e.baseline]) == 1
    assert a.front()[0].baseline                     # baselines listed first


def test_archive_dedups_identical_design_points():
    a = ParetoArchive()
    c = cand(1)
    assert a.add(c, obj(0.5, 10.0, 0.5))
    assert not a.add(c, obj(0.5, 10.0, 0.5))
    assert len(a.discovered()) == 1


def test_archive_json_round_trip():
    a = ParetoArchive(eps=1e-3)
    a.add(cand(1), obj(0.9, 5.0, 0.9), baseline=True)
    a.add(cand(2), obj(0.8, 10.0, 0.6))
    b = ParetoArchive.from_json(json.loads(json.dumps(a.to_json())))
    assert b.to_json() == a.to_json() and b.eps == a.eps


# ---------------------------------------------------------------------------
# the evolutionary loop: determinism, baselines, checkpoint/resume
# ---------------------------------------------------------------------------

def test_same_seed_identical_archive_json():
    a = tiny_run(seed=3).archive.to_json()
    b = tiny_run(seed=3).archive.to_json()
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_different_seeds_differ():
    a = tiny_run(seed=3).archive.to_json()
    b = tiny_run(seed=4).archive.to_json()
    assert a != b


def test_all_four_baselines_present_in_front():
    front = tiny_run().archive.front()
    names = [e.candidate.name for e in front if e.baseline]
    assert names == ["FCC(4)/128", "BCC(3)/108", "RTT(8)/128",
                     "T(8,4,4)/128"]


def test_checkpoint_resume_equals_uninterrupted(tmp_path):
    ck = str(tmp_path / "ck.json")
    full = tiny_run(seed=5, generations=4).archive.to_json()
    tiny_run(seed=5, generations=2, checkpoint=ck)
    resumed = tiny_run(seed=5, generations=4, checkpoint=ck,
                       resume=True).archive.to_json()
    assert json.dumps(full, sort_keys=True) == \
        json.dumps(resumed, sort_keys=True)


def test_resume_refuses_mismatched_protocol(tmp_path):
    ck = str(tmp_path / "ck.json")
    tiny_run(seed=5, generations=1, checkpoint=ck)
    with pytest.raises(ValueError, match="seed"):
        tiny_run(seed=6, generations=2, checkpoint=ck, resume=True)
    with pytest.raises(ValueError, match="EvalSettings"):
        explore(SearchSpace(), FAST.replace(pairs=256), generations=2,
                population=3, seed=5, checkpoint=ck, resume=True)


def test_evaluator_memoizes_by_design_point():
    ev = Evaluator(FAST)
    c = SearchSpace().torus_baseline()
    a, b = ev.evaluate(c), ev.evaluate(c)
    assert a == b and ev.evaluations == 1


def test_worst_candidate_cannot_enter_front():
    res = tiny_run()
    assert all(e.objectives != Objectives.worst()
               for e in res.archive.discovered())


# ---------------------------------------------------------------------------
# propcheck property: sampled + mutated candidates are always valid
# ---------------------------------------------------------------------------

@settings(max_examples=25)
@given(st.integers(min_value=0, max_value=10_000))
def test_sampled_candidates_always_valid(seed):
    space = SearchSpace()
    rng = np.random.default_rng(seed)
    c = space.sample(rng)
    assert space.valid(c)
    M = np.asarray(c.matrix, dtype=np.int64)
    np.testing.assert_array_equal(M, intmat.hermite_normal_form(M))
    assert space.min_nodes <= abs(int(intmat.det(M))) <= space.max_nodes


@settings(max_examples=25)
@given(st.integers(min_value=0, max_value=10_000))
def test_mutated_candidates_always_valid(seed):
    space = SearchSpace()
    rng = np.random.default_rng(seed)
    c = space.mutate(space.sample(rng), rng)
    assert space.valid(c)


@settings(max_examples=25)
@given(st.integers(min_value=0, max_value=10_000))
def test_candidate_json_round_trip(seed):
    c = SearchSpace().sample(np.random.default_rng(seed))
    assert Candidate.from_json(json.loads(json.dumps(c.to_json()))) == c
