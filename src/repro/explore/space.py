"""Declarative search space over candidate pod topologies.

A `Candidate` is one point of the paper's design space: an integral
lattice matrix (stored in Hermite normal form, so unimodular-equivalent
matrices — the same graph, Definition 6 — collapse onto one key) plus
the router/fabric parameters the simulator and the heterogeneous-link
layer expose (queue depth, virtual channels + credits, routing policy,
`LinkSpec` dimension weights and express overlays).

`SearchSpace` samples and mutates candidates inside a validity envelope:
node count in [min_nodes, max_nodes], degree ≤ degree_cap, matrix in
exact HNF (`intmat.hermite_normal_form`), diagonal ≥ 2 (no degenerate
one-node dimensions).  Mutation composes a random unimodular column op
(moving inside the equivalence class so the jitter lands on a different
representative entry) with an integer entry jitter, then re-normalises
to HNF — plus parameter jitter over the declared choices.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from repro.core import LatticeGraph, LinkSpec, intmat
from repro.core.crystals import bcc_matrix, fcc_matrix, rtt_matrix

POLICIES = ("dor", "adaptive", "escape")


@dataclass(frozen=True)
class Candidate:
    """One point of the topology design space.  `matrix` is the HNF
    lattice matrix as a tuple-of-tuples (hashable); `kind` tags how the
    point entered the space ("lattice" sampled/mutated HNF,
    "torus" diagonal mixed-radix, "baseline" pinned reference)."""

    matrix: tuple[tuple[int, ...], ...]
    kind: str = "lattice"
    name: str = ""
    queue: int = 4
    vcs: int = 1
    credits: int | None = None
    policy: str = "dor"
    dim_weights: tuple[int, ...] | None = None
    express: tuple[tuple[int, int, int], ...] | None = None

    def graph(self) -> LatticeGraph:
        return LatticeGraph(np.asarray(self.matrix, dtype=np.int64))

    def link_spec(self) -> LinkSpec | None:
        """The candidate's LinkSpec, or None when the fabric is uniform."""
        if self.dim_weights is None and self.express is None:
            return None
        kw = {}
        if self.dim_weights is not None:
            kw["dim_weights"] = self.dim_weights
        if self.express is not None:
            kw["express"] = self.express
        ls = LinkSpec(**kw)
        return None if ls.is_trivial else ls

    def key(self) -> tuple:
        """Dedup key: HNF matrix (unimodular-equivalence class) plus the
        non-topology parameters.  `kind`/`name` are labels, not state."""
        return (self.matrix, self.queue, self.vcs, self.credits,
                self.policy, self.dim_weights, self.express)

    def label(self) -> str:
        if self.name:
            return self.name
        diag = "x".join(str(r[i]) for i, r in enumerate(self.matrix))
        extras = []
        if self.queue != 4:
            extras.append(f"q{self.queue}")
        if self.vcs != 1:
            extras.append(f"v{self.vcs}")
        if self.dim_weights is not None:
            extras.append("w" + "".join(map(str, self.dim_weights)))
        if self.express is not None:
            extras.append("ex")
        tag = ("+" + "+".join(extras)) if extras else ""
        return f"H[{diag}]{tag}"

    def to_json(self) -> dict:
        return {"matrix": [list(r) for r in self.matrix], "kind": self.kind,
                "name": self.name, "queue": self.queue, "vcs": self.vcs,
                "credits": self.credits, "policy": self.policy,
                "dim_weights": (list(self.dim_weights)
                                if self.dim_weights is not None else None),
                "express": ([list(e) for e in self.express]
                            if self.express is not None else None)}

    @classmethod
    def from_json(cls, d: dict) -> "Candidate":
        return cls(
            matrix=tuple(tuple(int(x) for x in r) for r in d["matrix"]),
            kind=d["kind"], name=d["name"], queue=int(d["queue"]),
            vcs=int(d["vcs"]),
            credits=None if d["credits"] is None else int(d["credits"]),
            policy=d["policy"],
            dim_weights=(None if d["dim_weights"] is None
                         else tuple(int(x) for x in d["dim_weights"])),
            express=(None if d["express"] is None
                     else tuple(tuple(int(x) for x in e)
                                for e in d["express"])))


def _as_hnf(M: np.ndarray) -> tuple[tuple[int, ...], ...]:
    H = intmat.hermite_normal_form(M)
    return tuple(tuple(int(x) for x in row) for row in H)


@dataclass(frozen=True)
class SearchSpace:
    """The candidate envelope: 3D HNF lattices (spanning PC/FCC/BCC and
    every twisted relative) plus mixed-radix tori at matched node count,
    crossed with router/fabric parameters."""

    dims: int = 3
    min_nodes: int = 96
    max_nodes: int = 160
    degree_cap: int = 6
    queues: tuple[int, ...] = (4,)
    vcs_choices: tuple[int, ...] = (1,)
    policies: tuple[str, ...] = ("dor",)
    weight_choices: tuple[tuple[int, ...] | None, ...] = (None,)
    express_choices: tuple[tuple[tuple[int, int, int], ...] | None, ...] \
        = (None,)

    def __post_init__(self):
        if self.dims < 2:
            raise ValueError(f"dims must be >= 2, got {self.dims}")
        if not 2 <= self.min_nodes <= self.max_nodes:
            raise ValueError(
                f"need 2 <= min_nodes <= max_nodes, got "
                f"[{self.min_nodes}, {self.max_nodes}]")
        for p in self.policies:
            if p not in POLICIES:
                raise ValueError(f"unknown policy {p!r}")

    # -- validity -----------------------------------------------------------
    def valid(self, cand: Candidate) -> bool:
        """HNF-form (upper-triangular, positive diagonal ≥ 2, reduced
        off-diagonals), node count in band, degree under the cap."""
        M = np.asarray(cand.matrix, dtype=np.int64)
        if M.shape[0] != M.shape[1]:
            return False
        n = M.shape[0]
        for i in range(n):
            if M[i, i] < 2:
                return False
            for j in range(n):
                if j < i and M[i, j] != 0:
                    return False
                if j > i and not 0 <= M[i, j] < M[i, i]:
                    return False
        if not np.array_equal(M, intmat.hermite_normal_form(M)):
            return False
        order = abs(int(intmat.det(M)))
        if not self.min_nodes <= order <= self.max_nodes:
            return False
        if 2 * n > self.degree_cap:
            return False
        if cand.queue < 2 or cand.vcs < 1:
            return False
        if cand.credits is not None and not (cand.vcs >= 2
                                             and 2 <= cand.credits
                                             <= cand.queue):
            return False
        # express overlays at vcs=1 must route greedy DOR (the V=1
        # adaptive/escape heuristics score base ports only — the
        # validate_feature_combo exclusion cell)
        if cand.express is not None and cand.vcs == 1 \
                and cand.policy != "dor":
            return False
        return cand.policy in POLICIES

    # -- sampling -----------------------------------------------------------
    def _diag_in_band(self, rng: np.random.Generator) -> list[int]:
        """Random diagonal (each ≥ 2) whose product lands in the node
        band — rejection-sampled from per-entry geometric-ish draws."""
        for _ in range(256):
            diag = [int(rng.integers(2, 9)) for _ in range(self.dims)]
            order = int(np.prod(diag))
            if self.min_nodes <= order <= self.max_nodes:
                return diag
        # deterministic fallback: balanced factorisation of min_nodes
        side = max(2, round(self.min_nodes ** (1 / self.dims)))
        diag = [side] * (self.dims - 1)
        last = max(2, -(-self.min_nodes // int(np.prod(diag))))
        return diag + [last]

    def sample(self, rng: np.random.Generator) -> Candidate:
        """One uniform-ish draw from the envelope: torus (diagonal) with
        probability ~1/4, otherwise a random reduced upper-triangular
        HNF matrix; parameters drawn from the declared choices."""
        diag = self._diag_in_band(rng)
        M = np.diag(diag).astype(np.int64)
        kind = "torus"
        if rng.integers(0, 4) > 0:       # twisted lattice 3 times in 4
            kind = "lattice"
            for i in range(self.dims):
                for j in range(i + 1, self.dims):
                    M[i, j] = int(rng.integers(0, diag[i]))
        cand = Candidate(matrix=_as_hnf(M), kind=kind,
                         **self._sample_params(rng))
        return cand if self.valid(cand) else \
            replace(cand, matrix=_as_hnf(np.diag(diag)))

    def _sample_params(self, rng: np.random.Generator) -> dict:
        queue = int(_choice(rng, self.queues))
        vcs = int(_choice(rng, self.vcs_choices))
        credits = None
        if vcs >= 2 and rng.integers(0, 2):
            credits = int(rng.integers(2, queue + 1))
        policy = str(_choice(rng, self.policies))
        weights = _choice(rng, self.weight_choices)
        express = _choice(rng, self.express_choices)
        if express is not None and vcs == 1:
            policy = "dor"               # the feature-combo exclusion cell
        return {"queue": queue, "vcs": vcs, "credits": credits,
                "policy": policy, "dim_weights": weights,
                "express": express}

    # -- mutation -----------------------------------------------------------
    def mutate(self, cand: Candidate,
               rng: np.random.Generator) -> Candidate:
        """One evolutionary step: with equal odds either (a) a matrix
        move — a random unimodular column op (same graph, different
        representative) followed by a ±1/±2 entry jitter and
        re-normalisation to HNF — or (b) a parameter jitter.  Invalid
        offspring fall back to a fresh sample, so the loop never stalls
        on a boundary candidate."""
        if rng.integers(0, 2) == 0 and cand.kind != "baseline":
            M = np.asarray(cand.matrix, dtype=np.int64)
            n = M.shape[0]
            i, j = rng.integers(0, n, size=2)
            if i != j:                   # column op: col_j += ±col_i
                U = np.eye(n, dtype=np.int64)
                U[i, j] = int(rng.choice((-1, 1)))
                M = M @ U
            r, c = int(rng.integers(0, n)), int(rng.integers(0, n))
            M = M.copy()
            M[r, c] += int(rng.choice((-2, -1, 1, 2)))
            if abs(int(intmat.det(M))) >= 2:
                out = replace(cand, matrix=_as_hnf(M), kind="lattice",
                              name="")
                if self.valid(out):
                    return out
            return self.sample(rng)
        out = replace(cand, name="", **self._sample_params(rng))
        out = replace(out, kind=cand.kind if cand.kind != "baseline"
                      else "lattice")
        return out if self.valid(out) else self.sample(rng)

    # -- pinned baselines ---------------------------------------------------
    def baselines(self) -> tuple[Candidate, ...]:
        """The paper's reference points at matched order: RTT/FCC/BCC plus
        the same-order mixed-radix torus (the Table 1 comparison set)."""
        return (
            Candidate(matrix=_as_hnf(fcc_matrix(4)), kind="baseline",
                      name="FCC(4)/128"),
            Candidate(matrix=_as_hnf(bcc_matrix(3)), kind="baseline",
                      name="BCC(3)/108"),
            Candidate(matrix=_as_hnf(rtt_matrix(8)), kind="baseline",
                      name="RTT(8)/128"),
            Candidate(matrix=_as_hnf(np.diag((8, 4, 4))), kind="baseline",
                      name="T(8,4,4)/128"),
        )

    def torus_baseline(self) -> Candidate:
        """The mixed-radix torus the acceptance demo must dominate."""
        return self.baselines()[-1]


def _choice(rng: np.random.Generator, seq):
    """rng.choice over heterogeneous/None-bearing sequences (numpy's
    choice coerces; index instead)."""
    return seq[int(rng.integers(0, len(seq)))]
