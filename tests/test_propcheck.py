"""Tests for the offline hypothesis shim itself (`tests/_propcheck.py`).

The shim guards every property-test module in network-less CI, so it is
itself gated here: seeded determinism, the ≤50-example cap, `assume()`
semantics, `.filter` retry bounds, and the falsifying-example report.
The shim module is exercised DIRECTLY (not through the installed
`hypothesis` alias), so these tests are meaningful whether or not real
hypothesis is importable in the environment.
"""
import numpy as np
import pytest

import _propcheck as pc


def collect(strategy, n=20, seed=123):
    rng = np.random.default_rng(seed)
    return [strategy.example(rng) for _ in range(n)]


# ---------------------------------------------------------------------------
# strategies: determinism + domains
# ---------------------------------------------------------------------------

def test_strategies_are_seed_deterministic():
    strat = pc.tuples(pc.integers(0, 100), pc.booleans(),
                      pc.sampled_from(["a", "b", "c"]),
                      pc.lists(pc.integers(-5, 5), min_size=1, max_size=4))
    assert collect(strat) == collect(strat)
    assert collect(strat, seed=7) != collect(strat, seed=8)


def test_strategy_domains():
    for x in collect(pc.integers(-3, 3), 50):
        assert -3 <= x <= 3 and isinstance(x, int)
    for x in collect(pc.floats(0.0, 1.0), 50):
        assert 0.0 <= x <= 1.0
    for x in collect(pc.sets(pc.integers(0, 9), min_size=2, max_size=4), 20):
        assert isinstance(x, set) and 2 <= len(x) <= 4
    assert collect(pc.just(42), 5) == [42] * 5
    for x in collect(pc.one_of(pc.just("l"), pc.just("r")), 30):
        assert x in ("l", "r")


def test_map_and_filter():
    doubled = pc.integers(1, 10).map(lambda x: 2 * x)
    assert all(x % 2 == 0 for x in collect(doubled, 30))
    odd = pc.integers(0, 100).filter(lambda x: x % 2 == 1)
    assert all(x % 2 == 1 for x in collect(odd, 30))


def test_filter_retry_budget_exhausts_cleanly():
    impossible = pc.integers(0, 10).filter(lambda x: x > 10)
    with pytest.raises(RuntimeError, match="filter"):
        collect(impossible, 1)


def test_sampled_from_rejects_empty():
    with pytest.raises(ValueError):
        pc.sampled_from([])


# ---------------------------------------------------------------------------
# @given: run counts, caps, determinism
# ---------------------------------------------------------------------------

def test_given_runs_default_example_count():
    calls = []

    @pc.given(pc.integers(0, 1000))
    def prop(x):
        calls.append(x)

    prop()
    assert len(calls) == pc.DEFAULT_EXAMPLES


def test_given_is_deterministic_across_invocations():
    """The per-test rng is seeded from the test's qualified name: two
    invocations see the same example sequence."""
    runs = []

    @pc.given(pc.integers(0, 10**6))
    def prop(x):
        runs.append(x)

    prop()
    first = list(runs)
    runs.clear()
    prop()
    assert runs == first


def test_settings_honoured_below_cap():
    calls = []

    @pc.settings(max_examples=7)
    @pc.given(pc.integers())
    def prop(x):
        calls.append(x)

    prop()
    assert len(calls) == 7


def test_settings_capped_at_50():
    """Real hypothesis would run 500; the offline shim caps at 50 to keep
    network-less CI fast."""
    calls = []

    @pc.settings(max_examples=500)
    @pc.given(pc.integers())
    def prop(x):
        calls.append(x)

    prop()
    assert len(calls) == pc.DEFAULT_EXAMPLES


def test_failure_propagates_and_reports(capsys):
    @pc.given(pc.integers(5, 5))
    def prop(x):
        assert x != 5

    with pytest.raises(AssertionError):
        prop()
    err = capsys.readouterr().err
    assert "falsifying example" in err and "prop" in err


# ---------------------------------------------------------------------------
# assume()
# ---------------------------------------------------------------------------

def test_assume_skips_and_replaces_examples():
    """assume(False) discards the example; the shim still runs the full
    example budget with satisfying draws."""
    seen = []

    @pc.given(pc.integers(0, 9))
    def prop(x):
        pc.assume(x % 2 == 0)
        seen.append(x)

    prop()
    assert len(seen) == pc.DEFAULT_EXAMPLES
    assert all(x % 2 == 0 for x in seen)


def test_assume_rejecting_everything_errors():
    @pc.given(pc.integers(0, 9))
    def prop(x):
        pc.assume(False)

    with pytest.raises(RuntimeError, match="assume"):
        prop()


def test_install_is_idempotent_once_registered():
    """conftest already ran install() at session start; a second call must
    be a no-op (`hypothesis` — real or shim — is importable and wins)."""
    import hypothesis
    was_shim = getattr(hypothesis, "__propcheck__", False)
    assert pc.install() is False
    import hypothesis as again
    assert getattr(again, "__propcheck__", False) == was_shim


def test_build_modules_exposes_the_api_surface():
    hyp, st_mod = pc.build_modules()
    assert hyp.given is pc.given and hyp.assume is pc.assume
    assert hyp.settings is pc.settings and hyp.strategies is st_mod
    for name in ("integers", "booleans", "floats", "sampled_from", "lists",
                 "sets", "tuples", "just", "one_of"):
        assert callable(getattr(st_mod, name)), name
