"""Paper §3.4: analytic throughput bounds + measured channel loads."""
from __future__ import annotations

import time

import numpy as np

from repro.core import (BCC, FCC, Torus, channel_load,
                        mixed_torus_throughput_bound, route_bcc, route_fcc,
                        route_torus, symmetric_throughput_bound)
from repro.core.throughput import measured_saturation_throughput

from .util import emit


def main(quick: bool = False) -> None:
    a = 4 if quick else 8
    t0 = time.perf_counter()
    fcc_gain = symmetric_throughput_bound(FCC(a)) / \
        mixed_torus_throughput_bound(2 * a, a, a)
    bcc_gain = symmetric_throughput_bound(BCC(a)) / \
        mixed_torus_throughput_bound(2 * a, 2 * a, a)
    us = (time.perf_counter() - t0) * 1e6
    emit("throughput/FCC_vs_T(2a,a,a)", us,
         f"gain={fcc_gain:.3f};paper=1.71")
    emit("throughput/BCC_vs_T(2a,2a,a)", us,
         f"gain={bcc_gain:.3f};paper=1.37")

    # measured per-dimension channel load (edge-(a)symmetry in action)
    rng = np.random.default_rng(0)
    for name, g, router in [
        ("BCC(4)", BCC(4), lambda v: route_bcc(4, v, rng=rng)),
        ("T(8,8,4)", Torus(8, 8, 4), lambda v: route_torus((8, 8, 4), v, rng=rng)),
    ]:
        t0 = time.perf_counter()
        pairs = 20000
        v = g.labels[rng.integers(0, g.order, pairs)] - \
            g.labels[rng.integers(0, g.order, pairs)]
        load = channel_load(g, router(v))
        per_dim = load.reshape(g.order, 3, 2).mean(axis=(0, 2))
        us = (time.perf_counter() - t0) * 1e6
        emit(f"channel_load/{name}", us,
             f"per_dim={np.round(per_dim, 3).tolist()};"
             f"imbalance={per_dim.max() / per_dim.min():.2f}")

    # engine-routed saturation throughput vs the analytic Δ/k̄ bound; the
    # DOR crossing walk runs on device (channel_load_device) — numpy-walk
    # cross-check emitted alongside (identical loads, host timing)
    for name, g in [("BCC(4)", BCC(4)), ("FCC(8)", FCC(8))]:
        pairs = 5000 if quick else 50000
        t0 = time.perf_counter()
        sat = measured_saturation_throughput(g, pairs=pairs)
        us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        sat_np = measured_saturation_throughput(g, pairs=pairs,
                                                backend="numpy")
        us_np = (time.perf_counter() - t0) * 1e6
        emit(f"saturation/{name}", us,
             f"routed={sat:.3f};bound={symmetric_throughput_bound(g):.3f};"
             f"numpy_walk={sat_np:.3f};numpy_walk_us={us_np:.0f}")


if __name__ == "__main__":
    main()
