"""Unit tests for the `FaultSchedule` epoch compiler (ISSUE 5) — the
host-side half of the transient-fault engine: event normalization, the
slot→epoch boundary convention (an event at slot s takes effect FROM
slot s), fail→repair→fail chains, no-op dedup (a schedule whose events
never change anything compiles to one epoch), and a propcheck-shim
property test over random event lists.  The simulator-level timeline
tests live in tests/test_transient_sim.py.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import CompiledSchedule, FaultSchedule, Scenario, Torus

G = Torus(4, 4)
SLOTS = 64


def test_empty_schedule_single_epoch():
    c = FaultSchedule().compile(G, SLOTS)
    assert c.E == 1
    assert c.starts == (0,)
    assert np.array_equal(c.slot2epoch, np.zeros(SLOTS, np.int32))
    assert c.epochs[0].is_trivial


def test_static_base_single_epoch_is_the_scenario():
    scen = Scenario(dead_links=((3, 1),), policy="adaptive")
    c = FaultSchedule.from_scenario(scen).compile(G, SLOTS)
    assert c.E == 1 and c.policy == "adaptive"
    assert np.array_equal(c.epochs[0].link_ok(G), scen.link_ok(G))
    assert np.array_equal(c.epochs[0].node_ok(G), scen.node_ok(G))


def test_epoch_boundary_off_by_one():
    """An event at slot s starts a new epoch AT slot s: slot s−1 still
    sees the old world, slot s already sees the new one."""
    s = 17
    c = FaultSchedule(events=((s, "link_down", (2, 0)),)).compile(G, SLOTS)
    assert c.E == 2
    assert c.starts == (0, s)
    assert c.epoch_of(s - 1) == 0
    assert c.epoch_of(s) == 1
    assert c.scenario_at(s - 1).link_ok(G)[2, 0]
    assert not c.scenario_at(s).link_ok(G)[2, 0]


def test_slot_zero_and_out_of_range_events():
    """Events at slot ≤ 0 fold into the initial state; events at
    slot ≥ slots never fire in this run."""
    # the never-reached link (2, 1) is chosen non-incident to dead node 5
    c = FaultSchedule(events=((0, "link_down", (2, 0)),
                              (-3, "node_down", 5),
                              (SLOTS, "link_down", (2, 1)),
                              (SLOTS + 9, "node_down", 7))
                      ).compile(G, SLOTS)
    assert c.E == 1
    assert not c.epochs[0].link_ok(G)[2, 0]
    assert not c.epochs[0].node_ok(G)[5]
    assert c.epochs[0].link_ok(G)[2, 1]        # never-reached event dropped
    assert c.epochs[0].node_ok(G)[7]


def test_fail_repair_fail_same_link():
    link = (6, 2)
    c = FaultSchedule(events=((10, "link_down", link),
                              (20, "link_up", link),
                              (30, "link_down", link))).compile(G, SLOTS)
    assert c.E == 4
    assert c.starts == (0, 10, 20, 30)
    alive = [c.epochs[e].link_ok(G)[6, 2] for e in range(4)]
    assert alive == [True, False, True, False]
    # the reverse channel dies/revives in lockstep (links fail whole)
    v = int(G.neighbor_indices[6, 2])
    rev = [c.epochs[e].link_ok(G)[v, 3] for e in range(4)]
    assert rev == alive


def test_link_identity_is_undirected():
    """Killing (u, p) and repairing via the reverse endpoint (v, p^1)
    must cancel — the canonical undirected identity matches them."""
    u, p = 6, 2
    v = int(G.neighbor_indices[u, p])
    c = FaultSchedule(events=((10, "link_down", (u, p)),
                              (20, "link_up", (v, p ^ 1)))).compile(G, SLOTS)
    assert c.E == 3
    assert c.epochs[2].link_ok(G)[u, p]


def test_node_death_takes_links_and_returns():
    c = FaultSchedule(events=((8, "node_down", 5),
                              (24, "node_up", 5))).compile(G, SLOTS)
    assert c.E == 3
    assert not c.epochs[1].node_ok(G)[5]
    assert not c.epochs[1].link_ok(G)[5].any()
    assert c.epochs[2].node_ok(G)[5]
    assert c.epochs[2].link_ok(G)[5].all()
    assert c.has_dead_nodes          # any epoch with dead nodes counts


def test_noop_events_create_no_epochs():
    """Repairing a live link / re-killing a dead one changes nothing and
    must not split the run into spurious epochs."""
    c = FaultSchedule(events=((10, "link_up", (2, 0)),
                              (20, "node_up", 5))).compile(G, SLOTS)
    assert c.E == 1
    base = Scenario(dead_links=((2, 0),))
    c2 = FaultSchedule(events=((10, "link_down", (2, 0)),),
                       base=base).compile(G, SLOTS)
    assert c2.E == 1


def test_same_slot_events_apply_in_listed_order():
    c = FaultSchedule(events=((10, "link_down", (2, 0)),
                              (10, "link_up", (2, 0)))).compile(G, SLOTS)
    assert c.E == 1                   # down then up at slot 10 = no-op
    c2 = FaultSchedule(events=((10, "link_up", (2, 0)),
                               (10, "link_down", (2, 0)),),
                       base=Scenario(dead_links=((2, 0),))
                       ).compile(G, SLOTS)
    assert c2.E == 1                  # up then down: still dead
    assert not c2.epochs[0].link_ok(G)[2, 0]


def test_validation_errors():
    with pytest.raises(ValueError, match="unknown event kind"):
        FaultSchedule(events=((3, "link_explode", (1, 0)),))
    with pytest.raises(ValueError, match="triple"):
        FaultSchedule(events=("link_down",))
    with pytest.raises(ValueError, match="node, port"):
        FaultSchedule(events=((1, "link_down", 5),))     # bare int target
    with pytest.raises(ValueError, match="single node"):
        FaultSchedule(events=((1, "node_down", (5, 3)),))  # pair for a node
    with pytest.raises(ValueError, match="slots"):
        FaultSchedule().compile(G, 0)
    with pytest.raises(ValueError, match="repair slot"):
        FaultSchedule.link_flap((1, 0), down_at=20, up_at=20)
    with pytest.raises(ValueError, match="unknown policy"):
        FaultSchedule(base=Scenario(policy="psychic"))


def test_with_policy_and_properties():
    f = FaultSchedule.link_flap((1, 0), 8, 16, policy="dor")
    assert f.policy == "dor" and not f.is_static
    g2 = f.with_policy("escape")
    assert g2.policy == "escape"
    assert g2.events == f.events


def test_link_flap_keeps_base_policy():
    """`link_flap` without an explicit policy preserves the base
    scenario's policy instead of silently resetting it to DOR."""
    base = Scenario(policy="adaptive", dead_links=((3, 1),))
    f = FaultSchedule.link_flap((1, 0), 8, 16, base=base)
    assert f.policy == "adaptive"
    assert f.base.dead_links == base.dead_links
    # an explicit policy still wins
    assert FaultSchedule.link_flap((1, 0), 8, 16, policy="escape",
                                   base=base).policy == "escape"


EVENT = st.tuples(
    st.integers(min_value=-4, max_value=SLOTS + 4),
    st.sampled_from(["link_down", "link_up", "node_down", "node_up"]),
    st.integers(min_value=0, max_value=G.order * 2 * G.n - 1))


def _mk_event(ev):
    slot, kind, raw = ev
    if kind.startswith("link"):
        return (slot, kind, (raw // (2 * G.n), raw % (2 * G.n)))
    return (slot, kind, raw % (G.order - 1) + 1)   # keep node 0 alive


@given(st.lists(EVENT, min_size=0, max_size=10))
@settings(max_examples=50)
def test_random_event_lists_compile_consistently(raw_events):
    """Property: any event list compiles; the slot→epoch map is monotone,
    starts at epoch 0, changes only at event slots, and `scenario_at`
    replays the event fold exactly."""
    sched = FaultSchedule(events=tuple(_mk_event(e) for e in raw_events))
    c = sched.compile(G, SLOTS)
    s2e = c.slot2epoch
    assert s2e.shape == (SLOTS,)
    assert s2e[0] == 0
    assert (np.diff(s2e) >= 0).all()
    assert s2e[-1] == c.E - 1
    event_slots = {max(s, 0) for s, _, _ in sched.events if s < SLOTS}
    for i in range(1, SLOTS):
        if s2e[i] != s2e[i - 1]:
            assert i in event_slots
            assert c.starts[s2e[i]] == i
    # epochs are deduped: consecutive epochs always differ
    for a, b in zip(c.epochs, c.epochs[1:]):
        assert (a.dead_links != b.dead_links
                or a.dead_nodes != b.dead_nodes)
    # replay: fold the events by hand and compare the final epoch
    dead_links, dead_nodes = set(), set()
    nbr = G.neighbor_indices
    for slot, kind, tgt in sched.events:
        if slot >= SLOTS:
            continue
        if kind.startswith("link"):
            u, p = tgt
            key = min((u, p), (int(nbr[u, p]), p ^ 1))
            (dead_links.add if kind == "link_down"
             else dead_links.discard)(key)
        else:
            (dead_nodes.add if kind == "node_down"
             else dead_nodes.discard)(tgt)
    final = c.epochs[-1]
    assert set(final.dead_links) == dead_links
    assert set(final.dead_nodes) == dead_nodes


def test_precompiled_schedule_slots_mismatch_raises():
    """Every schedule-taking API funnels through `ensure_compiled`: a
    CompiledSchedule bound to a different run length must fail loudly,
    not silently report epochs the run never reaches."""
    from repro.core.distances import distance_stats
    from repro.core.fault_schedule import ensure_compiled
    from repro.core.throughput import channel_load_stats
    c = FaultSchedule.link_flap((1, 0), 8, 16).compile(G, 128)
    with pytest.raises(ValueError, match="compiled for 128"):
        ensure_compiled(c, G, 64)
    with pytest.raises(ValueError, match="compiled for 128"):
        distance_stats(G, schedule=c, slots=64)
    with pytest.raises(ValueError, match="compiled for 128"):
        channel_load_stats(G, schedule=c, slots=64)
    assert ensure_compiled(c, G, 128) is c


def test_random_events_constructor_is_deterministic():
    a = FaultSchedule.random_events(G, 6, SLOTS, seed=3, node_events=True)
    b = FaultSchedule.random_events(G, 6, SLOTS, seed=3, node_events=True)
    assert a.events == b.events
    ca = a.compile(G, SLOTS)
    assert isinstance(ca, CompiledSchedule) and ca.E >= 1
