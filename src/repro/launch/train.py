"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 300 --batch 16 --seq 256 --ckpt /tmp/run1

Runs on whatever devices exist (1 CPU here; a pod in production — the same
code path the dry-run lowers).  Features: synthetic data pipeline, AdamW +
cosine schedule, async checkpointing with restart, straggler monitor,
optional gradient compression.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointing import (AsyncCheckpointer, latest_step,
                                            restore_checkpoint)
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.models import forward, init_params, param_count
from repro.models.common import cross_entropy
from repro.optim import adamw
from repro.runtime.fault_tolerance import StepTimeMonitor


def make_step(cfg, base_lr: float, total_steps: int, remat: str):
    schedule = adamw.cosine_schedule(base_lr, warmup=max(total_steps // 20, 1),
                                     total=total_steps)

    @jax.jit
    def step(params, opt_state, tokens, labels):
        def loss_fn(p):
            logits, aux = forward(p, cfg, tokens, remat=remat)
            return cross_entropy(logits, labels) + aux

        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr = schedule(opt_state.step + 1)
        params, opt_state = adamw.update(grads, opt_state, params, lr=lr)
        return params, opt_state, loss

    return step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw.init(params)
    print(f"arch={cfg.name} params={param_count(params):,} "
          f"devices={jax.device_count()}")

    data = SyntheticLMStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch))
    step_fn = make_step(cfg, args.lr, args.steps, args.remat)
    monitor = StepTimeMonitor(num_hosts=1)

    start = 0
    ckpt = None
    if args.ckpt:
        ckpt = AsyncCheckpointer(args.ckpt)
        last = latest_step(args.ckpt)
        if last is not None:
            params = restore_checkpoint(args.ckpt, last, params)
            opt_state = restore_checkpoint(
                args.ckpt + "/opt", last, opt_state) \
                if latest_step(args.ckpt + "/opt") == last else opt_state
            start = last + 1
            print(f"restored checkpoint step {last}")

    losses = []
    for step in range(start, args.steps):
        batch = data.global_batch(step)
        t0 = time.time()
        params, opt_state, loss = step_fn(
            params, opt_state,
            jnp.asarray(batch["tokens"]), jnp.asarray(batch["labels"]))
        loss = float(loss)
        monitor.record(0, time.time() - t0)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({time.time() - t0:.2f}s/step)", flush=True)
        if ckpt and step % args.ckpt_every == 0 and step > 0:
            ckpt.save(step, params)
    if ckpt:
        ckpt.close()
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "losses": losses}


if __name__ == "__main__":
    main()
