"""Parity suite for the Pallas fused slot step (`impl="fused"`, ISSUE 4).

Quick shapes, interpret mode (this container is CPU-only; the kernel
compiles for real on TPU).  The contract is two-layered:

  * **bitwise vs batched** — the fused kernel consumes the same pre-drawn
    traffic and encodes the same arbitration keys, so its counters must
    equal `impl="batched"` integer-for-integer on every cell, and
  * **differential vs reference** — the same scenario × pattern cells the
    batched implementation is validated on (`tests/test_scenarios.py`)
    hold for fused, within the same ±5 %/point band.

These run in the offline CI matrix (slow-ok: interpret-mode Pallas traces
each slot's kernel into the scan, so shapes here stay small).
"""
import numpy as np
import pytest

from repro.core import Scenario, Torus
from repro.core.simulation import build_tables, simulate, simulate_sweep

G = Torus(4, 4)
TABLES = build_tables(G)
KW = dict(slots=128, warmup=0, seed=2, tables=TABLES)

SCENARIOS = {
    "baseline": None,
    "links2/dor": Scenario.random_link_faults(G, 2, seed=1, policy="dor"),
    "links2/adaptive": Scenario.random_link_faults(G, 2, seed=1,
                                                   policy="adaptive"),
    "links2/escape": Scenario.random_link_faults(G, 2, seed=1,
                                                 policy="escape"),
    "nodes1/adaptive": Scenario(dead_nodes=(6,), policy="adaptive"),
}


@pytest.mark.parametrize("pattern", ("uniform", "centralsymmetric"))
@pytest.mark.parametrize("scen_name", sorted(SCENARIOS))
def test_fused_bitwise_equals_batched(scen_name, pattern):
    scen = SCENARIOS[scen_name]
    b = simulate(G, pattern, 0.6, scenario=scen, **KW)
    f = simulate(G, pattern, 0.6, scenario=scen, impl="fused", **KW)
    assert (b.delivered, b.injected, b.in_flight, b.dropped) == \
           (f.delivered, f.injected, f.in_flight, f.dropped), (scen_name,
                                                               pattern)
    if scen is not None:
        assert np.array_equal(b.link_use, f.link_use)


@pytest.mark.parametrize("policy", ("adaptive", "dor"))
def test_fused_differential_vs_reference(policy):
    """The scenario differential cells at quick shapes: fused load curve ≡
    reference within ±5 % per point (seed-averaged), conservation and the
    dead-channel audit exact on every (load, seed) run.  T(4,4,4): big
    enough that arbitration-stream noise sits inside the band (at N=16
    even batched-vs-reference drifts past it at saturation)."""
    g = Torus(4, 4, 4)
    t = build_tables(g)
    scen = Scenario.random_link_faults(g, 3, seed=1, policy=policy)
    loads = (0.3, 0.8)
    acc = {}
    for impl in ("fused", "reference"):
        st = simulate_sweep(g, "uniform", loads, seeds=3, scenario=scen,
                            impl=impl, slots=128, warmup=0, seed=2,
                            tables=t)
        for row in st.results:
            for r in row:
                assert r.delivered + r.in_flight + r.dropped == r.injected
                assert int(r.link_use[~scen.link_ok(g)].sum()) == 0
        acc[impl] = st.accepted_mean()
    diff = np.abs(acc["fused"] - acc["reference"])
    assert (diff <= np.maximum(0.05 * acc["reference"], 0.025)).all(), acc


def test_fused_conservation_on_escape_ring():
    """The documented n=1-ring escape livelock: even the pathological cell
    conserves exactly under the fused kernel."""
    ring = Torus(8)
    t = build_tables(ring)
    scen = Scenario(dead_links=((0, 0),), policy="escape")
    r = simulate(ring, "uniform", 0.25, slots=128, warmup=0, seed=3,
                 tables=t, scenario=scen, impl="fused")
    assert r.delivered + r.in_flight + r.dropped == r.injected
    assert int(r.link_use[~scen.link_ok(ring)].sum()) == 0


def test_fused_kernel_node_tiling_exact():
    """Grid-tiled kernel (block_nodes < N) == single-tile kernel, output
    for output — the VMEM tiling changes residency, never results."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.sim_step import fused_slot_step
    key = jax.random.PRNGKey(0)
    N, P, Q, n = G.order, 2 * G.n, 4, G.n
    ks = jax.random.split(key, 8)
    rec = jax.random.randint(ks[0], (N, P, Q, n), -3, 4).astype(jnp.int8)
    birth = jnp.where(jax.random.uniform(ks[1], (N, P, Q)) < 0.5, 3,
                      -1).astype(jnp.int16)
    port = jax.random.randint(ks[2], (N, P, Q), 0, P).astype(jnp.int8)
    prio = jax.random.bits(ks[3], (N, P * Q), jnp.uint8)
    nbr = jnp.asarray(G.neighbor_indices.astype(np.int32))
    want = jax.random.uniform(ks[4], (N,)) < 0.5
    tr_r = jax.random.randint(ks[5], (N, n), -3, 4).astype(jnp.int8)
    tr_p = jax.random.randint(ks[6], (N,), 0, P).astype(jnp.int8)
    tr_v = jnp.ones((N,), bool)
    args = (rec, birth, port, prio, jnp.int32(5), want, tr_r, tr_p, tr_v,
            nbr)
    whole = fused_slot_step(*args)
    tiled = fused_slot_step(*args, block_nodes=4)
    for w, t_ in zip(whole, tiled):
        assert np.array_equal(np.asarray(w), np.asarray(t_))


def test_fused_sweep_and_scenario_sweep():
    """The fused runner composes with the sweep vmaps: load×seed sweeps
    and the K-scenario sweep both accept impl="fused" and match batched
    bitwise."""
    from repro.core.simulation import simulate_scenario_sweep
    scen = SCENARIOS["links2/adaptive"]
    kw = dict(slots=64, warmup=0, seed=0, tables=TABLES)
    sf = simulate_sweep(G, "uniform", (0.3, 0.8), seeds=2, scenario=scen,
                        impl="fused", **kw)
    sb = simulate_sweep(G, "uniform", (0.3, 0.8), seeds=2, scenario=scen,
                        impl="batched", **kw)
    assert np.array_equal(sf.accepted(), sb.accepted())
    scens = [Scenario.random_link_faults(G, k, seed=k, policy="adaptive")
             for k in (1, 2)]
    rf = simulate_scenario_sweep(G, "uniform", scens, loads=(0.5,),
                                 impl="fused", **kw)
    rb = simulate_scenario_sweep(G, "uniform", scens, loads=(0.5,),
                                 impl="batched", **kw)
    assert [r[0].delivered for r in rf] == [r[0].delivered for r in rb]


def test_unknown_impl_rejected():
    with pytest.raises(ValueError, match="unknown simulator impl"):
        simulate(G, "uniform", 0.5, impl="pallas", **KW)
