"""The benchmark driver's CLI contract: an unknown --only section name
must be a clear upfront error listing the valid choices (ISSUE 4
satellite) — not a generic "section failed" swallowed by the driver's
keep-going exception handler.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))

from benchmarks import run as bench_run  # noqa: E402
from benchmarks import util  # noqa: E402


def _main(argv):
    old = sys.argv
    sys.argv = ["benchmarks.run"] + argv
    try:
        bench_run.main()
    finally:
        sys.argv = old


def test_unknown_section_is_a_clear_upfront_error():
    with pytest.raises(SystemExit) as e:
        _main(["--only", "tabel1,routing"])
    msg = str(e.value.code)
    assert "unknown section" in msg and "tabel1" in msg
    assert "routing" not in msg.split("choose from")[0].replace(
        "tabel1,", "")         # only the bad name is reported as unknown
    for valid in ("table1", "sim", "scenarios", "transient"):
        assert valid in msg.split("choose from")[1]


def test_known_sections_still_run(capsys):
    util.reset()
    _main(["--only", "table1", "--quick"])
    out = capsys.readouterr().out
    assert "name,us_per_call,derived" in out
    assert any(r[0].startswith("table1") for r in util.ROWS)
