from . import _compat

# publish jax.shard_map / jax.sharding.AxisType / make_mesh(axis_types=...)
# adapters on jax versions that predate them (no-op on modern jax)
_compat.install()

from . import sharding  # noqa: E402  (sharding may touch the patched API)
from .sharding import (activation_rules, constrain,  # noqa: E402
                       make_activation_rules, make_param_specs, named_tree)
