"""Unified simulator configuration (`SimConfig`) for every `simulate*`
entry point.

PRs 2–6 grew the `simulate` family ten shared keyword arguments (`slots`,
`warmup`, `queue`, `seed`, `tables`, `impl`, `scenario`, `schedule`,
`hist_bins`, and now `vcs`/`credits`); each new axis had to be threaded
through five signatures and three internal planners.  `SimConfig` bundles
them into ONE frozen value object:

    cfg = SimConfig(slots=1024, impl="batched", vcs=2,
                    scenario=Scenario.random_link_faults(g, 4))
    simulate(g, "uniform", 0.6, config=cfg)
    simulate_sweep(g, "uniform", loads, config=cfg, seeds=4)

Every entry point still accepts the historical kwargs — they are a thin
shim over `SimConfig.from_kwargs`, which raises when a kwarg is passed
ALONGSIDE a config carrying the same field (an ambiguous call is a bug at
the call site, never a silent preference).  Validation that used to be
duplicated per entry point (`scenario`/`schedule` mutual exclusion, impl
and vcs/credits checks) lives once in `__post_init__`, so every path
raises the same error.

New in this PR, the virtual-channel axis:

  * ``vcs`` — virtual channels per (node, port); 1 (default) is the
    single-FIFO pre-VC router, bitwise-unchanged.  ``vcs > 1`` switches
    to the credit-flow VC router (VC0 = restricted-DOR escape lane,
    VCs 1.. = credit-aware adaptive lanes — see docs/simulator.md).
  * ``credits`` — per-(port, VC) credit window (advertised downstream
    buffer space); None means the full queue depth.  Must satisfy
    ``2 <= credits <= queue`` (a window of 1 cannot admit the 2-slot
    injection/turn bubble, so it would silence the escape lane).
"""
from __future__ import annotations

from dataclasses import dataclass, fields, replace

from .fault_schedule import FaultSchedule
from .link_spec import LinkSpec
from .scenario import Scenario

SIM_IMPLS = ("batched", "reference", "fused")


def validate_feature_combo(*, impl: str | None = None, vcs: int = 1,
                           links_trivial: bool = True,
                           express: bool = False,
                           policy: str = "dor") -> None:
    """The single source of truth for unsupported feature combinations.

    `SimConfig.__post_init__` calls this with the user-facing fields;
    `simulation._make_ctx` / `_get_runner` call it again with the resolved
    context so direct internal callers hit the SAME actionable message.
    Passing `impl=None` skips the impl-specific cells (not yet known).

    The remaining exclusion cells of the feature-compatibility matrix
    (docs/simulator.md) are:

      * fused × vcs>1            — the Pallas kernel is V=1-only
      * fused × non-trivial links — the kernel is weight-1/no-overlay
      * express × vcs=1 × adaptive/escape policy — faulted express
        fabrics at V=1 route with greedy weighted DOR only; the V=1
        adaptive/escape heuristics score base-lattice ports
    """
    if impl == "fused":
        if vcs > 1:
            raise ValueError(
                "impl='fused' (the Pallas slot-step kernel) is V=1-only"
                "; run vcs>1 with impl='batched' or 'reference' (see "
                "docs/simulator.md, 'Virtual channels & credit flow')")
        if not links_trivial:
            raise ValueError(
                "impl='fused' (the Pallas slot-step kernel) is "
                "weight-1/no-overlay-only; run heterogeneous "
                "LinkSpecs with impl='batched' or 'reference' "
                "(see docs/simulator.md, 'Heterogeneous links')")
    if express and vcs == 1 and policy in ("adaptive", "escape"):
        raise ValueError(
            f"express-channel overlays at vcs=1 route with greedy "
            f"weighted DOR only (dead express hops fall back to base "
            f"ports); the V=1 {policy!r} policy scores base-lattice "
            f"ports — use policy='dor' or the VC router (vcs >= 2, "
            f"whose adaptive lanes and escape fallback understand the "
            f"extended port axis)")

# fields an entry point may also receive as a legacy kwarg; used by
# `from_kwargs` to build the config and to name conflicts precisely
_FIELD_NAMES: tuple[str, ...] = (
    "slots", "warmup", "queue", "seed", "tables", "impl", "scenario",
    "schedule", "hist_bins", "vcs", "credits", "links")


@dataclass(frozen=True)
class SimConfig:
    """Frozen bundle of every run-shaping `simulate*` parameter (the
    per-call inputs — graph, pattern, loads, seeds, fold — stay call
    arguments: they name *what* to run, the config names *how*)."""

    slots: int = 512
    warmup: int = 128
    queue: int = 4
    seed: int = 0
    tables: object | None = None        # SimTables; kept untyped to avoid
    impl: str = "batched"               # a circular simulation import
    scenario: Scenario | None = None
    schedule: FaultSchedule | None = None
    hist_bins: int = 0
    vcs: int = 1
    credits: int | None = None
    links: LinkSpec | None = None

    def __post_init__(self):
        if self.impl not in SIM_IMPLS:
            raise ValueError(
                f"unknown simulator impl {self.impl!r}; expected one of "
                f"{SIM_IMPLS}")
        if self.scenario is not None and self.schedule is not None:
            # the one shared home of the exclusivity check every entry
            # point used to duplicate — keep the historical message
            raise ValueError("pass either scenario= or schedule=, not both")
        if self.slots <= 0:
            raise ValueError(f"slots must be positive, got {self.slots}")
        if not 0 <= self.warmup <= self.slots:
            raise ValueError(
                f"need 0 <= warmup <= slots, got warmup={self.warmup} "
                f"slots={self.slots}")
        if self.queue < 2:
            raise ValueError(
                f"queue must be >= 2 (the bubble rule needs 2 free slots "
                f"to admit a packet), got {self.queue}")
        if self.hist_bins < 0:
            raise ValueError(
                f"hist_bins must be >= 0, got {self.hist_bins}")
        if self.vcs < 1:
            raise ValueError(f"vcs must be >= 1, got {self.vcs}")
        if self.credits is not None:
            if self.vcs == 1:
                raise ValueError(
                    "credits= is part of the VC credit-flow router; it "
                    "needs vcs >= 2 (the single-FIFO vcs=1 router has no "
                    "credit counters)")
            if not 2 <= self.credits <= self.queue:
                raise ValueError(
                    f"need 2 <= credits <= queue={self.queue} (a window "
                    f"below 2 starves the injection/turn bubble), got "
                    f"{self.credits}")
        if self.links is not None and not isinstance(self.links, LinkSpec):
            raise TypeError(
                f"links= expects a LinkSpec, got "
                f"{type(self.links).__name__}")
        if self.schedule is not None:
            policy = self.schedule.policy
        elif self.scenario is not None:
            policy = self.scenario.policy
        else:
            policy = "dor"
        validate_feature_combo(
            impl=self.impl, vcs=self.vcs,
            links_trivial=self.links is None or self.links.is_trivial,
            express=bool(self.links is not None and self.links.express),
            policy=policy)

    # -- the legacy-kwarg shim ---------------------------------------------
    @classmethod
    def from_kwargs(cls, config: "SimConfig | None" = None,
                    **kwargs) -> "SimConfig":
        """Resolve `config=` plus legacy per-call kwargs into one
        `SimConfig`.  kwargs valued None mean "not passed" (every legacy
        kwarg is declared with a None default); passing a real value for
        a field while also passing `config` raises — the call is
        ambiguous, and silently preferring either side would hide bugs.
        """
        unknown = set(kwargs) - set(_FIELD_NAMES)
        if unknown:
            raise TypeError(
                f"unknown simulate kwargs: {sorted(unknown)}; SimConfig "
                f"fields are {list(_FIELD_NAMES)}")
        given = {k: v for k, v in kwargs.items() if v is not None}
        if config is None:
            return cls(**given)
        if not isinstance(config, cls):
            raise TypeError(
                f"config= expects a SimConfig, got {type(config).__name__}")
        if given:
            raise ValueError(
                f"both config= and legacy kwarg(s) {sorted(given)} were "
                "passed; put every run parameter on the SimConfig (e.g. "
                "replace(config, ...)) or drop config= and use kwargs")
        return config

    def replace(self, **changes) -> "SimConfig":
        """`dataclasses.replace` convenience (re-validates)."""
        return replace(self, **changes)

    def run_kwargs(self) -> dict:
        """The config as the keyword dict internal planners consume."""
        return {f.name: getattr(self, f.name) for f in fields(self)}
