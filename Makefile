# Entry points — no PYTHONPATH=src incantations needed (pytest picks up
# src/ via pyproject's pythonpath ini + tests/conftest.py; the benchmark
# driver gets it from this Makefile).
#
# CI (.github/workflows/ci.yml) runs: `make test` + `make bench-smoke` on
# the test matrix, `make bench-check` as the perf-regression gate, and
# `make lint` in the lint job.  Policy details: docs/ci.md.
PY ?= python
BENCH_JSON ?= /tmp/bench_current.json
BENCH_NIGHTLY_JSON ?= /tmp/bench_nightly.json
BENCH_TOLERANCE ?= 0.30
# sections whose numbers the regression gate tracks (routing Mrec/s +
# simulator, scenario-engine & transient-timeline slots/s + the latency
# histogram overhead ratio + the VC router's overhead/saturation rows +
# the heterogeneous-link overhead/express-saturation rows + the
# fault-composition VC-under-schedule/faulted-express rows + the
# topology explorer's candidates/s and front-quality rows);
# keep in sync with BENCH_baseline.json
BENCH_GATE_SECTIONS = routing,sim,scenarios,transient,latency,vc,hetero,compose,explore

.PHONY: test test-fast bench bench-quick bench-routing bench-smoke \
        bench-nightly bench-check bench-baseline lint \
        explore explore-smoke

# --durations surfaces the slowest tests so suite-time regressions are
# visible in every CI log
test:
	$(PY) -m pytest -q --durations=15

# analytic + routing + scenario-unit modules (NO simulator sweeps): the
# integer-matrix/lattice/crystal/symmetry stack, both routing backends,
# the fault-BFS table rebuilds and the fault-schedule epoch compiler —
# everything that runs in seconds without compiling a slot-step program.
# The simulator differential/property suites stay in plain `make test`.
test-fast:
	$(PY) -m pytest -q tests/test_intmat.py tests/test_lattice.py \
	    tests/test_crystals.py tests/test_routing.py \
	    tests/test_routing_engine.py tests/test_symmetry.py \
	    tests/test_fault_bfs.py tests/test_fault_schedule.py \
	    tests/test_propcheck.py tests/test_check_regression.py \
	    tests/test_bench_driver.py tests/test_explore.py

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

bench-quick:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick

# routing engine throughput only (ISSUE 1 acceptance numbers)
bench-routing:
	PYTHONPATH=src $(PY) -m benchmarks.run --only routing

# fast sanity pass CI runs on every matrix entry: cheap analytic sections
# + the quick simulator / scenario-engine / transient-timeline / latency
# telemetry benchmarks (covers the fused Pallas row, the K-scenario and
# K-schedule one-compile sweeps, the device fault-BFS sweeps and the
# histogram-overhead rows); exercises the whole bench plumbing
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick \
	    --only table1,table2,throughput,sim,scenarios,transient,latency,vc,hetero,compose,explore

# the nightly CI job: FULL mode, every section (incl. the fused-parity
# differential cells in `sim` and the N=4096 sweeps), JSON for the
# dated bench-trend artifact (docs/ci.md "Nightly bench trend")
bench-nightly:
	PYTHONPATH=src $(PY) -m benchmarks.run --json $(BENCH_NIGHTLY_JSON)

# perf-regression gate: measure the gated sections twice (quick mode,
# JSON; per-metric best-of — a load spike slows one run, a regression
# slows both) and compare against the committed baseline; >30% fails
bench-check:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick \
	    --only $(BENCH_GATE_SECTIONS) --json $(BENCH_JSON)
	PYTHONPATH=src $(PY) -m benchmarks.run --quick \
	    --only $(BENCH_GATE_SECTIONS) --json $(BENCH_JSON).2
	PYTHONPATH=src $(PY) -m benchmarks.check_regression \
	    --baseline BENCH_baseline.json \
	    --current $(BENCH_JSON) $(BENCH_JSON).2 \
	    --tolerance $(BENCH_TOLERANCE)

# refresh the committed baseline (run on the CI machine class, then commit)
bench-baseline:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick \
	    --only $(BENCH_GATE_SECTIONS) --json BENCH_baseline.json

# closed-loop topology exploration (ISSUE 10): seeded evolutionary
# search over HNF lattices + mixed-radix tori, Pareto front over
# throughput x p99 x faulted capacity with RTT/FCC/BCC + torus pinned.
# `explore` is the full acceptance demo; `explore-smoke` is the CI
# budget (<=8 generations, analytic p99, N <= a few hundred cells) and
# FAILS unless a discovered lattice still dominates the torus baseline.
explore:
	PYTHONPATH=src $(PY) -m repro.explore --require-dominance

explore-smoke:
	PYTHONPATH=src $(PY) -m repro.explore --smoke --require-dominance

# ruff config lives in pyproject.toml [tool.ruff]; skips politely when
# ruff isn't installed (offline containers)
lint:
	@command -v ruff >/dev/null 2>&1 \
	    && ruff check src benchmarks tests \
	    || echo "ruff not installed; skipping lint (CI installs it)"
