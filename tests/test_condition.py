"""The unified analytic surface (ISSUE 10 satellite 1 + 2):
`NetworkCondition` validation, the `distance_stats` /
`channel_load_stats` / `saturation` facades, result-identity of the
eleven deprecated `faulted_*`/`weighted_*`/`fault_aware_*` shims, the
`analyze_pod(condition=..., options=...)` collapse, and deprecation
hygiene (every shim warns exactly ONCE per call)."""
import warnings

import numpy as np
import pytest

from repro.core import (FCC, FaultSchedule, LinkSpec, NetworkCondition,
                        Scenario, Torus, channel_load_stats, distance_stats,
                        saturation)
from repro.core import distances as D
from repro.core import throughput as T
from repro.core.simulation import simulate_load_sweep, throughput_curve

G = FCC(2)                       # N=16: big enough to route, fast to walk
SCEN = Scenario(dead_links=((0, 0), (3, 2)))
LS = LinkSpec(dim_weights=(2, 1, 1))
PAIRS, SEED = 2000, 1


def sched():
    return FaultSchedule.random_events(G, 3, 128, seed=4)


def one_warning(fn, *args, **kwargs):
    """Run fn asserting exactly one DeprecationWarning; return result."""
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = fn(*args, **kwargs)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1, (fn.__name__, [str(x.message) for x in w])
    return out


# ---------------------------------------------------------------------------
# NetworkCondition validation (the SimConfig pattern, mirrored)
# ---------------------------------------------------------------------------

def test_condition_defaults_are_pristine():
    c = NetworkCondition()
    assert c.is_pristine and c.router_backend == "auto"


def test_condition_scenario_xor_schedule():
    with pytest.raises(ValueError, match="not both"):
        NetworkCondition(scenario=SCEN, schedule=sched())


@pytest.mark.parametrize("kw", [{"slots": 0}, {"pairs": -1},
                                {"backend": "devcie"},
                                {"scenario": "nope"},
                                {"links": (1, 2, 3)},
                                {"schedule": SCEN}])
def test_condition_rejects_bad_fields(kw):
    with pytest.raises((ValueError, TypeError)):
        NetworkCondition(**kw)


def test_condition_backend_vocabulary():
    assert NetworkCondition(backend="device").router_backend == "jax"
    assert NetworkCondition(backend="host").router_backend == "numpy"


def test_from_kwargs_conflict_and_unknown():
    c = NetworkCondition(scenario=SCEN)
    with pytest.raises(ValueError, match="both condition="):
        NetworkCondition.from_kwargs(c, scenario=SCEN)
    with pytest.raises(TypeError, match="unknown condition kwargs"):
        NetworkCondition.from_kwargs(None, scenari=SCEN)
    assert NetworkCondition.from_kwargs(c) is c
    assert NetworkCondition.from_kwargs(None, pairs=7).pairs == 7


def test_condition_replace_and_as_kwargs_round_trip():
    c = NetworkCondition(scenario=SCEN, pairs=123)
    assert c.replace(pairs=5).pairs == 5
    assert NetworkCondition(**c.as_kwargs()) == c


# ---------------------------------------------------------------------------
# shim-vs-facade result identity: the five distance names
# ---------------------------------------------------------------------------

def test_faulted_average_distance_shim_matches_facade():
    assert one_warning(D.faulted_average_distance, G, SCEN) == \
        distance_stats(G, scenario=SCEN)["average_distance"]


def test_faulted_diameter_shim_matches_facade():
    assert one_warning(D.faulted_diameter, G, SCEN) == \
        distance_stats(G, scenario=SCEN)["diameter"]


def test_faulted_schedule_stats_shim_matches_facade():
    old = one_warning(D.faulted_schedule_stats, G, sched(), 128)
    new = distance_stats(G, schedule=sched(), slots=128)
    assert old.keys() == new.keys()
    for k in old:
        np.testing.assert_array_equal(np.asarray(old[k]),
                                      np.asarray(new[k]))


def test_weighted_average_distance_shim_matches_facade():
    assert one_warning(D.weighted_average_distance, G, LS) == \
        distance_stats(G, links=LS)["average_distance"]


def test_weighted_diameter_shim_matches_facade():
    assert one_warning(D.weighted_diameter, G, LS) == \
        distance_stats(G, links=LS)["diameter"]


# ---------------------------------------------------------------------------
# shim-vs-facade result identity: the six throughput names
# ---------------------------------------------------------------------------

def test_fault_aware_channel_load_shim_matches_facade():
    old = one_warning(T.fault_aware_channel_load, G, SCEN, PAIRS, SEED)
    new = channel_load_stats(G, scenario=SCEN, pairs=PAIRS, seed=SEED)
    np.testing.assert_array_equal(old, new["load"])


def test_fault_aware_schedule_load_shim_matches_facade():
    old = one_warning(T.fault_aware_schedule_load, G, sched(), 128,
                      PAIRS, SEED)
    new = channel_load_stats(G, schedule=sched(), slots=128, pairs=PAIRS,
                             seed=SEED)
    np.testing.assert_array_equal(old, new["load"])


def test_weighted_channel_load_shim_matches_facade():
    old = one_warning(T.weighted_channel_load, G, LS, PAIRS, SEED)
    new = channel_load_stats(G, links=LS, pairs=PAIRS, seed=SEED)
    np.testing.assert_array_equal(old, new["load"])


def test_fault_aware_saturation_shim_matches_facade():
    assert one_warning(T.fault_aware_saturation_throughput, G, SCEN,
                       PAIRS, SEED) == \
        saturation(G, scenario=SCEN, pairs=PAIRS, seed=SEED)


def test_fault_aware_schedule_saturation_shim_matches_facade():
    old = one_warning(T.fault_aware_schedule_saturation, G, sched(), 128,
                      PAIRS, SEED)
    new = saturation(G, schedule=sched(), slots=128, pairs=PAIRS, seed=SEED)
    np.testing.assert_array_equal(old, new)


def test_weighted_saturation_shim_matches_facade():
    assert one_warning(T.weighted_saturation_throughput, G, LS,
                       PAIRS, SEED) == \
        saturation(G, links=LS, pairs=PAIRS, seed=SEED)


# ---------------------------------------------------------------------------
# facade semantics
# ---------------------------------------------------------------------------

def test_pristine_facades_match_graph_properties():
    s = distance_stats(G)
    assert s["average_distance"] == float(G.average_distance)
    assert s["diameter"] == int(G.diameter)
    assert s["reachable_pairs"] == G.order * (G.order - 1)


def test_channel_load_stats_saturation_consistent():
    st = channel_load_stats(G, pairs=PAIRS, seed=SEED)
    assert st["saturation"] == pytest.approx(1.0 / st["max_load"])
    assert st["saturation"] == saturation(G, pairs=PAIRS, seed=SEED)


def test_weighted_times_schedule_distance_cell_runs():
    out = distance_stats(G, schedule=sched(), slots=128, links=LS)
    assert np.asarray(out["average_distance"]).ndim == 1


# ---------------------------------------------------------------------------
# deprecation hygiene: the PRE-existing simulator aliases still warn once
# ---------------------------------------------------------------------------

def test_simulate_load_sweep_and_throughput_curve_warn_once():
    g = Torus(4, 4)
    for fn in (simulate_load_sweep, throughput_curve):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            fn(g, "uniform", [0.2], slots=32, warmup=8)
        dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(dep) == 1, fn


def test_shim_warning_names_the_replacement():
    with pytest.warns(DeprecationWarning,
                      match=r"distance_stats\(g, scenario="):
        D.faulted_average_distance(G, SCEN)
    with pytest.warns(DeprecationWarning, match="Unified analytic"):
        T.weighted_saturation_throughput(G, LS, 500, 0)


# ---------------------------------------------------------------------------
# analyze_pod: condition= / options= collapse (satellite 2)
# ---------------------------------------------------------------------------

def test_analyze_pod_condition_options_equal_legacy_kwargs():
    from repro.topology.collective_model import PodOptions, analyze_pod
    g = Torus(4, 4)
    legacy = analyze_pod("t44", g, (4, 4), scenario=SCEN, routed_pairs=1500)
    new = analyze_pod("t44", g, (4, 4),
                      condition=NetworkCondition(scenario=SCEN, pairs=1500),
                      options=PodOptions(routed_pairs=1500))
    assert legacy == new
    with pytest.raises(ValueError, match="both options="):
        analyze_pod("t44", g, options=PodOptions(), measure_routed=True)
