"""Distance properties of cubic crystal graphs — closed forms of Table 1 and
BFS-based measurement utilities.

Average-distance convention (matches Table 1): k̄ = Σ_v d(0, v) / (N − 1).

Degraded/weighted summaries route through ONE facade,
`distance_stats(g, condition=...)` — a `repro.core.NetworkCondition`
names the fabric state (static scenario, fault timeline, heterogeneous
links) and the facade dispatches to the matching engine.  The historical
per-combination names (`faulted_average_distance`, `weighted_diameter`,
`faulted_schedule_stats`, ...) remain as `DeprecationWarning` shims.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

from .condition import NetworkCondition
from .lattice import LatticeGraph


def _warn_deprecated(old: str, new: str) -> None:
    """One shared DeprecationWarning voice for the analytic shims (see
    docs/simulator.md, 'Unified analytic surface')."""
    warnings.warn(
        f"{old} is deprecated; use {new} (docs/simulator.md, "
        f"'Unified analytic surface')",
        DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# Table 1 closed forms
# ---------------------------------------------------------------------------

def pc_diameter(a: int) -> int:
    return 3 * (a // 2)


def fcc_diameter(a: int) -> int:
    return (3 * a) // 2


def bcc_diameter(a: int) -> int:
    return (3 * a) // 2


def mixed_torus_diameter(*sides: int) -> int:
    return sum(s // 2 for s in sides)


def pc_average_distance(a: int) -> float:
    if a % 2 == 0:
        return 3 * a**4 / (4 * (a**3 - 1))
    return (3 * a**4 - 3 * a**2) / (4 * (a**3 - 1))


def fcc_average_distance(a: int) -> float:
    if a % 2 == 0:
        return (7 * a**4 - 2 * a**2) / (4 * (2 * a**3 - 1))
    return (7 * a**4 - 2 * a**2 - 1) / (4 * (2 * a**3 - 1))


def bcc_average_distance(a: int, as_printed: bool = False) -> float:
    """BCC(a) average distance.

    The paper's odd-a numerator reads `35a⁴ − 14a² + 30`; exhaustive BFS at
    a ∈ {3, 5, 7} shows the constant is a typo for `+3` (measured 8·Σd equals
    35a⁴ − 14a² + 3 exactly).  Pass as_printed=True for the printed form."""
    if a % 2 == 0:
        return (35 * a**4 - 8 * a**2) / (8 * (4 * a**3 - 1))
    c = 30 if as_printed else 3
    return (35 * a**4 - 14 * a**2 + c) / (8 * (4 * a**3 - 1))


def torus_average_distance(*sides: int) -> float:
    """Exact k̄ of a mixed-radix torus: sum of per-dimension ring averages.

    Ring of size s has Σ d = s²/4 (even) or (s²−1)/4 (odd) over all nodes;
    per-dimension averages add because distance is separable."""
    N = int(np.prod(sides))
    total = 0
    for s in sides:
        ring_sum = s * s // 4 if s % 2 == 0 else (s * s - 1) // 4
        total += ring_sum * (N // s)  # each ring value appears N/s times
    return total / (N - 1)


# ---------------------------------------------------------------------------
# measured summaries
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# routed distance profiles (minimal-routing engine instead of BFS)
# ---------------------------------------------------------------------------

def routed_distance_profile(g: LatticeGraph, backend: str = "auto",
                            router=None) -> np.ndarray:
    """hist[k] = #nodes at distance k from any fixed node, computed from the
    norms of minimal routing records (Theorem 29: |r|₁ = d_G(0, v)) instead
    of BFS.  One batched engine call over all N labels — the fast path for
    sweeping large graph families.  Pass a prebuilt `router` (from
    `make_router`) to amortize engine construction across calls."""
    from .routing import make_router, norm1
    if router is None:
        router = make_router(g.matrix, backend)
    return np.bincount(norm1(np.asarray(router(g.labels))))


def routed_diameter(g: LatticeGraph, backend: str = "auto",
                    profile: np.ndarray | None = None) -> int:
    hist = routed_distance_profile(g, backend) if profile is None else profile
    return int(len(hist) - 1)


def routed_average_distance(g: LatticeGraph, backend: str = "auto",
                            profile: np.ndarray | None = None) -> float:
    """k̄ = Σ_v d(0, v) / (N − 1) from routed records (Table 1 convention).
    Pass `profile` (from `routed_distance_profile`) to reuse one all-pairs
    pass for several summary statistics."""
    hist = routed_distance_profile(g, backend) if profile is None else profile
    ks = np.arange(len(hist))
    return float((hist * ks).sum()) / (g.order - 1)


# ---------------------------------------------------------------------------
# degraded-graph (scenario) distance profiles: fault-aware table rebuild
# ---------------------------------------------------------------------------

def faulted_distance_matrix(g: LatticeGraph, scenario,
                            backend: str = "auto") -> np.ndarray:
    """(N, N) live-path distances of the degraded graph (BFS rebuild via
    `routing.fault_aware_next_hop`; −1 = unreachable or dead endpoint).
    Faults break vertex transitivity, so unlike the pristine case a single
    origin profile is not enough — the whole matrix is rebuilt.

    backend: "device" uses the compiled multi-source min-plus BFS
    (`routing.fault_aware_next_hop_device` — same tables, scales past pod
    sizes), "host" the per-destination numpy BFS loop, "auto" the device
    path when JAX is importable."""
    from .routing import fault_aware_next_hop, fault_aware_next_hop_device
    link_ok, node_ok = scenario.link_ok(g), scenario.node_ok(g)
    if backend not in ("auto", "device", "host"):
        raise ValueError(f"unknown BFS backend {backend!r}")
    if backend != "host":
        try:
            return fault_aware_next_hop_device(g, link_ok, node_ok)[0]
        except ImportError:
            if backend == "device":
                raise
    return fault_aware_next_hop(g, link_ok, node_ok)[0]


def faulted_distance_sweep(g: LatticeGraph, scenarios) -> dict:
    """Degraded-distance statistics for K fault patterns as ONE compiled
    device program: the min-plus BFS relaxation runs under `lax.map` over
    the stacked liveness masks (sequential over scenarios, so the (N, N)
    distance front is resident once, not K times) and only the per-
    scenario reductions come back to host.

    Returns {"average_distance": (K,), "diameter": (K,),
    "reachable_pairs": (K,)} over ordered live reachable pairs (the
    `faulted_average_distance` / `faulted_diameter` conventions, with
    one batched-sweep deviation: a lane with ZERO reachable pairs —
    a totally disconnected fault pattern — reports
    average_distance=NaN / diameter=0 / reachable_pairs=0 instead of
    raising like `faulted_average_distance`, so one broken lane cannot
    kill the other K−1; check `reachable_pairs` or NaN before ranking).  This is
    the degraded-topology sweep the host N×BFS loop cannot sustain: at
    N=4096 one host rebuild is minutes of Python, while the whole K-
    scenario sweep here is one device program (`make bench` row
    `scenarios/bfs_sweep*`)."""
    import jax
    import jax.numpy as jnp

    from .routing import _get_fault_bfs            # shared relaxation

    scenarios = list(scenarios)
    N, P = g.order, 2 * g.n
    nbr = g.neighbor_indices.astype(np.int32)
    link = np.stack([s.link_ok(g) for s in scenarios])
    node = np.stack([s.node_ok(g) for s in scenarios])
    eff = link & node[:, :, None] & node[:, nbr]
    relax = _get_fault_bfs(N, P, with_next_hop=False)
    nbr_j = jnp.asarray(nbr)

    def stats(masks):
        eff_ok, link_ok, node_ok = masks
        dist = relax(nbr_j, eff_ok, link_ok, node_ok)
        reach = dist > 0
        pairs = reach.sum()
        d = jnp.where(reach, dist, 0)
        # float32 row-sum accumulation: exact for any realistic diameter
        # (row sums < 2^24), and the final mean is a float anyway
        total = d.sum(axis=0, dtype=jnp.float32).sum(dtype=jnp.float32)
        avg = jnp.where(pairs > 0, total / jnp.maximum(pairs, 1),
                        jnp.float32(jnp.nan))   # disconnected lane → NaN
        return (avg, d.max(), pairs)

    avg, diam, pairs = jax.lax.map(
        stats, (jnp.asarray(eff), jnp.asarray(link), jnp.asarray(node)))
    return {"average_distance": np.asarray(avg, np.float64),
            "diameter": np.asarray(diam, np.int64),
            "reachable_pairs": np.asarray(pairs, np.int64)}


def _faulted_schedule_stats(g: LatticeGraph, schedule, slots: int = 512
                            ) -> dict:
    """Per-EPOCH degraded-distance curves of a transient-fault timeline
    (`repro.core.fault_schedule.FaultSchedule`, or an already-compiled
    `CompiledSchedule`): the schedule's epochs are static scenarios, so
    the whole timeline reuses `faulted_distance_sweep`'s one-compile
    device BFS — K epochs of (N, N) relaxation in one program.

    Returns `faulted_distance_sweep`'s dict plus `epoch_start_slot`
    ((E,) — epoch e covers slots [start[e], start[e+1]))."""
    from .fault_schedule import ensure_compiled
    compiled = ensure_compiled(schedule, g, slots)
    out = faulted_distance_sweep(g, compiled.epochs)
    out["epoch_start_slot"] = np.asarray(compiled.starts, np.int64)
    return out


def faulted_distance_profile(g: LatticeGraph, scenario,
                             dist: np.ndarray | None = None) -> np.ndarray:
    """hist[k] = #ordered live reachable pairs at distance k ≥ 1 in the
    degraded graph (cf. `routed_distance_profile`, which counts from one
    origin of the vertex-transitive pristine graph)."""
    if dist is None:
        dist = faulted_distance_matrix(g, scenario)
    d = dist[dist > 0]
    return np.bincount(d) if d.size else np.zeros(1, dtype=np.int64)


def _faulted_average_distance(g: LatticeGraph, scenario,
                              dist: np.ndarray | None = None) -> float:
    """Mean distance over ordered live reachable pairs of the degraded
    graph — the k̄ entering the Δ/k̄-style saturation intuition once links
    or nodes die."""
    if dist is None:
        dist = faulted_distance_matrix(g, scenario)
    d = dist[dist > 0]
    if d.size == 0:
        raise ValueError("no reachable pairs under this scenario")
    return float(d.mean())


def _faulted_diameter(g: LatticeGraph, scenario,
                      dist: np.ndarray | None = None) -> int:
    """Max live-pair distance of the degraded graph."""
    if dist is None:
        dist = faulted_distance_matrix(g, scenario)
    return int(dist.max())


# -- heterogeneous-link (LinkSpec) metrics ----------------------------------

def weighted_distance_matrix(g: LatticeGraph, link_spec,
                             scenario=None) -> np.ndarray:
    """(N, N) weighted shortest-path COSTS (slots) of a heterogeneous
    fabric: per-dimension/express slot costs and the pillar mask of a
    `core.link_spec.LinkSpec`, optionally composed with a fault
    `Scenario`.  Runs the per-port-cost min-plus relaxation of
    `routing.fault_aware_next_hop_device` over the extended (base +
    express) port axis; −1 marks unreachable pairs (possible once
    pillars or faults cut the graph).  A trivial spec reproduces
    `faulted_distance_matrix` / the hop-count matrix exactly."""
    from .routing import fault_aware_next_hop_device
    if scenario is not None:
        link_ok, node_ok = scenario.link_ok(g), scenario.node_ok(g)
    else:
        link_ok = np.ones((g.order, 2 * g.n), dtype=bool)
        node_ok = None
    return fault_aware_next_hop_device(
        g, link_ok, node_ok, link_spec=link_spec)[0]


def _weighted_average_distance(g: LatticeGraph, link_spec,
                               dist: np.ndarray | None = None) -> float:
    """Mean weighted cost over ordered reachable pairs — the k̄ entering
    the Δ/k̄ saturation intuition once slot costs are non-uniform."""
    if dist is None:
        dist = weighted_distance_matrix(g, link_spec)
    d = dist[dist > 0]
    if d.size == 0:
        raise ValueError("no reachable pairs under this LinkSpec")
    return float(d.mean())


def _weighted_diameter(g: LatticeGraph, link_spec,
                       dist: np.ndarray | None = None) -> int:
    """Max weighted pair cost (slots) of the heterogeneous fabric."""
    if dist is None:
        dist = weighted_distance_matrix(g, link_spec)
    return int(dist.max())


# ---------------------------------------------------------------------------
# unified analytic surface: distance_stats facade + deprecation shims
# ---------------------------------------------------------------------------

def _matrix_stats(dist: np.ndarray) -> dict:
    """Reduce one (N, N) distance/cost matrix (−1 = unreachable) to the
    facade's summary dict, keeping the shim conventions exactly."""
    d = dist[dist > 0]
    if d.size == 0:
        raise ValueError("no reachable pairs under this condition")
    return {"average_distance": float(d.mean()),
            "diameter": int(dist.max()),
            "reachable_pairs": int(d.size)}


def distance_stats(g: LatticeGraph,
                   condition: NetworkCondition | None = None,
                   **kwargs) -> dict:
    """Distance summary of `g` under one `repro.core.NetworkCondition` —
    THE entry point for degraded/weighted distance metrics (the shimmed
    `faulted_*`/`weighted_*` names all dispatch through here).

    Returns {"average_distance", "diameter", "reachable_pairs"}:

      * pristine condition — the closed BFS values (`g.average_distance`,
        `g.diameter`) over all N·(N−1) ordered pairs;
      * static `scenario` — live-pair statistics of the degraded graph
        (fault-aware BFS rebuild, `condition.backend` selects the
        engine);
      * `links` (LinkSpec) — weighted shortest-path costs over the
        extended port axis, composable with a static `scenario`;
      * `schedule` (FaultSchedule) — per-EPOCH arrays plus
        `epoch_start_slot` ((E,) — epoch e covers slots
        [start[e], start[e+1])); lanes left totally disconnected report
        average_distance=NaN / diameter=0 / reachable_pairs=0 (the
        `faulted_distance_sweep` convention) instead of raising.

    Condition fields may also be passed as kwargs (`scenario=...`,
    `links=...`); passing both a `condition` and kwargs raises."""
    cond = NetworkCondition.from_kwargs(condition, **kwargs)
    links = cond.links if cond.links is not None else None
    if cond.schedule is not None:
        if links is not None and not links.is_trivial:
            # weighted × timeline: per-epoch min-plus relaxations (the
            # sweep engine is hop-count only, so this walks epochs on
            # host — E is small by construction)
            from .fault_schedule import ensure_compiled
            compiled = ensure_compiled(cond.schedule, g, cond.slots, links)
            avg, diam, pairs = [], [], []
            for scen in compiled.epochs:
                dist = weighted_distance_matrix(g, links, scenario=scen)
                d = dist[dist > 0]
                avg.append(float(d.mean()) if d.size else float("nan"))
                diam.append(int(dist.max()) if d.size else 0)
                pairs.append(int(d.size))
            return {"average_distance": np.asarray(avg, np.float64),
                    "diameter": np.asarray(diam, np.int64),
                    "reachable_pairs": np.asarray(pairs, np.int64),
                    "epoch_start_slot": np.asarray(compiled.starts,
                                                   np.int64)}
        return _faulted_schedule_stats(g, cond.schedule, cond.slots)
    if links is not None:
        return _matrix_stats(
            weighted_distance_matrix(g, links, scenario=cond.scenario))
    if cond.scenario is not None:
        return _matrix_stats(
            faulted_distance_matrix(g, cond.scenario, cond.backend))
    return {"average_distance": float(g.average_distance),
            "diameter": int(g.diameter),
            "reachable_pairs": g.order * (g.order - 1)}


def faulted_average_distance(g: LatticeGraph, scenario,
                             dist: np.ndarray | None = None) -> float:
    """Deprecated shim — `distance_stats(g, scenario=...)`."""
    _warn_deprecated(
        "faulted_average_distance",
        "distance_stats(g, scenario=...)['average_distance']")
    return _faulted_average_distance(g, scenario, dist)


def faulted_diameter(g: LatticeGraph, scenario,
                     dist: np.ndarray | None = None) -> int:
    """Deprecated shim — `distance_stats(g, scenario=...)`."""
    _warn_deprecated("faulted_diameter",
                     "distance_stats(g, scenario=...)['diameter']")
    return _faulted_diameter(g, scenario, dist)


def faulted_schedule_stats(g: LatticeGraph, schedule, slots: int = 512
                           ) -> dict:
    """Deprecated shim — `distance_stats(g, schedule=...)`."""
    _warn_deprecated("faulted_schedule_stats",
                     "distance_stats(g, schedule=..., slots=...)")
    return _faulted_schedule_stats(g, schedule, slots)


def weighted_average_distance(g: LatticeGraph, link_spec,
                              dist: np.ndarray | None = None) -> float:
    """Deprecated shim — `distance_stats(g, links=...)`."""
    _warn_deprecated(
        "weighted_average_distance",
        "distance_stats(g, links=...)['average_distance']")
    return _weighted_average_distance(g, link_spec, dist)


def weighted_diameter(g: LatticeGraph, link_spec,
                      dist: np.ndarray | None = None) -> int:
    """Deprecated shim — `distance_stats(g, links=...)`."""
    _warn_deprecated("weighted_diameter",
                     "distance_stats(g, links=...)['diameter']")
    return _weighted_diameter(g, link_spec, dist)


@dataclass(frozen=True)
class DistanceSummary:
    name: str
    n: int
    order: int
    degree: int
    diameter: int
    average_distance: float

    def row(self) -> str:
        return (f"{self.name:<24} n={self.n} N={self.order:<8} Δ={self.degree} "
                f"D={self.diameter:<4} k̄={self.average_distance:.5f}")


def summarize(name: str, g: LatticeGraph) -> DistanceSummary:
    return DistanceSummary(
        name=name, n=g.n, order=g.order, degree=g.degree,
        diameter=g.diameter, average_distance=g.average_distance)
