"""Sharded, async, elastic checkpointing.

Layout: one directory per step containing
  * manifest.json — pytree structure, shapes/dtypes, mesh fingerprint, step
  * shard-<host>.npz — each host's slice of every array (here: single-host
    saves the full arrays; the reshard path is exercised via slicing maths
    that is mesh-independent, so restore works onto ANY new mesh/pod size —
    the elastic path of topology.upgrade).

Fault-tolerance contract: writes go to a temp dir + atomic rename, so a
crash mid-save never corrupts the latest checkpoint; `latest_step` skips
incomplete directories.  Saving is async (background thread) with a bounded
queue so training never blocks longer than one outstanding checkpoint.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str | Path, step: int, tree, extra: dict | None = None):
    """Synchronous atomic save of a pytree."""
    path = Path(path)
    final = path / f"step_{step:08d}"
    tmp = path / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    arrays = {}
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        if a.dtype.name == "bfloat16":      # npz has no bf16: store raw bits
            a = a.view(np.uint16)
        arrays[f"leaf_{i}"] = a
    np.savez(tmp / "shard-0.npz", **arrays)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "time": time.time(),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(path: str | Path) -> int | None:
    path = Path(path)
    if not path.exists():
        return None
    steps = []
    for d in path.iterdir():
        if d.name.startswith("step_") and (d / "manifest.json").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(path: str | Path, step: int, like_tree):
    """Restore into the structure of `like_tree` (shapes must match;
    dtype-casts allowed).  Device placement/sharding is the caller's job
    (e.g. jax.device_put with the new mesh's NamedShardings — this is what
    makes restore elastic across pod upgrades)."""
    path = Path(path) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "shard-0.npz")
    leaves, treedef = _flatten(like_tree)
    assert manifest["num_leaves"] == len(leaves), "structure mismatch"
    out = []
    for i, like in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        want = np.asarray(like).dtype
        saved = manifest["dtypes"][i]
        if saved == "bfloat16":             # stored as raw uint16 bits
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        assert tuple(arr.shape) == tuple(np.shape(like)), \
            f"leaf {i}: {arr.shape} vs {np.shape(like)}"
        out.append(arr.astype(want))
    return jax.tree.unflatten(treedef, out)


def reshard_for_mesh(tree, mesh, spec_tree):
    """Place a host-resident pytree onto a (new) mesh with the given
    PartitionSpecs — the elastic restore path after a pod upgrade."""
    from jax.sharding import NamedSharding, PartitionSpec

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


class AsyncCheckpointer:
    """Background-thread checkpoint writer with a bounded queue (depth 1:
    at most one checkpoint in flight; the next save waits, which bounds
    both host memory and the blocking time of the train loop)."""

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._errors: list[Exception] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, extra = item
            try:
                save_checkpoint(self.path, step, tree, extra)
            except Exception as e:  # pragma: no cover
                self._errors.append(e)
            finally:
                self._q.task_done()

    def save(self, step: int, tree, extra: dict | None = None):
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot off-device
        self._q.put((step, host_tree, extra))

    def wait(self):
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join()
