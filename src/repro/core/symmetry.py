"""Symmetry analysis of lattice graphs (paper §3 + Appendix A).

A lattice graph G(M) is *linearly symmetric* (Definition 37) when for every i
there is a linear automorphism φ fixing 0 with φ(e_1) = ±e_i.  By Lemma 35
linear automorphisms fixing 0 are signed permutation matrices P, and by
Lemma 36 P is an automorphism iff M⁻¹PM is integral.
"""
from __future__ import annotations

from itertools import permutations, product

import numpy as np

from . import intmat


def signed_permutation_matrices(n: int):
    """All n!·2^n signed permutation matrices (Definition 34)."""
    eye = np.eye(n, dtype=np.int64)
    for perm in permutations(range(n)):
        base = eye[list(perm)].T  # column j holds e_{perm[j]}
        for signs in product((1, -1), repeat=n):
            yield base * np.array(signs, dtype=np.int64)[None, :]


def is_linear_automorphism(P, M) -> bool:
    """Lemma 36: φ(x)=Px is an automorphism of G(M) iff M⁻¹PM ∈ Z^{n×n}."""
    M = intmat.as_np(M)
    P = intmat.as_np(P)
    d = intmat.det(M)
    adj = intmat.adjugate(M)
    prod_ = adj.astype(object) @ P.astype(object) @ M.astype(object)
    return bool(np.all(np.vectorize(lambda x: x % d == 0)(prod_)))


def linear_stabilizer(M) -> list[np.ndarray]:
    """All signed-permutation automorphisms of G(M) (= LAut(G(M), 0) by
    Lemma 35)."""
    M = intmat.as_np(M)
    n = M.shape[0]
    return [P for P in signed_permutation_matrices(n)
            if is_linear_automorphism(P, M)]


def is_linearly_symmetric(M) -> bool:
    """Definition 37: ∀i ∃φ ∈ LAut(G(M),0) with φ(e_1) = ±e_i.

    Checked over the group *generated* by the signed-permutation
    automorphisms; since signed permutations form a finite group closed under
    composition and every automorphism here is a signed permutation, checking
    the stabilizer set directly is exhaustive."""
    M = intmat.as_np(M)
    n = M.shape[0]
    hit = [False] * n
    for P in linear_stabilizer(M):
        img = P[:, 0]  # φ(e_1)
        nz = np.nonzero(img)[0]
        if len(nz) == 1 and abs(img[nz[0]]) == 1:
            hit[int(nz[0])] = True
    return all(hit)


def theorem12_matrix_first_family(a: int, b: int, c: int) -> np.ndarray:
    """M1 = circulant [[a,c,b],[b,a,c],[c,b,a]] — always symmetric (Thm 12)."""
    return np.array([[a, c, b], [b, a, c], [c, b, a]], dtype=np.int64)


def theorem12_matrix_second_family(a: int, b: int, c: int) -> np.ndarray:
    """M'1 = [[a,b,c],[a,c,−b−c],[a,−b−c,b]] — always symmetric (Thm 47)."""
    return np.array([[a, b, c], [a, c, -b - c], [a, -b - c, b]], dtype=np.int64)


def bcc_lift_is_never_symmetric(a: int) -> bool:
    """Computational check of Theorem 20 for a given a: no Hermite-form lift
      L = [[2a,0,a,x],[0,2a,a,y],[0,0,a,z],[0,0,0,1]]
    (t=1 wlog per the proof) is linearly symmetric."""
    for x in range(2 * a):
        for y in range(2 * a):
            for z in range(a):
                L = np.array(
                    [[2 * a, 0, a, x],
                     [0, 2 * a, a, y],
                     [0, 0, a, z],
                     [0, 0, 0, 1]], dtype=np.int64)
                if is_linearly_symmetric(L):
                    return False
    return True
