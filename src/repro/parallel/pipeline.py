"""Pipeline parallelism: GPipe-style microbatch schedule over a "pipe" mesh
axis, built from shard_map + lax.ppermute.

Layer-stacked params (L, ...) are sharded over the pipe axis (L/P layers per
stage).  Each tick every stage applies its layers to the activation it
holds and ppermutes the result downstream; microbatch m enters at tick m and
leaves after P−1+m ticks (the usual (P−1)/M bubble).  Differentiable (the
transpose of ppermute is the reverse ppermute), so one jax.grad gives true
pipeline-parallel training.

This is the PP building block exercised in tests on small meshes; the fixed
production meshes of the dry-run use DP×FSDP×TP/EP (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ._compat import shard_map


def pipeline_apply(layer_fn, stacked_params, x, mesh, *,
                   num_microbatches: int, axis: str = "pipe"):
    """Run `layer_fn(params_slice, x) -> x` over L stacked layers, pipelined.

    stacked_params: pytree with leading dim L (L % pipe_size == 0)
    x: (B, ...) with B % num_microbatches == 0
    Returns: (B, ...) outputs (replicated over the pipe axis)."""
    nstages = mesh.shape[axis]
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    assert L % nstages == 0, (L, nstages)
    M = num_microbatches
    B = x.shape[0]
    assert B % M == 0, (B, M)
    mb = B // M
    xs = x.reshape(M, mb, *x.shape[1:])

    def stage(params_local, xs_full):
        rank = jax.lax.axis_index(axis)
        ticks = M + nstages - 1

        def apply_stage(h):
            def body(c, p):
                return layer_fn(p, c), None
            out, _ = jax.lax.scan(body, h, params_local)
            return out

        def tick(carry, t):
            buf = carry                       # activation entering my stage
            feed = xs_full[jnp.clip(t, 0, M - 1)]
            h = jnp.where(rank == 0, feed, buf)
            act = apply_stage(h)
            # pass downstream (stage s -> s+1); last stage's output wraps to
            # 0 but is masked out by the collection logic
            nxt = jax.lax.ppermute(
                act, axis, [(i, (i + 1) % nstages) for i in range(nstages)])
            # collect: on the last stage, tick t emits microbatch t-(P-1)
            emit = act * jnp.where(rank == nstages - 1, 1.0, 0.0).astype(act.dtype)
            return nxt, emit

        _, emitted = jax.lax.scan(tick, jnp.zeros_like(xs_full[0]),
                                  jnp.arange(ticks))
        # emitted[t] valid for t in [P-1, P-1+M) → reorder to microbatch order
        out = jax.lax.dynamic_slice_in_dim(emitted, nstages - 1, M, axis=0)
        # only the last stage emitted nonzero → psum broadcasts it to all
        return jax.lax.psum(out, axis)

    pspec = jax.tree.map(lambda _: P(axis), stacked_params)
    y = shard_map(
        stage, mesh=mesh,
        in_specs=(pspec, P()), out_specs=P(),
        check_vma=False,
    )(stacked_params, xs)
    return y.reshape(B, *x.shape[1:])
