"""Blocked flash attention for TPU (pl.pallas_call + BlockSpec VMEM tiling).

Canonical TPU formulation: 3D grid (batch·heads, q_blocks, k_blocks); the
innermost grid dimension iterates sequentially on a core, so the online
softmax state (m, l, acc) lives in VMEM scratch and persists across k-blocks.
Block shapes are MXU-aligned (q/k blocks multiples of 128 in production; the
defaults here divide the assigned shapes).  Causal masking skips fully-masked
blocks and applies a triangular mask on the diagonal block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ._compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                  causal: bool, block_q: int, block_k: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    def _compute():
        q = q_ref[...].astype(jnp.float32)                 # (bq, hd)
        k = k_ref[...].astype(jnp.float32)                 # (bk, hd)
        s = jax.lax.dot_general(                           # (bq, bk)
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_s[...]                                  # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)                 # (bk, hd)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc[...] = acc[...] * alpha + pv
        m_s[...] = m_new

    if causal:
        # skip blocks strictly above the diagonal
        @pl.when(ki * block_k <= qi * block_q + (block_q - 1))
        def _():
            _compute()
    else:
        _compute()

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = (acc[...] / l_s[...]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q, k, v: (BH, S, hd) → (BH, S, hd).  GQA is folded by the ops wrapper."""
    BH, S, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    grid = (BH, S // block_q, S // block_k)
    kernel = functools.partial(
        _flash_kernel, causal=causal, block_q=block_q, block_k=block_k,
        scale=1.0 / (hd ** 0.5))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
