"""Fault-composition matrix cost + graceful express degradation (ISSUE 9).

Two committed records of the composed fault machinery:

  * `compose/vc_sched` — the SAME vcs=2 cell run against a static
    `Scenario` and against a 3-epoch `FaultSchedule` flap, interleaved
    best-of-`REPS`.  `vc_sched_slots_per_s` gates the absolute scheduled
    VC step throughput; `overhead_ratio` (static_time / scheduled_time)
    is the committed price of the per-epoch mask gathers + per-slot
    timeline emission on top of the static VC program — expected near 1
    (four gathers and a dead-queue reconciliation per slot).

  * `compose/express_fault` — routed saturation
    (`channel_load_stats` Monte-Carlo, deterministic given the seed)
    of the T(8,4) express overlay pristine, with half of its
    express channels dead, and the bare base fabric.  All three carry
    the `_sat_phits` gate suffix: the gate pins GRACEFUL degradation —
    the faulted overlay must keep beating the bare fabric instead of
    raising the pre-ISSUE-9 pristine-fabric error — not a timing.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (FaultSchedule, LinkSpec, Scenario, SimConfig,
                        Torus, channel_load_stats)
from repro.core.simulation import build_tables, simulate

from .util import emit

REPS = 3


def main(quick: bool = False) -> None:
    # ---- vcs=2 under a FaultSchedule vs the static-scenario VC step ----
    g = Torus(8, 4) if quick else Torus(8, 8)
    slots, warmup = (96, 24) if quick else (192, 48)
    t = build_tables(g)
    cfg = SimConfig(slots=slots, warmup=warmup, seed=1, tables=t, vcs=2)
    scen = Scenario(dead_links=((0, 0),), policy="adaptive")
    flap = FaultSchedule.link_flap((0, 0), slots // 4, (3 * slots) // 4,
                                   base=Scenario(policy="adaptive"))
    cfgs = {
        "static": cfg.replace(scenario=scen),
        "scheduled": cfg.replace(schedule=flap),
    }

    def run(which):
        return simulate(g, "uniform", 0.5, config=cfgs[which])

    for which in cfgs:                             # compile both first
        run(which)
    best = {which: float("inf") for which in cfgs}
    for _ in range(REPS):
        for which in cfgs:
            t0 = time.perf_counter()
            run(which)
            best[which] = min(best[which], time.perf_counter() - t0)
    emit(f"compose/vc_sched/N={g.order}", best["scheduled"] * 1e6,
         f"vc_sched_slots_per_s={slots / best['scheduled']:.1f};"
         f"overhead_ratio={best['static'] / best['scheduled']:.3f};"
         f"vcs=2;E=3")

    # ---- faulted express overlay: graceful degradation, not an error ----
    pairs = 5_000 if quick else 20_000
    mixed = Torus(8, 4)
    ls = LinkSpec(express=((0, 2, 1),))
    w = ls.port_weights(mixed.n).astype(np.float64)

    def sat(scenario=None):
        load = channel_load_stats(mixed, links=ls, scenario=scenario,
                                  pairs=pairs, seed=1)["load"]
        return float(1.0 / (load * w[None, :]).max())

    # every 2nd node's +express port: enough kills to move the routed
    # bottleneck (sparser kills leave the max-loaded channel untouched
    # and the row would pin nothing)
    dead = Scenario(dead_links=tuple(
        (u, 2 * mixed.n) for u in range(0, mixed.order, 2)))
    pristine, faulted = sat(), sat(dead)
    base_load = channel_load_stats(mixed, links=LinkSpec(dim_weights=(1, 1)),
                                   pairs=pairs, seed=1)["load"]
    base = float(1.0 / base_load.max())
    emit(f"compose/express_fault/N={mixed.order}", 0.0,
         f"express_sat_phits={pristine:.4f};"
         f"faulted_sat_phits={faulted:.4f};"
         f"exbase_sat_phits={base:.4f};"
         f"retained={faulted / pristine:.2f}")


if __name__ == "__main__":
    main()
