"""Core library: lattice graphs from cubic crystal lattices (the paper's
contribution), exact integer-matrix machinery, symmetry, routing, distance
analysis and throughput bounds."""
from . import intmat
from .condition import NetworkCondition
from .crystals import (BCC, FCC, PC, RTT, FourD_BCC, FourD_FCC, Lip, Torus,
                       bcc_matrix, boxplus, crystal_for_order, direct_sum,
                       fcc_matrix, fourd_bcc_matrix, fourd_fcc_matrix,
                       lip_matrix, nd_bcc_matrix, nd_fcc_matrix, nd_pc_matrix,
                       pc_matrix, rtt_matrix, torus_matrix, upgrade_path)
from .distances import (DistanceSummary, bcc_average_distance, bcc_diameter,
                        distance_stats, faulted_average_distance,
                        faulted_diameter, faulted_distance_matrix,
                        faulted_distance_profile, faulted_distance_sweep,
                        faulted_schedule_stats, fcc_average_distance,
                        fcc_diameter, mixed_torus_diameter,
                        pc_average_distance, pc_diameter, summarize,
                        torus_average_distance, weighted_average_distance,
                        weighted_diameter, weighted_distance_matrix)
from .fault_schedule import CompiledSchedule, FaultSchedule
from .lattice import LatticeGraph
from .link_spec import LinkSpec
from .routing import (HierarchicalRouter, fault_aware_next_hop,
                      fault_aware_next_hop_device, make_router,
                      minimal_record_bruteforce, norm1, route_bcc, route_fcc,
                      route_ring, route_rtt, route_torus)
from .scenario import Scenario, scenario_connected
from .sim_config import SimConfig
try:
    from .routing_engine import RoutingEngine, credit_vc_select
except ImportError:           # jax absent — the numpy oracle stands alone
    RoutingEngine = None      # type: ignore[assignment,misc]
    credit_vc_select = None   # type: ignore[assignment]
from .symmetry import (bcc_lift_is_never_symmetric, is_linear_automorphism,
                       is_linearly_symmetric, linear_stabilizer,
                       signed_permutation_matrices,
                       theorem12_matrix_first_family,
                       theorem12_matrix_second_family)
from .throughput import (bcc_throughput_bound, channel_load,
                         channel_load_device, channel_load_stats,
                         channel_load_uniform, fault_aware_channel_load,
                         fault_aware_saturation_throughput,
                         fault_aware_schedule_load,
                         fault_aware_schedule_saturation,
                         fcc_throughput_bound, measured_saturation_throughput,
                         mixed_torus_throughput_bound, pc_throughput_bound,
                         saturation, symmetric_throughput_bound,
                         weighted_channel_load,
                         weighted_saturation_throughput)

__all__ = [
    "intmat", "LatticeGraph",
    "PC", "FCC", "BCC", "RTT", "Torus", "FourD_FCC", "FourD_BCC", "Lip",
    "pc_matrix", "fcc_matrix", "bcc_matrix", "rtt_matrix", "torus_matrix",
    "fourd_fcc_matrix", "fourd_bcc_matrix", "lip_matrix",
    "nd_pc_matrix", "nd_bcc_matrix", "nd_fcc_matrix",
    "boxplus", "direct_sum", "crystal_for_order", "upgrade_path",
    "route_ring", "route_torus", "route_rtt", "route_fcc", "route_bcc",
    "HierarchicalRouter", "RoutingEngine", "make_router",
    "minimal_record_bruteforce", "norm1",
    "pc_diameter", "fcc_diameter", "bcc_diameter", "mixed_torus_diameter",
    "pc_average_distance", "fcc_average_distance", "bcc_average_distance",
    "torus_average_distance", "summarize", "DistanceSummary",
    "signed_permutation_matrices", "is_linear_automorphism",
    "linear_stabilizer", "is_linearly_symmetric",
    "theorem12_matrix_first_family", "theorem12_matrix_second_family",
    "bcc_lift_is_never_symmetric",
    "symmetric_throughput_bound", "mixed_torus_throughput_bound",
    "pc_throughput_bound", "fcc_throughput_bound", "bcc_throughput_bound",
    "channel_load", "channel_load_device", "channel_load_uniform",
    "measured_saturation_throughput",
    "Scenario", "scenario_connected", "fault_aware_next_hop",
    "fault_aware_next_hop_device",
    "fault_aware_channel_load", "fault_aware_saturation_throughput",
    "faulted_distance_matrix", "faulted_distance_profile",
    "faulted_distance_sweep",
    "faulted_average_distance", "faulted_diameter",
    "FaultSchedule", "CompiledSchedule", "faulted_schedule_stats",
    "fault_aware_schedule_load", "fault_aware_schedule_saturation",
    "SimConfig", "credit_vc_select", "LinkSpec",
    "NetworkCondition", "distance_stats", "channel_load_stats", "saturation",
    "weighted_distance_matrix", "weighted_average_distance",
    "weighted_diameter", "weighted_channel_load",
    "weighted_saturation_throughput",
]
