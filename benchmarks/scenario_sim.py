"""Scenario-engine throughput: faulted/adaptive simulation vs the
fault-free batched baseline, the multi-seed sweep cost, the K-scenario
one-compile sweep vs sequential per-pattern compiles, and the device
fault-BFS distance sweep vs the host N×BFS loop.

The acceptance bars: at N=4096 a faulted adaptive-routing run must stay
within 2× of the fault-free batched path (ISSUE 3 — faults and policies
enter the compiled slot update as masks/tables only); a K=8-pattern
`simulate_scenario_sweep` must beat K sequential `simulate` calls that
each pay the pre-traced-mask per-pattern compile by ≥3× (ISSUE 4); and
the device BFS must sustain a multi-scenario distance sweep the host
loop cannot (ISSUE 4: 64 scenarios at N=4096 in full mode).  Quick mode
shrinks the sim rows to N=512 and the BFS sweep to K=4 (the K=8
scenario sweep is pinned at N=512 in both modes — see inline comment);
emitted `slots_per_s` / `loadpoints_per_s` / `scenarios_per_s` metrics
are gated by `make bench-check`.
"""
from __future__ import annotations

import time

from repro.core import (Scenario, SimConfig, Torus, fault_aware_next_hop,
                        faulted_distance_sweep)
from repro.core.simulation import (_RUNNER_CACHE, build_tables, simulate,
                                   simulate_scenario_sweep, simulate_sweep)

from .util import emit

REPS = 3


def main(quick: bool = False) -> None:
    g = Torus(8, 8, 4, 2) if quick else Torus(8, 8, 8, 8)
    slots = 192 if quick else 512
    warmup = 48 if quick else 128
    t = build_tables(g)
    scen = Scenario.random_link_faults(g, 8, seed=5, policy="adaptive")
    cfg = SimConfig(slots=slots, warmup=warmup, seed=1, tables=t)

    def run(scenario):
        return simulate(g, "uniform", 0.6,
                        config=cfg.replace(scenario=scenario))

    # compile both, then alternate (fair under machine noise)
    run(None)
    run(scen)
    best = {"fault_free": float("inf"), "faulted_adaptive": float("inf")}
    for _ in range(REPS):
        for name, s in (("fault_free", None), ("faulted_adaptive", scen)):
            t0 = time.perf_counter()
            run(s)
            best[name] = min(best[name], time.perf_counter() - t0)
    for name in best:
        emit(f"scenarios/{name}/N={g.order}", best[name] * 1e6,
             f"slots_per_s={slots / best[name]:.1f};slots={slots}")
    emit(f"scenarios/overhead/N={g.order}", 0.0,
         f"overhead={best['faulted_adaptive'] / best['fault_free']:.2f}x")

    # multi-seed sweep: (loads × seeds) error-bar program, cost per run
    loads, seeds = (0.3, 0.6, 1.0), 2
    kw = dict(config=cfg.replace(scenario=scen), seeds=seeds)
    simulate_sweep(g, "uniform", loads, **kw)          # compile
    best_sweep = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        simulate_sweep(g, "uniform", loads, **kw)
        best_sweep = min(best_sweep, time.perf_counter() - t0)
    runs = len(loads) * seeds
    emit(f"scenarios/sweep{len(loads)}x{seeds}/N={g.order}",
         best_sweep * 1e6,
         f"scenario_loadpoints_per_s={runs / best_sweep:.2f};"
         f"per_run_s={best_sweep / runs:.2f}")

    # ---- K-scenario sweep: one trace/compile for K fault patterns ----
    # the comparison point is what evaluating K fresh patterns used to
    # cost before the masks became traced inputs (PR 3 baked them into
    # the program, so every pattern recompiled + re-ran the host BFS):
    # K sequential simulate() calls, each from a cold runner cache.  The
    # sweep side is timed cold too — its single compile is the claim.
    # The row is pinned at N=512 in BOTH modes: the win being measured
    # is compile amortization (identical at any N — on XLA CPU the
    # vmapped lanes serialize, so at N=4096 run time would drown it);
    # same-N rows also keep the committed gate number mode-independent.
    K = 8
    gk = Torus(8, 8, 4, 2)
    tk = build_tables(gk)   # cheap at N=512; never alias another graph's t
    kscens = [Scenario.random_link_faults(gk, 6, seed=100 + i,
                                          policy="adaptive")
              for i in range(K)]
    kcfg = SimConfig(slots=192, warmup=48, seed=1, tables=tk)
    skw = dict(config=kcfg)
    _RUNNER_CACHE.clear()
    t0 = time.perf_counter()
    simulate_scenario_sweep(gk, "uniform", kscens, loads=(0.6,), **skw)
    sweep_cold = time.perf_counter() - t0
    best_ksweep = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        simulate_scenario_sweep(gk, "uniform", kscens, loads=(0.6,), **skw)
        best_ksweep = min(best_ksweep, time.perf_counter() - t0)
    t0 = time.perf_counter()
    for s in kscens:
        _RUNNER_CACHE.clear()            # pre-traced-mask behavior
        simulate(gk, "uniform", 0.6, config=kcfg.replace(scenario=s))
    seq_cold = time.perf_counter() - t0
    emit(f"scenarios/scen_sweep{K}/N={gk.order}", best_ksweep * 1e6,
         f"scen_sweep_loadpoints_per_s={K / best_ksweep:.2f};"
         f"one_compile_s={sweep_cold:.2f};seq_cold_s={seq_cold:.2f};"
         f"speedup_vs_seq_cold={seq_cold / sweep_cold:.1f}x")

    # ---- device fault-BFS distance sweep vs the host N×BFS loop ----
    # full mode: the ISSUE 4 acceptance row — 64 fault patterns at N=4096
    # through the compiled min-plus relaxation; the host Python loop is
    # timed on ONE pattern and extrapolated (running it 64× would take
    # ~10 minutes on this class of box — the point of the row).
    Kb = 4 if quick else 64
    bscens = [Scenario.random_link_faults(g, 8, seed=200 + i)
              for i in range(Kb)]
    t0 = time.perf_counter()
    faulted_distance_sweep(g, bscens)
    bfs_cold = time.perf_counter() - t0
    # warm timing best-of-reps like every other gated metric (one rep in
    # full mode — the 64×N=4096 sweep is ~90 s a pass)
    bfs_warm = float("inf")
    for _ in range(REPS if quick else 1):
        t0 = time.perf_counter()
        faulted_distance_sweep(g, bscens)
        bfs_warm = min(bfs_warm, time.perf_counter() - t0)
    t0 = time.perf_counter()
    fault_aware_next_hop(g, bscens[0].link_ok(g), bscens[0].node_ok(g))
    host_one = time.perf_counter() - t0
    emit(f"scenarios/bfs_sweep{Kb}/N={g.order}", bfs_warm * 1e6,
         f"bfs_scenarios_per_s={Kb / bfs_warm:.2f};"
         f"device_s={bfs_warm:.2f};"
         f"compile_s={max(bfs_cold - bfs_warm, 0.0):.2f};"
         f"host_est_s={host_one * Kb:.1f};"
         f"device_vs_host={host_one * Kb / bfs_warm:.1f}x")


if __name__ == "__main__":
    main()
