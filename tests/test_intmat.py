"""Exact integer-matrix machinery tests (hypothesis property tests)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import intmat


def nonsingular_matrices(n: int, lo: int = -6, hi: int = 6):
    return (
        st.lists(st.lists(st.integers(lo, hi), min_size=n, max_size=n),
                 min_size=n, max_size=n)
        .map(lambda rows: np.array(rows, dtype=np.int64))
        .filter(lambda M: intmat.det(M) != 0)
    )


@given(nonsingular_matrices(3))
@settings(max_examples=60, deadline=None)
def test_det_matches_numpy(M):
    assert intmat.det(M) == round(float(np.linalg.det(M.astype(np.float64))))


@given(nonsingular_matrices(3))
@settings(max_examples=60, deadline=None)
def test_adjugate_identity(M):
    adj = intmat.adjugate(M)
    d = intmat.det(M)
    assert np.array_equal(M @ adj, d * np.eye(3, dtype=np.int64))
    assert np.array_equal(adj @ M, d * np.eye(3, dtype=np.int64))


@given(nonsingular_matrices(3))
@settings(max_examples=60, deadline=None)
def test_hnf_properties(M):
    H = intmat.hermite_normal_form(M)
    n = 3
    # upper triangular, positive diagonal
    for i in range(n):
        assert H[i, i] > 0
        for j in range(i):
            assert H[i, j] == 0
        for j in range(i + 1, n):
            assert 0 <= H[i, j] < H[i, i]
    # same determinant magnitude (unimodular column ops)
    assert abs(intmat.det(H)) == abs(intmat.det(M))
    # idempotent
    assert np.array_equal(intmat.hermite_normal_form(H), H)


@given(nonsingular_matrices(4, -4, 4))
@settings(max_examples=30, deadline=None)
def test_hnf_dimension4(M):
    H = intmat.hermite_normal_form(M)
    assert abs(intmat.det(H)) == abs(intmat.det(M))
    assert np.array_equal(H, np.triu(H))


@given(nonsingular_matrices(3))
@settings(max_examples=40, deadline=None)
def test_right_equivalence_under_unimodular(M):
    U = np.array([[1, 2, 0], [0, 1, -1], [0, 0, 1]], dtype=np.int64)
    assert intmat.is_unimodular(U)
    assert intmat.right_equivalent(M, M @ U)


@given(nonsingular_matrices(3), st.lists(st.integers(-30, 30), min_size=3, max_size=3))
@settings(max_examples=60, deadline=None)
def test_canonical_label_is_congruent_and_boxed(M, v):
    H = intmat.hermite_normal_form(M)
    v = np.array(v, dtype=np.int64)
    lab = intmat.canonical_label(v, H)
    # inside the Hermite box
    assert (lab >= 0).all() and (lab < np.diagonal(H)).all()
    # congruent to v: v - lab in the column span of H over Z
    diff = (v - lab).astype(np.float64)
    u = np.linalg.solve(H.astype(np.float64), diff)
    assert np.allclose(u, np.round(u), atol=1e-6)


def test_smith_invariants_examples():
    assert intmat.smith_invariants(np.diag([4, 4, 4])) == (4, 4, 4)
    # FCC(2): group Z/2 x Z/2 x Z/4? order 16 -- just verify product = det
    from repro.core import fcc_matrix
    inv = intmat.smith_invariants(fcc_matrix(2))
    assert int(np.prod(inv)) == 16
    for a, b in zip(inv, inv[1:]):
        assert b % a == 0


def test_element_order_paper_formula():
    from repro.core import bcc_matrix, fcc_matrix
    # ord(e_3) = 2a in both FCC(a) and BCC(a) (paper §5.2)
    for a in (2, 3, 4):
        e3 = np.array([0, 0, 1])
        assert intmat.element_order(e3, fcc_matrix(a)) == 2 * a
        assert intmat.element_order(e3, bcc_matrix(a)) == 2 * a


def test_element_order_vs_bruteforce():
    from repro.core import LatticeGraph, fourd_bcc_matrix
    M = fourd_bcc_matrix(2)
    g = LatticeGraph(M)
    rng = np.random.default_rng(1)
    for _ in range(10):
        x = rng.integers(-5, 6, size=4)
        o = intmat.element_order(x, M)
        # brute force: smallest k >= 1 with k*x == 0 (mod M)
        k = 1
        while g.label_to_index(k * x) != 0:
            k += 1
            assert k <= g.order
        assert o == k
