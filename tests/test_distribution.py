"""Multi-device distribution tests.

The main pytest process must keep seeing ONE device (per the dry-run spec),
so anything needing a mesh runs in a subprocess with
--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_in_subprocess(body: str) -> str:
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_executes_and_learns():
    out = run_in_subprocess("""
        import dataclasses
        from repro.configs import get_config
        from repro.models import init_params
        from repro.optim import adamw
        from repro.runtime.steps import make_train_step
        from repro.parallel import sharding as shard
        from repro.launch.specs import input_specs

        cfg = get_config("qwen3-4b").reduced()
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init(params)
        pspecs = shard.make_param_specs(cfg, mesh)
        ospecs = adamw.AdamWState(step=P(), m=pspecs, v=pspecs)
        ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        params = jax.device_put(params, ns(pspecs))
        opt = jax.device_put(opt, ns(ospecs))
        rules = shard.make_activation_rules(cfg, mesh, "train", 8)
        step = make_train_step(cfg, lr=1e-2)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                    cfg.vocab_size)
        labels = jnp.roll(tokens, -1, axis=1)
        losses = []
        with mesh, shard.activation_rules(rules, mesh=mesh, fsdp_axis="data"):
            jstep = jax.jit(step)
            for _ in range(8):
                params, opt, m = jstep(params, opt,
                                       {"tokens": tokens, "labels": labels})
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses
        print("LEARNS", losses[0], "->", losses[-1])
    """)
    assert "LEARNS" in out


def test_moe_sharded_matches_local_on_mesh():
    out = run_in_subprocess("""
        import dataclasses
        from repro.configs import get_config
        from repro.models.mlp import init_moe, moe_local, moe_sharded
        from repro.parallel import sharding as shard

        cfg = get_config("deepseek-moe-16b").reduced()
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
        y_ref, _ = moe_local(p, cfg, x)
        rules = shard.make_activation_rules(cfg, mesh, "train", 4)
        with mesh, shard.activation_rules(rules, mesh=mesh, fsdp_axis="data"):
            y_sh, _ = jax.jit(lambda p, x: moe_sharded(p, cfg, x, mesh))(p, x)
        err = float(jnp.abs(y_ref - y_sh).max())
        rel = err / float(jnp.abs(y_ref).max())
        assert rel < 0.02, (err, rel)
        print("MOE_OK", rel)
    """)
    assert "MOE_OK" in out


def test_elastic_restore_onto_different_mesh(tmp_path):
    out = run_in_subprocess(f"""
        from repro.configs import get_config
        from repro.models import init_params, forward
        from repro.parallel import sharding as shard
        from repro.checkpoint.checkpointing import (save_checkpoint,
                                                    restore_checkpoint,
                                                    reshard_for_mesh)

        cfg = get_config("olmo-1b").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                    cfg.vocab_size)
        # "old pod": 4x2 mesh
        mesh_a = jax.make_mesh((4, 2), ("data", "model"),
                               axis_types=(jax.sharding.AxisType.Auto,) * 2)
        specs_a = shard.make_param_specs(cfg, mesh_a)
        pa = reshard_for_mesh(params, mesh_a, specs_a)
        with mesh_a:
            la, _ = jax.jit(lambda p, t: forward(p, cfg, t))(pa, tokens)
        save_checkpoint(r"{tmp_path}", 5, pa)
        # "upgraded pod": 2x4 mesh (different layout entirely)
        mesh_b = jax.make_mesh((2, 4), ("data", "model"),
                               axis_types=(jax.sharding.AxisType.Auto,) * 2)
        restored = restore_checkpoint(r"{tmp_path}", 5, params)
        specs_b = shard.make_param_specs(cfg, mesh_b)
        pb = reshard_for_mesh(restored, mesh_b, specs_b)
        with mesh_b:
            lb, _ = jax.jit(lambda p, t: forward(p, cfg, t))(pb, tokens)
        # bf16 matmuls reduce in different orders on different layouts
        err = float(jnp.abs(la.astype(jnp.float32) -
                            lb.astype(jnp.float32)).max())
        assert err < 5e-2, err
        print("ELASTIC_OK", err)
    """)
    assert "ELASTIC_OK" in out


def test_dryrun_cell_on_tiny_mesh():
    """The dry-run build machinery itself, on an 8-device mesh with a
    reduced config (full configs are exercised by the real dry-run)."""
    out = run_in_subprocess("""
        from repro.configs import get_config, get_shape
        from repro.launch import specs as S
        from repro.launch.hlo_analysis import collective_stats
        from repro.parallel import sharding as shard
        from repro.runtime.steps import make_train_step
        from repro.optim.adamw import AdamWState

        cfg = get_config("phi3-mini-3.8b").reduced()
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
        pspecs = shard.make_param_specs(cfg, mesh)
        ospecs = AdamWState(step=P(), m=pspecs, v=pspecs)
        ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        import functools
        params = S.abstract_params(cfg)
        opt = S.abstract_opt_state(cfg)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        bspecs = {"tokens": P(("pod", "data"), None),
                  "labels": P(("pod", "data"), None)}
        rules = shard.make_activation_rules(cfg, mesh, "train", 8)
        step = make_train_step(cfg, unroll=cfg.num_layers)
        with mesh, shard.activation_rules(rules, mesh=mesh, fsdp_axis="data"):
            lowered = jax.jit(step, in_shardings=(ns(pspecs), ns(ospecs),
                                                  ns(bspecs)),
                              out_shardings=(ns(pspecs), ns(ospecs),
                                             {"loss": NamedSharding(mesh, P())}),
                              donate_argnums=(0, 1)).lower(params, opt, batch)
            compiled = lowered.compile()
        cost = compiled.cost_analysis()
        coll = collective_stats(compiled.as_text())
        assert cost["flops"] > 0
        assert coll.total_bytes > 0
        print("DRYRUN_OK", cost["flops"], coll.total_bytes)
    """)
    assert "DRYRUN_OK" in out
