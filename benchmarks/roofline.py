"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh), all in seconds per step:
  compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF bf16)
  memory     = HLO_bytes_per_device / HBM_bw               (819 GB/s)
  collective = collective_bytes_per_device / link_bw       (50 GB/s/link)

(cost_analysis() and the parsed HLO are the per-device SPMD program, so
"per device" here equals the spec's global/(chips·rate) formulation.)

Also reported: MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (serve);
the usefulness ratio MODEL_FLOPS/HLO_FLOPs; the dominant term; and the
roofline fraction  model_compute_time / dominant_term  (the perf score).
"""
from __future__ import annotations

import json
from pathlib import Path

from .util import emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ARTIFACTS = Path(__file__).resolve().parent / "artifacts"


def load_artifacts(mesh: str = "pod16x16", tag_filter: str | None = None):
    rows = {}
    for f in sorted(ARTIFACTS.glob("*.json")):
        try:
            d = json.loads(f.read_text())
        except Exception:
            continue
        if d.get("mesh") != mesh:
            continue
        if tag_filter and tag_filter not in f.name:
            continue
        key = (d["arch"], d["shape"])
        rows.setdefault(key, []).append((f.name, d))
    return rows


def analyze(d: dict) -> dict:
    chips = d["chips"]
    compute = d["flops_per_device"] / PEAK_FLOPS
    memory = d["bytes_accessed_per_device"] / HBM_BW
    coll = d["collective"]["total_bytes"] / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    # recompute MODEL_FLOPS from the config (early artifacts hit an int32
    # overflow in the stored value)
    from repro.configs import get_config, get_shape
    from repro.launch.specs import model_flops
    mf = model_flops(get_config(d["arch"]), get_shape(d["shape"]))
    d = dict(d, model_flops_global=mf)
    model_time = d["model_flops_global"] / (chips * PEAK_FLOPS)
    hlo_total = d["flops_per_device"] * chips
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": coll,
        "dominant": dominant,
        "model_flops": d["model_flops_global"],
        "useful_ratio": d["model_flops_global"] / max(hlo_total, 1e-30),
        "roofline_fraction": model_time / max(terms[dominant], 1e-30),
        "mem_gib": (d["memory"]["argument_bytes"] + d["memory"]["temp_bytes"]
                    + d["memory"]["output_bytes"]
                    - d["memory"]["alias_bytes"]) / 2**30,
    }


def markdown_table(mesh: str = "pod16x16") -> str:
    rows = load_artifacts(mesh)
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | MODEL/HLO | roofline frac | mem GiB/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), artifacts in sorted(rows.items()):
        name, d = artifacts[-1]
        a = analyze(d)
        lines.append(
            f"| {arch} | {shape} | {a['compute_s']:.4f} | {a['memory_s']:.4f} "
            f"| {a['collective_s']:.4f} | **{a['dominant']}** "
            f"| {a['useful_ratio']:.2f} | {a['roofline_fraction']:.2f} "
            f"| {a['mem_gib']:.1f} |")
    return "\n".join(lines)


def main(quick: bool = False) -> None:
    rows = load_artifacts()
    if not rows:
        emit("roofline/no-artifacts", 0.0,
             "run `python -m repro.launch.dryrun --all` first")
        return
    for (arch, shape), artifacts in sorted(rows.items()):
        name, d = artifacts[-1]
        a = analyze(d)
        emit(f"roofline/{arch}/{shape}", d.get("compile_s", 0) * 1e6,
             f"compute={a['compute_s']:.4f}s;memory={a['memory_s']:.4f}s;"
             f"collective={a['collective_s']:.4f}s;dominant={a['dominant']};"
             f"useful={a['useful_ratio']:.2f};"
             f"roofline_frac={a['roofline_fraction']:.2f}")
    out = ARTIFACTS / "roofline_table.md"
    out.write_text(markdown_table())
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()
