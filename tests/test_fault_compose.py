"""ISSUE 9 (robustness): fault tolerance composes with everything.

The full fault-injection matrix is now
``{pristine, Scenario, FaultSchedule} × {V=1, V≥2} × {trivial, weighted,
pillar, express}`` with only the fused kernel's documented exclusions
remaining (docs/simulator.md, "Feature-compatibility matrix").  This
module pins the composition contracts:

  * **VC × FaultSchedule bitwise bridge** — a degenerate single-epoch
    schedule run at ``vcs ≥ 2`` equals the static `Scenario` VC run bit
    for bit (PR 5's bridge, lifted to the credit-flow router);
  * **credit accounting under churn** — ``credit == credit_init −
    occupancy`` at EVERY slot of a scheduled VC run, including slots
    where a node death drops enqueued phits across all lanes (the freed
    occupancy's downstream credits are restored in the same slot);
  * **express channels die and repair like any link** — zero
    dead-channel crossings over the extended 2n+2X port axis, per-slot
    conservation through death/repair, and the greedy weighted-DOR
    record falls back to base-lattice ports while an express hop is
    masked;
  * **fault-aware escape under VCs** — with DOR's escape port dead,
    `credit_vc_select` falls back to the PR 3 escape-policy misroute on
    VC0 only; the escape-CDG stays acyclic on faulted cells because the
    fallback only ever crosses LIVE channels (re-enumerated here in
    tests/test_vc_router.py style);
  * **single source of combo rejection** — every remaining unsupported
    cell raises the same actionable message from `SimConfig` and from
    the internal planner paths;
  * **composition property** (propcheck) — random (vcs, dim_weights,
    express, event-list) draws hold per-slot conservation, zero
    dead-channel crossings, and per-VC conservation V-sums.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (FaultSchedule, LinkSpec, Scenario, SimConfig,
                        Torus)
from repro.core.sim_config import validate_feature_combo
from repro.core.simulation import (_init_state, _make_ctx,
                                   _make_slot_step_vc_batched,
                                   _make_traffic, build_tables,
                                   schedule_recovery_slots, simulate,
                                   simulate_schedule_sweep)
from repro.core.fault_schedule import ensure_compiled

G = Torus(4, 4)
TAB = build_tables(G)
KW = dict(slots=96, warmup=0, seed=2, tables=TAB)


def check_timeline(r):
    tl = r.timeline
    assert tl is not None
    assert tl.conservation_ok(), tl.conservation_violations()
    assert tl.dead_crossings.sum() == 0
    assert tl.delivered[-1] == r.delivered
    assert tl.injected[-1] == r.injected
    assert tl.dropped[-1] == r.dropped
    assert tl.in_flight[-1] == r.in_flight


# ---------------------------------------------------------------------------
# VC × FaultSchedule: the E=1 bitwise bridge + per-slot accounting
# ---------------------------------------------------------------------------

_VC_CELLS = [
    (Scenario.random_link_faults(G, 2, seed=3, policy="dor"), "uniform"),
    (Scenario.random_link_faults(G, 3, seed=4, policy="adaptive"),
     "randompairings"),
    (Scenario.random_link_faults(G, 2, seed=5, policy="escape"),
     "uniform"),
    (Scenario.random_node_faults(G, 2, seed=6, policy="adaptive"),
     "uniform"),
]


@pytest.mark.parametrize("impl", ["batched", "reference"])
@pytest.mark.parametrize("scen,pattern", _VC_CELLS,
                         ids=[f"{s.policy}-{p}" for s, p in _VC_CELLS])
def test_vc_single_epoch_schedule_bitwise_equals_static(scen, pattern,
                                                        impl):
    """E=1 schedule ≡ static scenario at vcs=2, counter for counter —
    PR 5's bridge extended to the credit-flow router on both the traced
    and the baked-mask implementation."""
    a = simulate(G, pattern, 0.45, scenario=scen, vcs=2, impl=impl, **KW)
    b = simulate(G, pattern, 0.45,
                 schedule=FaultSchedule.from_scenario(scen), vcs=2,
                 impl=impl, **KW)
    for f in ("delivered", "injected", "dropped", "in_flight",
              "accepted_load", "lat_count"):
        assert getattr(a, f) == getattr(b, f), f
    np.testing.assert_array_equal(a.vc_delivered, b.vc_delivered)
    np.testing.assert_array_equal(a.vc_injected, b.vc_injected)
    check_timeline(b)


@pytest.mark.parametrize("vcs", [2, 3])
def test_vc_schedule_conservation_through_flap(vcs):
    sched = FaultSchedule.link_flap((0, 0), 16, 56, policy="adaptive")
    r = simulate(G, "uniform", 0.5, schedule=sched, vcs=vcs, **KW)
    check_timeline(r)
    assert int(r.vc_delivered.sum()) == r.delivered
    assert int(r.vc_injected.sum()) == r.injected + r.dropped
    assert int(r.vc_in_flight.sum()) == r.in_flight


def test_vc_schedule_node_death_drops_all_lanes():
    """A killed node's enqueued phits drop across every lane the slot it
    dies; the ledger balances at every slot, not just run end."""
    sched = FaultSchedule(events=((20, "node_down", 5),
                                  (60, "node_up", 5)),
                          base=Scenario(policy="adaptive"))
    r = simulate(G, "uniform", 0.5, schedule=sched, vcs=2, **KW)
    check_timeline(r)
    assert r.dropped > 0          # the death actually cost packets
    compiled = ensure_compiled(sched, G, KW["slots"])
    death = compiled.starts[1]
    tl = r.timeline
    # the drop ledger moves at (or after: dead-destination refusals) the
    # death slot and never before it
    assert tl.dropped[death - 1] == 0
    assert tl.dropped[-1] == r.dropped


@pytest.mark.parametrize("credits", [None, 3])
def test_vc_credit_invariant_per_slot_under_schedule(credits):
    """credit[w,p,v] == credit_init − occupancy(w,p,v) after EVERY slot
    of a scheduled run — including the node-death slots where dropped
    occupancy must hand its credits back."""
    sched = FaultSchedule(events=((12, "node_down", 5),
                                  (30, "node_up", 5),
                                  (36, "link_down", (1, 2))),
                          base=Scenario(policy="adaptive"))
    compiled = sched.compile(G, 48)
    ctx = _make_ctx(TAB, G, "uniform", 0, 4, schedule=compiled, vcs=2,
                    credits=credits)
    state = _init_state(ctx, 0.6, "batched")
    slots = 48
    tr = _make_traffic(ctx, state, jax.random.PRNGKey(7), slots)
    tr["epoch"] = state["slot2epoch"]
    step = jax.jit(_make_slot_step_vc_batched(ctx, 0))
    cinit = ctx["credit_init"]
    for s in range(slots):
        state, _ = step(state, {k: v[s] for k, v in tr.items()})
        credit = np.asarray(state["credit"])
        occ = (np.asarray(state["birth"]) >= 0).sum(axis=3)
        assert (credit == cinit - occ).all(), f"slot {s}"
        assert credit.min() >= 0 and credit.max() <= cinit, f"slot {s}"
    assert int(state["delivered"]) > 0


def test_vc_schedule_sweep_lane_bitwise_vs_single():
    """Sweep lane k at vcs=2 ≡ the single-schedule run (common random
    numbers), and a static lane ≡ the scenario run."""
    scen = Scenario(dead_links=((5, 0),), policy="adaptive")
    flap = FaultSchedule.link_flap((9, 2), 16, 48,
                                   base=Scenario(policy="adaptive"))
    rows = simulate_schedule_sweep(G, "uniform", [scen, flap],
                                   loads=(0.45,), vcs=2, **KW)
    single = simulate(G, "uniform", 0.45, schedule=flap, vcs=2, **KW)
    static = simulate(G, "uniform", 0.45, scenario=scen, vcs=2, **KW)
    assert rows[1][0].delivered == single.delivered
    assert rows[1][0].injected == single.injected
    assert rows[0][0].delivered == static.delivered
    for row in rows:
        check_timeline(row[0])


# ---------------------------------------------------------------------------
# faults × express overlays: the extended 2n+2X port axis
# ---------------------------------------------------------------------------

_XLS = LinkSpec(express=((0, 2, 1),))


def test_express_link_death_and_repair():
    """An express channel dies and repairs like any link: conservation
    and the dead-crossing audit hold per slot over the extended axis,
    and traffic falls back to base-lattice ports while it is down."""
    sched = FaultSchedule.link_flap((0, 4), 16, 56)
    r = simulate(G, "uniform", 0.45, schedule=sched, links=_XLS, **KW)
    check_timeline(r)
    pristine = simulate(G, "uniform", 0.45, links=_XLS, **KW)
    assert r.delivered > 0.9 * pristine.delivered   # graceful, not broken


def test_express_scenario_masks_extended_axis():
    scen = Scenario(dead_links=((0, 4),))
    r = simulate(G, "uniform", 0.45, scenario=scen, links=_XLS, **KW)
    assert r.delivered > 0
    # the dead express channel is never crossed (link_use audit covers
    # the full extended axis for non-trivial scenarios)
    assert r.link_use is not None and r.link_use.shape[1] == 6
    assert r.link_use[0, 4] == 0 and r.link_use[0, 5] > 0


def test_express_dead_node_kills_its_express_ports():
    scen = Scenario(dead_nodes=(5,))
    r = simulate(G, "uniform", 0.45, scenario=scen, links=_XLS, **KW)
    assert r.link_use[5].sum() == 0
    assert r.delivered + r.in_flight + r.dropped == r.injected


def test_express_faults_compose_with_vcs():
    scen = Scenario(dead_links=((0, 4),), policy="adaptive")
    r = simulate(G, "uniform", 0.45, scenario=scen, links=_XLS, vcs=2,
                 **KW)
    assert r.delivered + r.in_flight + r.dropped == r.injected
    assert int(r.vc_delivered.sum()) == r.delivered
    # and under a timeline too
    sched = FaultSchedule.link_flap((0, 4), 16, 56,
                                    base=Scenario(policy="adaptive"))
    rt = simulate(G, "uniform", 0.45, schedule=sched, links=_XLS, vcs=2,
                  **KW)
    check_timeline(rt)


def test_scenario_link_ok_extends_and_validates_ports():
    ok = Scenario(dead_links=((0, 4),)).link_ok(G, _XLS)
    assert ok.shape == (G.order, 6)
    assert not ok[0, 4]
    v = int(_XLS.extended_neighbors(G)[0, 4])
    assert not ok[v, 5]          # undirected: far endpoint's paired port
    with pytest.raises(ValueError, match="only 4 ports"):
        Scenario(dead_links=((0, 4),)).link_ok(G)
    with pytest.raises(ValueError, match="express-port events"):
        FaultSchedule(events=((5, "link_down", (0, 4)),)).compile(G, 32)


# ---------------------------------------------------------------------------
# fault-aware escape under VCs: the VC0 misroute fallback
# ---------------------------------------------------------------------------

def test_credit_vc_select_escape_fallback_unit():
    """When the DOR escape port is dead and no adaptive lane has credit,
    the fallback misroutes through a live record-zero-dimension port on
    VC0 only; on a live DOR port the flag is bitwise-invisible."""
    import jax.numpy as jnp

    from repro.core.routing_engine import credit_vc_select

    rec = jnp.array([[2, 0]], dtype=jnp.int32)       # DOR dim 0, port 0
    link_ok = jnp.array([[False, True, True, True]])
    credit = jnp.zeros((1, 4, 2), jnp.int32).at[:, :, 0].set(4)
    p0, v0 = credit_vc_select(rec, link_ok, credit, policy="escape",
                              escape_fallback=False)
    p1, v1 = credit_vc_select(rec, link_ok, credit, policy="escape",
                              escape_fallback=True)
    # without the flag the escape request still names the dead port
    assert (int(p0[0]), int(v0[0])) == (0, 0)
    # with it: a live orthogonal port, still VC0
    assert int(p1[0]) in (2, 3) and int(v1[0]) == 0
    live = jnp.ones_like(link_ok)
    pa, va = credit_vc_select(rec, live, credit, policy="escape",
                              escape_fallback=False)
    pb, vb = credit_vc_select(rec, live, credit, policy="escape",
                              escape_fallback=True)
    assert (int(pa[0]), int(va[0])) == (int(pb[0]), int(vb[0]))


def test_vc_escape_fallback_drains_stale_cohort():
    """Records are written fault-aware at injection, so a STATIC dead
    link never strands a VC packet — the fallback earns its keep when a
    link dies mid-run under packets already in flight with stale
    records.  Under 'adaptive' that cohort wedges (its escape port is
    dead and stays dead); the 'escape' fallback misroutes it on VC0 and
    in_flight returns to its pre-death level."""
    g = Torus(8, 8)
    kw = dict(slots=384, warmup=0, seed=3, vcs=2)

    def run(pol):
        sched = FaultSchedule(events=((96, "link_down", (0, 0)),),
                              base=Scenario(policy=pol))
        return simulate(g, "uniform", 0.3, schedule=sched, **kw)

    esc, ad = run("escape"), run("adaptive")
    check_timeline(esc)
    check_timeline(ad)
    pre = int(esc.timeline.injected[90] - esc.timeline.delivered[90]
              - esc.timeline.dropped[90])
    # escape drains back toward the pre-death baseline; adaptive strands
    # the stale cohort on top of it
    assert esc.in_flight <= 1.3 * pre
    assert ad.in_flight > esc.in_flight


def test_vc_escape_fallback_never_crosses_dead_channels():
    scen = Scenario(dead_links=((0, 0), (3, 2)), policy="escape")
    sched = FaultSchedule.from_scenario(scen)
    r = simulate(G, "uniform", 0.5, schedule=sched, vcs=2, **KW)
    check_timeline(r)


def test_escape_cdg_acyclic_on_faulted_cells():
    """Duato's argument survives the fallback: VC0's restricted-DOR
    transitions still only continue a ring or climb dimensions, and the
    misroute egress is always a LIVE channel, so removing dead channels
    from the escape CDG cannot create a cycle.  Enumerate the faulted
    CDG (test_vc_router style) and topologically sort its ring
    quotient."""
    scen = Scenario(dead_links=((5, 0), (9, 2)), policy="escape")
    link_ok = scen.link_ok(G)
    t = TAB
    nbr, n, N = t.neighbors, t.n, t.N
    edges = set()
    for table in (t.records_a, t.records_b):
        for src in range(N):
            for di in range(N):
                rec = table[di].copy()
                cur, prev = src, None
                guard = 0
                while np.abs(rec).sum() > 0 and guard < 8 * N:
                    guard += 1
                    d = int(np.argmax(np.abs(rec) > 0))
                    s = int(rec[d])
                    p = 2 * d + (s < 0)
                    if not link_ok[cur, p]:
                        break     # escape lane blocked: the fallback
                                  # misroutes on an adaptive-score port,
                                  # leaving the escape CDG entirely
                    ch = (cur, p)
                    if prev is not None:
                        edges.add((prev, ch))
                    cur = int(nbr[cur, p])
                    rec[d] -= int(np.sign(s))
                    prev = ch
    assert edges
    # every surviving escape transition climbs dimensions or stays on
    # its directed ring — the faulted CDG is a sub-DAG of the pristine
    for (w1, p1), (w2, p2) in edges:
        assert link_ok[w1, p1] and link_ok[w2, p2]
        assert p1 == p2 or p2 // 2 > p1 // 2


# ---------------------------------------------------------------------------
# centralized combo rejection: one message everywhere
# ---------------------------------------------------------------------------

_EXCLUDED = [
    (dict(impl="fused", vcs=2), "V=1-only",
     dict(impl="fused", vcs=2)),
    (dict(impl="fused", links_trivial=False), "weight-1/no-overlay",
     dict(impl="fused", links=LinkSpec(dim_weights=(1, 2)))),
    (dict(express=True, vcs=1, policy="adaptive"), "greedy",
     dict(links=LinkSpec(express=((0, 2, 1),)),
          scenario=Scenario(dead_links=((0, 0),), policy="adaptive"))),
    (dict(express=True, vcs=1, policy="escape"), "greedy",
     dict(links=LinkSpec(express=((0, 2, 1),)),
          scenario=Scenario(policy="escape"))),
]


@pytest.mark.parametrize("combo,match,cfg_kw", _EXCLUDED,
                         ids=["fused-vcs", "fused-links",
                              "express-adaptive", "express-escape"])
def test_unsupported_cells_raise_same_message_everywhere(combo, match,
                                                         cfg_kw):
    """`validate_feature_combo` is the single source: the SimConfig
    surface and the internal planner raise the IDENTICAL message."""
    with pytest.raises(ValueError, match=match) as direct:
        validate_feature_combo(**combo)
    with pytest.raises(ValueError, match=match) as via_cfg:
        SimConfig(**cfg_kw)
    assert str(direct.value) == str(via_cfg.value)


def test_make_ctx_rejects_express_adaptive_like_simconfig():
    with pytest.raises(ValueError, match="greedy"):
        _make_ctx(TAB, G, "uniform", 0, 4,
                  Scenario(dead_links=((0, 0),), policy="adaptive"),
                  links=LinkSpec(express=((0, 2, 1),)))


# ---------------------------------------------------------------------------
# recovery telemetry on VC scheduled runs
# ---------------------------------------------------------------------------

def test_recovery_slots_on_vc_link_flap():
    sched = FaultSchedule.link_flap((0, 0), 96, 224,
                                    base=Scenario(policy="adaptive"))
    r = simulate(G, "uniform", 0.6, slots=384, warmup=0, seed=3,
                 tables=TAB, vcs=2, schedule=sched, hist_bins=32)
    tl = r.timeline
    assert tl.lat_hist is not None and tl.lat_hist.shape == (384, 32)
    check_timeline(r)
    rec = schedule_recovery_slots(r, sched, q=0.99, window=48,
                                  slack_cycles=16.0)
    assert rec is not None and 0 <= rec < 384 - 224
    # the p99 trace visibly degrades during the outage
    trace = tl.latency_percentile_trace(q=0.99, window=48)
    assert np.nanmax(trace[96:224]) >= np.nanmax(trace[:96])


# ---------------------------------------------------------------------------
# the propcheck composition property
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    vcs=st.sampled_from([1, 2, 3]),
    wy=st.sampled_from([1, 2]),
    express=st.booleans(),
    events=st.lists(
        st.tuples(st.integers(min_value=0, max_value=63),
                  st.sampled_from(["link_down", "link_up", "node_down",
                                   "node_up"]),
                  st.integers(min_value=0, max_value=15),
                  st.integers(min_value=0, max_value=3)),
        min_size=1, max_size=6),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_composition_property(vcs, wy, express, events, seed):
    """Random (vcs, dim_weights, express, FaultSchedule) draws hold the
    per-slot ledger, cross no dead channel (express ports included), and
    keep per-VC conservation V-sums."""
    ls = LinkSpec(dim_weights=(1, wy),
                  express=((0, 2, 1),) if express else ())
    evs = []
    for slot, kind, node, port in events:
        if kind.startswith("link"):
            evs.append((slot, kind, (node, port)))   # base ports only:
        elif node != 0:                              # events may also be
            evs.append((slot, kind, node))           # no-ops — fine
    sched = FaultSchedule(events=tuple(evs),
                          base=Scenario(policy="adaptive" if vcs > 1
                                        else "dor"))
    r = simulate(G, "uniform", 0.45, slots=64, warmup=0, seed=seed,
                 tables=TAB, vcs=vcs, schedule=sched, links=ls)
    tl = r.timeline
    assert tl.conservation_ok(), tl.conservation_violations()
    assert tl.dead_crossings.sum() == 0
    if vcs > 1:
        assert int(r.vc_delivered.sum()) == r.delivered
        # injection-drops are already inside BOTH counters; queue drops
        # (node death) are in neither — so the V-sum matches `injected`
        # exactly, with no `dropped` correction
        assert int(r.vc_injected.sum()) == r.injected
        assert int(r.vc_in_flight.sum()) == r.in_flight
