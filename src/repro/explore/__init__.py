"""Closed-loop topology exploration over the paper's design space.

`python -m repro.explore` (or `make explore` / `make explore-smoke`)
runs a seeded evolutionary search over 3D HNF lattice matrices and
mixed-radix tori crossed with router/fabric parameters, scoring each
candidate on saturation throughput × p99 latency × faulted capacity
through the unified analytic surface, and emits an epsilon-Pareto front
with RTT/FCC/BCC and the same-order torus pinned as baselines.
"""
from .evaluate import EvalSettings, Evaluator, canonical_schedule
from .optimizer import ExploreResult, explore, load_checkpoint
from .pareto import ArchiveEntry, Objectives, ParetoArchive, dominates
from .space import Candidate, SearchSpace

__all__ = [
    "ArchiveEntry",
    "Candidate",
    "EvalSettings",
    "Evaluator",
    "ExploreResult",
    "Objectives",
    "ParetoArchive",
    "SearchSpace",
    "canonical_schedule",
    "dominates",
    "explore",
    "load_checkpoint",
]
