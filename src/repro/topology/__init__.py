"""Lattice-topology-aware TPU layer: collective cost model, logical-mesh
placement, elastic pod upgrades (the paper's §3.4 path)."""
from . import collective_model, placement, upgrade
