"""Transient-fault timeline engine throughput: the per-slot epoch-indexed
simulator vs the static scenario path, the K-schedule one-compile sweep
vs sequential per-timeline recompiles, and the epoch-stacked device BFS.

The acceptance bars (ISSUE 5): a scheduled run (per-slot epoch gathers +
conservation timeline) must stay within 2× of the static traced-mask
scenario path at the same size; a K=8-timeline
`simulate_schedule_sweep` must beat K sequential `simulate(schedule=)`
calls that each pay their own compile (the sweep's one compile is the
claim, so both sides are timed cold); and the per-epoch BFS rebuild of a
whole schedule must run as ONE compiled program
(`fault_aware_next_hop_device` stacked mode).  Sim rows are pinned at
N=512 in BOTH modes — the measured wins are compile amortization and
per-slot bookkeeping overhead, identical at any N (on XLA CPU vmap lanes
serialize, so large-N run time would drown them) — while the BFS row
scales to N=4096 × E=16 in full mode.  Emitted `slots_per_s` /
`loadpoints_per_s` / `epochs_per_s` metrics are gated by
`make bench-check`.
"""
from __future__ import annotations

import time

from repro.core import (FaultSchedule, Scenario, SimConfig, Torus,
                        fault_aware_next_hop, fault_aware_next_hop_device)
from repro.core.simulation import (_RUNNER_CACHE, build_tables, simulate,
                                   simulate_schedule_sweep)

from .util import emit

REPS = 3


def main(quick: bool = False) -> None:
    # ---- scheduled vs static slot-step overhead ----
    # pinned at N=512 in both modes: the quantity is the per-slot cost of
    # the epoch gathers + timeline emission, not lattice scale
    g = Torus(8, 8, 4, 2)
    slots, warmup = 192, 48
    t = build_tables(g)
    scen = Scenario.random_link_faults(g, 8, seed=5, policy="adaptive")
    flap = FaultSchedule(
        events=((slots // 4, "link_down", (1, 0)),
                (slots // 2, "link_down", (40, 2)),
                (3 * slots // 4, "link_up", (1, 0))),
        base=scen, name="bench_flap")
    cfg = SimConfig(slots=slots, warmup=warmup, seed=1, tables=t)
    kw = dict(config=cfg)

    def run_static():
        return simulate(g, "uniform", 0.6, config=cfg.replace(scenario=scen))

    def run_sched():
        return simulate(g, "uniform", 0.6, config=cfg.replace(schedule=flap))

    run_static()
    run_sched()                                    # compile both
    best = {"static": float("inf"), "timeline": float("inf")}
    for _ in range(REPS):
        for name, fn in (("static", run_static), ("timeline", run_sched)):
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    emit(f"transient/timeline/N={g.order}", best["timeline"] * 1e6,
         f"timeline_slots_per_s={slots / best['timeline']:.1f};"
         f"slots={slots};"
         f"overhead_vs_static={best['timeline'] / best['static']:.2f}x")

    # ---- K-schedule one-compile sweep vs sequential per-timeline runs ----
    # mirrors scenarios/scen_sweep8: the win is the single trace/compile
    # shared by all K timelines (each sequential run below starts from a
    # cold runner cache, which is what K independent evaluations cost
    # without the sweep)
    K = 8
    kscheds = [FaultSchedule.random_events(g, 6, slots, seed=100 + i,
                                           policy="adaptive")
               for i in range(K)]
    _RUNNER_CACHE.clear()
    t0 = time.perf_counter()
    simulate_schedule_sweep(g, "uniform", kscheds, loads=(0.6,), **kw)
    sweep_cold = time.perf_counter() - t0
    best_ksweep = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        simulate_schedule_sweep(g, "uniform", kscheds, loads=(0.6,), **kw)
        best_ksweep = min(best_ksweep, time.perf_counter() - t0)
    t0 = time.perf_counter()
    for s in kscheds:
        _RUNNER_CACHE.clear()              # per-timeline compile behavior
        simulate(g, "uniform", 0.6, config=cfg.replace(schedule=s))
    seq_cold = time.perf_counter() - t0
    emit(f"transient/sched_sweep{K}/N={g.order}", best_ksweep * 1e6,
         f"sched_loadpoints_per_s={K / best_ksweep:.2f};"
         f"one_compile_s={sweep_cold:.2f};seq_cold_s={seq_cold:.2f};"
         f"speedup_vs_seq_cold={seq_cold / sweep_cold:.1f}x")

    # ---- epoch-stacked device BFS: a whole timeline's per-epoch tables
    # in ONE compiled program ----
    gb = Torus(8, 8, 4, 2) if quick else Torus(8, 8, 8, 8)
    E = 4 if quick else 16
    churn = FaultSchedule.random_events(gb, 2 * E, 512, seed=7,
                                        policy="adaptive", node_events=True)
    cb = churn.compile(gb, 512)
    link, node = cb.link_ok_stack(gb), cb.node_ok_stack(gb)
    Eb = link.shape[0]
    fault_aware_next_hop_device(gb, link, node)    # compile
    best_bfs = float("inf")
    for _ in range(REPS if quick else 1):
        t0 = time.perf_counter()
        fault_aware_next_hop_device(gb, link, node)
        best_bfs = min(best_bfs, time.perf_counter() - t0)
    t0 = time.perf_counter()
    fault_aware_next_hop(gb, link[0], node[0])
    host_one = time.perf_counter() - t0
    emit(f"transient/bfs_epochs{Eb}/N={gb.order}", best_bfs * 1e6,
         f"bfs_epochs_per_s={Eb / best_bfs:.2f};"
         f"device_s={best_bfs:.2f};host_est_s={host_one * Eb:.1f};"
         f"device_vs_host={host_one * Eb / best_bfs:.1f}x")


if __name__ == "__main__":
    main()
