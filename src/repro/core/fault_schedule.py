"""Transient-fault timelines for lattice-graph fabrics.

A `Scenario` (PR 3/4) describes a *statically* degraded network.  Real
systems live with churn: links flap, nodes die and come back mid-run.
A `FaultSchedule` is the declarative time axis over that fault space —
an ordered list of fault/repair **events**

    (slot, kind, target)     kind ∈ {link_down, link_up,
                                     node_down, node_up}

applied on top of a base `Scenario` (initial faults + routing policy).
An event at slot ``s`` takes effect *from* slot ``s`` onward (the whole
of slot ``s`` already sees the new world).

The spec compiles against a graph and a run length into a
`CompiledSchedule`: the run is partitioned into **epochs** — maximal
slot ranges with a constant fault pattern — each of which is an ordinary
static `Scenario`, plus per-epoch mask stacks ``(E, …)`` and a
``slot→epoch`` map.  Consecutive epochs whose fault state is identical
are merged (a repair of a live link is a no-op, not a boundary), so a
schedule whose events never change anything compiles to E = 1 — and a
single-epoch schedule run is bitwise-equal to the static `Scenario` run
(tests/test_transient_sim.py pins this on every scenario × pattern
differential cell).

Downstream consumers (`repro.core.simulation`, the `distances` /
`throughput` fault-aware rebuilds) never branch on events in a hot loop:
they consume the stacked per-epoch masks as traced device inputs and the
slot→epoch map as a gather index — see docs/scenarios.md ("Transient
faults") for the threading through all three `slot_step` implementations
and the per-slot accounting semantics (enqueued packets at a node that
dies are dropped; conservation holds at every slot, not just at run
end).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from .lattice import LatticeGraph
from .scenario import Scenario

EVENT_KINDS = ("link_down", "link_up", "node_down", "node_up")


def _canonical_link(g: LatticeGraph, u: int, p: int,
                    link_spec=None) -> tuple[int, int]:
    """Undirected identity of channel (u, p): min of the two directed
    endpoints, so kill/repair pairs match regardless of which side the
    caller names.  Express ports (p >= 2n) resolve their far endpoint
    through `link_spec.extended_neighbors`."""
    if p >= 2 * g.n:
        if link_spec is None or not getattr(link_spec, "express", ()):
            raise ValueError(
                f"link event targets port {p} beyond the base lattice's "
                f"{2 * g.n} ports; express-port events need the matching "
                f"LinkSpec (SimConfig(links=...))")
        v = int(link_spec.extended_neighbors(g)[u, p])
    else:
        v = int(g.neighbor_indices[u, p])
    return min((int(u), int(p)), (v, int(p) ^ 1))


@dataclass(frozen=True)
class FaultSchedule:
    """Ordered fault/repair events over a base scenario (module docstring).

    events: tuple of ``(slot, kind, target)`` — target is ``(node, port)``
    for link events, a node index for node events.  Events are kept in
    slot order (stable for same-slot events: they apply in listed order);
    repairs of live targets and re-kills of dead ones are no-ops.
    """

    events: tuple = ()
    base: Scenario = Scenario()
    name: str = "schedule"

    def __post_init__(self):
        norm = []
        for ev in self.events:
            try:
                slot, kind, target = ev
            except (TypeError, ValueError):
                raise ValueError(
                    f"event {ev!r} is not a (slot, kind, target) triple")
            if kind not in EVENT_KINDS:
                raise ValueError(
                    f"unknown event kind {kind!r}; expected one of "
                    f"{EVENT_KINDS}")
            if kind.startswith("link"):
                try:
                    u, p = target
                except (TypeError, ValueError):
                    raise ValueError(
                        f"link event target {target!r} is not a "
                        f"(node, port) pair")
                target = (int(u), int(p))
            else:
                if isinstance(target, (tuple, list)):
                    if len(target) != 1:
                        raise ValueError(
                            f"node event target {target!r} is not a "
                            f"single node index")
                    target = target[0]
                target = int(target)
            norm.append((int(slot), kind, target))
        norm.sort(key=lambda ev: ev[0])        # stable: listed order kept
        object.__setattr__(self, "events", tuple(norm))

    @property
    def policy(self) -> str:
        return self.base.policy

    def with_policy(self, policy: str) -> "FaultSchedule":
        return replace(self, base=self.base.with_policy(policy),
                       name=f"{self.name}/{policy}")

    @property
    def is_static(self) -> bool:
        """True iff no events — the schedule is its base scenario."""
        return not self.events

    # -- compilation --------------------------------------------------------
    def compile(self, g: LatticeGraph, slots: int,
                link_spec=None) -> "CompiledSchedule":
        """Partition a `slots`-long run into constant-fault epochs.

        Events at slot ≤ 0 fold into the initial state; events at
        slot ≥ `slots` never take effect in this run and are dropped.
        Consecutive identical fault states merge (no spurious epochs).
        `link_spec=` resolves express-port link events (p >= 2n) to
        their undirected identity; base-port schedules never need it.
        """
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        dead_links = {_canonical_link(g, u, p, link_spec)
                      for u, p in self.base.dead_links}
        dead_nodes = set(int(u) for u in self.base.dead_nodes)
        by_slot: dict[int, list] = {}
        for slot, kind, target in self.events:
            s = max(slot, 0)
            if s >= slots:
                continue
            by_slot.setdefault(s, []).append((kind, target))

        def apply(kind, target):
            if kind == "link_down":
                dead_links.add(_canonical_link(g, *target, link_spec))
            elif kind == "link_up":
                dead_links.discard(_canonical_link(g, *target, link_spec))
            elif kind == "node_down":
                dead_nodes.add(target)
            else:
                dead_nodes.discard(target)

        def snapshot(at: int) -> Scenario:
            return Scenario(dead_links=tuple(sorted(dead_links)),
                            dead_nodes=tuple(sorted(dead_nodes)),
                            policy=self.base.policy,
                            name=f"{self.name}@{at}")

        for kind, target in by_slot.pop(0, []):
            apply(kind, target)
        epochs = [snapshot(0)]
        starts = [0]
        for s in sorted(by_slot):
            for kind, target in by_slot[s]:
                apply(kind, target)
            snap = snapshot(s)
            prev = epochs[-1]
            if (snap.dead_links == prev.dead_links
                    and snap.dead_nodes == prev.dead_nodes):
                continue                       # no-op events: no boundary
            epochs.append(snap)
            starts.append(s)
        starts_np = np.asarray(starts, dtype=np.int64)
        slot2epoch = (np.searchsorted(starts_np, np.arange(slots),
                                      side="right") - 1).astype(np.int32)
        return CompiledSchedule(
            epochs=tuple(epochs), starts=tuple(starts),
            slot2epoch=slot2epoch, policy=self.base.policy,
            slots=int(slots), name=self.name)

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_scenario(cls, scenario: Scenario | None) -> "FaultSchedule":
        """Degenerate (event-free) schedule: compiles to one epoch that IS
        the scenario — the bitwise-equality bridge to the static engine."""
        scenario = scenario or Scenario()
        return cls(base=scenario, name=f"static:{scenario.name}")

    @classmethod
    def link_flap(cls, link: tuple[int, int], down_at: int, up_at: int,
                  policy: str | None = None, base: Scenario | None = None,
                  ) -> "FaultSchedule":
        """One link dies at `down_at` and is repaired at `up_at` — the
        canonical transient-fault smoke scenario.  `policy=None` keeps
        the base scenario's policy (DOR for a fresh base); an explicit
        `policy` overrides it."""
        if up_at <= down_at:
            raise ValueError(
                f"repair slot {up_at} must follow failure slot {down_at}")
        base = base or Scenario()
        if policy is not None and base.policy != policy:
            base = replace(base, policy=policy)
        return cls(events=((down_at, "link_down", link),
                           (up_at, "link_up", link)),
                   base=base,
                   name=f"flap{link}@{down_at}-{up_at}")

    @classmethod
    def random_events(cls, g: LatticeGraph, k: int, slots: int,
                      seed: int = 0, policy: str = "adaptive",
                      node_events: bool = False) -> "FaultSchedule":
        """k random link (and optionally node) fault/repair events at
        uniform slots — the property-test / benchmark generator.  Repairs
        target previously-killed entities when any exist, so timelines
        exercise fail→repair→fail chains rather than pure decay."""
        rng = np.random.default_rng(seed)
        events = []
        downed_links: list[tuple[int, int]] = []
        downed_nodes: list[int] = []
        for _ in range(int(k)):
            slot = int(rng.integers(0, slots))
            pick_node = node_events and bool(rng.integers(0, 2))
            repair = bool(rng.integers(0, 2))
            if pick_node:
                if repair and downed_nodes:
                    u = downed_nodes.pop(int(rng.integers(
                        0, len(downed_nodes))))
                    events.append((slot, "node_up", u))
                else:
                    u = int(rng.integers(1, g.order))   # keep origin alive
                    downed_nodes.append(u)
                    events.append((slot, "node_down", u))
            else:
                if repair and downed_links:
                    link = downed_links.pop(int(rng.integers(
                        0, len(downed_links))))
                    events.append((slot, "link_up", link))
                else:
                    link = (int(rng.integers(0, g.order)),
                            int(rng.integers(0, 2 * g.n)))
                    downed_links.append(link)
                    events.append((slot, "link_down", link))
        return cls(events=tuple(events),
                   base=Scenario(policy=policy),
                   name=f"random{k}@{seed}")


@dataclass(frozen=True)
class CompiledSchedule:
    """A `FaultSchedule` bound to a graph and run length: per-epoch static
    scenarios plus the slot→epoch index map (see `FaultSchedule.compile`).
    """

    epochs: tuple[Scenario, ...]
    starts: tuple[int, ...]          # starts[e] = first slot of epoch e
    slot2epoch: np.ndarray           # (slots,) int32
    policy: str
    slots: int
    name: str = "schedule"

    @property
    def E(self) -> int:
        return len(self.epochs)

    @property
    def has_dead_nodes(self) -> bool:
        """True iff ANY epoch kills nodes — the program-structure bit the
        simulator's destination sampling specializes on."""
        return any(e.dead_nodes for e in self.epochs)

    def epoch_of(self, slot: int) -> int:
        return int(self.slot2epoch[slot])

    def scenario_at(self, slot: int) -> Scenario:
        """The static fault pattern in force during `slot`."""
        return self.epochs[self.epoch_of(slot)]

    def fingerprint(self, g: LatticeGraph) -> tuple:
        """Hashable identity for compiled-runner caches (reference oracle:
        masks are baked, so the full timeline is the key)."""
        return ("schedule",
                tuple(e.fingerprint(g) for e in self.epochs),
                self.slot2epoch.tobytes())

    # -- stacked masks -------------------------------------------------------
    def link_ok_stack(self, g: LatticeGraph, link_spec=None) -> np.ndarray:
        """(E, N, P) per-epoch channel-liveness masks (P = 2n, or 2n+2X
        when `link_spec` carries express overlays)."""
        return np.stack([e.link_ok(g, link_spec) for e in self.epochs])

    def node_ok_stack(self, g: LatticeGraph) -> np.ndarray:
        """(E, N) per-epoch node-liveness masks."""
        return np.stack([e.node_ok(g) for e in self.epochs])


def ensure_compiled(schedule, g: LatticeGraph, slots: int,
                    link_spec=None) -> CompiledSchedule:
    """Normalize a schedule argument (every schedule-taking API funnels
    through here): a `FaultSchedule` compiles against this run's length;
    an already-compiled `CompiledSchedule` must match it — a silent
    slots mismatch would index epochs the run never reaches."""
    if isinstance(schedule, CompiledSchedule):
        if schedule.slots != slots:
            raise ValueError(
                f"schedule was compiled for {schedule.slots} slots, "
                f"this run has {slots}")
        return schedule
    return schedule.compile(g, slots, link_spec)
