"""Shared model building blocks (pure JAX, no flax).

Parameters are plain pytrees (nested dicts of jnp arrays).  Every init helper
has a mirrored `*_spec` helper producing the same-structure pytree of
`PartitionSpec`s (see repro.parallel.sharding).  Compute follows a mixed
precision policy: parameters are stored fp32 and cast to bf16 for compute;
reductions (norms, softmax, losses) run in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


def cast_compute(x):
    return x.astype(COMPUTE_DTYPE)


def dense_init(key, d_in: int, d_out: int, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), PARAM_DTYPE) * scale


def embed_init(key, vocab: int, d: int):
    return jax.random.normal(key, (vocab, d), PARAM_DTYPE) * 0.02


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    if weight is not None:
        out = out * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm_nonparametric(x, eps: float = 1e-5):
    """OLMo-style non-parametric LayerNorm (no learned scale/bias)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def make_norm(cfg):
    if cfg.nonparametric_norm:
        return lambda x, w: layer_norm_nonparametric(x, cfg.norm_eps)
    return lambda x, w: rms_norm(x, w, cfg.norm_eps)


def norm_param(cfg, d: int):
    if cfg.nonparametric_norm:
        return jnp.zeros((0,), PARAM_DTYPE)  # placeholder keeps pytrees uniform
    return jnp.ones((d,), PARAM_DTYPE)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    inv_freq = jnp.asarray(rope_frequencies(hd, theta))
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d: int):
    """Whisper-style sinusoidal embeddings, (S, d)."""
    pos = np.arange(seq_len, dtype=np.float32)[:, None]
    dim = np.arange(d // 2, dtype=np.float32)[None, :]
    angle = pos / np.power(10_000.0, 2 * dim / d)
    return jnp.asarray(
        np.concatenate([np.sin(angle), np.cos(angle)], axis=-1), jnp.float32)


def sinusoidal_at(position, d: int):
    """Sinusoidal embedding for a traced scalar position → (d,)."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    angle = position.astype(jnp.float32) / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, ignore_id: int = -1):
    """Mean token cross-entropy in fp32; labels == ignore_id are masked."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
