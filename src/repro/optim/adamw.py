"""AdamW in pure JAX (no optax dependency).

Moments are fp32 and inherit each parameter's sharding (ZeRO-1/3 falls out of
the param specs for free).  The update is fused into train_step so XLA can
overlap it with the tail of the backward pass.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array      # () int32
    m: Any               # pytree like params, fp32
    v: Any               # pytree like params, fp32


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
           b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1,
           grad_clip: float = 1.0):
    """Returns (new_params, new_state).  `lr` may be a scalar array (from a
    schedule) or a python float."""
    step = state.step + 1
    if grad_clip:
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * g32 * g32
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr_at(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr_at
