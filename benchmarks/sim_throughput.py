"""Slots/sec of the port-batched simulator vs the per-port-sweep reference.

This is the ISSUE 2 acceptance benchmark: a full 512-slot uniform-traffic
run at N=4096 (T(8,8,8,8)), batched vs reference, timed interleaved
best-of-`REPS` (the two implementations alternate so machine noise hits
both), plus the vmapped `simulate_sweep` cost per load point.  Quick mode
shrinks to N=512 / 192 slots for CI smoke.

The reference implementation is the pre-batching simulator algorithm
(sequential per-port sweep, in-scan PRNG draws), so `speedup` here is the
committed record of the batched rewrite's win.
"""
from __future__ import annotations

import time

from repro.core import SimConfig, Torus
from repro.core.simulation import build_tables, simulate, simulate_sweep

from .util import emit

REPS = 3


def _best(f, reps=REPS) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        f()
        best = min(best, time.perf_counter() - t0)
    return best


def main(quick: bool = False) -> None:
    g = Torus(8, 8, 4, 2) if quick else Torus(8, 8, 8, 8)
    slots = 192 if quick else 512
    warmup = 48 if quick else 128
    loads = (0.3, 0.6, 1.0) if quick else (0.2, 0.4, 0.6, 0.8, 1.0)
    t = build_tables(g)
    cfg = SimConfig(slots=slots, warmup=warmup, seed=1, tables=t)

    def run(impl, load=0.6):
        return simulate(g, "uniform", load, config=cfg.replace(impl=impl))

    # compile all three before timing, then alternate (fair under machine
    # noise); "fused" is the Pallas kernel path — interpret mode off-TPU,
    # so this row records the cost of the kernel formulation itself
    impls = ("batched", "fused", "reference")
    for impl in impls:
        run(impl, 0.5)
    best = {impl: float("inf") for impl in impls}
    for _ in range(REPS):
        for impl in impls:
            t0 = time.perf_counter()
            run(impl)
            best[impl] = min(best[impl], time.perf_counter() - t0)
    for impl in impls:
        emit(f"sim/{impl}/N={g.order}", best[impl] * 1e6,
             f"slots_per_s={slots / best[impl]:.1f};slots={slots}")
    emit(f"sim/speedup/N={g.order}", 0.0,
         f"speedup={best['reference'] / best['batched']:.2f}x")
    emit(f"sim/fused_vs_batched/N={g.order}", 0.0,
         f"ratio={best['batched'] / best['fused']:.2f}x")

    # whole load curve as one vmapped device program
    simulate_sweep(g, "uniform", loads, config=cfg)          # compile
    dt = _best(lambda: simulate_sweep(g, "uniform", loads, config=cfg))
    emit(f"sim/sweep{len(loads)}/N={g.order}", dt * 1e6,
         f"sweep_loadpoints_per_s={len(loads) / dt:.2f};"
         f"per_point_s={dt / len(loads):.2f}")


if __name__ == "__main__":
    main()
