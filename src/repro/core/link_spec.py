"""Heterogeneous-link specification: weighted latencies, sparse Z-pillars,
express channels.

The slot simulator (`core/simulation.py`) historically assumed every hop
costs exactly one slot over a fixed 2n-port torus/lattice neighbourhood.
Real 3D fabrics are not uniform: TSV-style Z-links run slower than
in-plane links, vertical connectivity may exist only at sparse *pillar*
coordinates, and *express* channels spanning several hops of one
dimension are the standard latency fix (see ROADMAP "Heterogeneous
links" and the NoC-3D exemplars in SNIPPETS.md).  `LinkSpec` is the
declarative description of all three axes:

  * ``dim_weights`` — per-dimension integer slot cost ``w >= 1`` of one
    hop.  A packet crossing a weight-w channel holds it for w slots and
    only becomes eligible downstream after those w slots have elapsed.
  * ``pillar_dim``/``pillar_every`` — Z-connectivity restricted to
    pillar nodes: node u keeps its ``pillar_dim`` links iff every OTHER
    label coordinate is ``0 (mod pillar_every)``.  Compiles to a static
    (N, 2n) structural mask AND-ed into the scenario/schedule ``link_ok``
    masks (so the dead-channel audit covers missing pillars for free).
  * ``express`` — extra long links: each ``(dim, span, weight)`` entry
    appends a +/- port pair connecting u to u ± span·e_dim with its own
    slot cost.  Express ports extend the port axis to P = 2n + 2·X and
    participate in greedy weighted-DOR routing (largest usable span
    first), so the minimal-record invariant is preserved: a span-s hop
    is only taken when the remaining offset in that dimension is >= s.

A default-constructed spec (``LinkSpec()``) is *trivial* — every
consumer treats it exactly like ``None`` and compiles the identical
pre-heterogeneous program (the bitwise weight-1 contract pinned by
``tests/test_hetero_links.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class LinkSpec:
    """Frozen, hashable description of a heterogeneous link overlay.

    All fields default to the trivial (uniform weight-1, full
    connectivity, no overlay) spec.  Dimension indices are validated
    lazily against the graph (``validate(n)``) because the spec is
    constructed before a lattice is bound.
    """

    dim_weights: tuple[int, ...] = ()
    pillar_dim: int | None = None
    pillar_every: int = 1
    express: tuple[tuple[int, int, int], ...] = field(default_factory=tuple)

    def __post_init__(self):
        object.__setattr__(self, "dim_weights",
                           tuple(int(w) for w in self.dim_weights))
        object.__setattr__(self, "express",
                           tuple((int(d), int(s), int(w))
                                 for d, s, w in self.express))
        if any(w < 1 for w in self.dim_weights):
            raise ValueError("dim_weights must all be >= 1, got "
                             f"{self.dim_weights}")
        if self.pillar_every < 1:
            raise ValueError("pillar_every must be >= 1")
        if self.pillar_dim is not None and self.pillar_dim < 0:
            raise ValueError("pillar_dim must be a dimension index >= 0")
        seen = set()
        for d, s, w in self.express:
            if s < 2:
                raise ValueError(
                    f"express span must be >= 2 (got {s}); a span-1 "
                    "express link duplicates the base channel — use "
                    "dim_weights instead")
            if w < 1:
                raise ValueError(f"express weight must be >= 1, got {w}")
            if d < 0:
                raise ValueError("express dim must be >= 0")
            if (d, s) in seen:
                raise ValueError(
                    f"duplicate express entry for (dim={d}, span={s})")
            seen.add((d, s))
        if self.express and self.has_pillar:
            raise ValueError(
                "express overlays and pillar masks cannot be combined "
                "in one LinkSpec (express channels require the full "
                "base connectivity to fall back on)")

    # -- classification -----------------------------------------------------

    @property
    def has_pillar(self) -> bool:
        """True when the spec removes any links (pillar_every >= 2)."""
        return self.pillar_dim is not None and self.pillar_every > 1

    @property
    def weighted(self) -> bool:
        """True when any channel costs more than one slot."""
        return any(w > 1 for w in self.dim_weights) or \
            any(w > 1 for _, _, w in self.express)

    @property
    def is_trivial(self) -> bool:
        """True when the spec changes nothing: every consumer must then
        compile the exact same program as ``links=None``."""
        return (not self.weighted and not self.has_pillar
                and not self.express)

    def validate(self, n: int) -> None:
        """Check dimension indices against an n-dimensional lattice."""
        if self.dim_weights and len(self.dim_weights) != n:
            raise ValueError(
                f"dim_weights has {len(self.dim_weights)} entries for an "
                f"n={n} lattice")
        if self.pillar_dim is not None and self.pillar_dim >= n:
            raise ValueError(f"pillar_dim {self.pillar_dim} out of range "
                             f"for n={n}")
        for d, s, w in self.express:
            if d >= n:
                raise ValueError(f"express dim {d} out of range for n={n}")

    def fingerprint(self):
        """Hashable identity for compile caches (None-like when trivial)."""
        if self.is_trivial:
            return None
        return (self.dim_weights, self.pillar_dim, self.pillar_every,
                self.express)

    # -- port geometry ------------------------------------------------------
    # Port layout: base ports 2d (+e_d) and 2d+1 (-e_d) for d < n, then
    # one +/- pair per express entry: port 2n+2j = +span_j·e_{dim_j},
    # port 2n+2j+1 its opposite.  This keeps both structural invariants
    # the whole simulator relies on: opp(p) == p ^ 1, and
    # nbr[nbr[u, p], p ^ 1] == u.

    def num_ports(self, n: int) -> int:
        return 2 * n + 2 * len(self.express)

    def port_dims(self, n: int) -> np.ndarray:
        """(P,) dimension index of each port."""
        base = np.repeat(np.arange(n), 2)
        ext = np.repeat([d for d, _, _ in self.express], 2).astype(np.int64)
        return np.concatenate([base, ext]).astype(np.int32)

    def port_signs(self, n: int) -> np.ndarray:
        """(P,) +1 for even (forward) ports, -1 for odd ones."""
        P = self.num_ports(n)
        return np.where(np.arange(P) % 2 == 0, 1, -1).astype(np.int32)

    def port_spans(self, n: int) -> np.ndarray:
        """(P,) hop span of each port (1 for base, span for express)."""
        base = np.ones(2 * n, dtype=np.int32)
        ext = np.repeat([s for _, s, _ in self.express], 2).astype(np.int32)
        return np.concatenate([base, ext]).astype(np.int32)

    def port_weights(self, n: int) -> np.ndarray:
        """(P,) slot cost of crossing each port's channel."""
        dw = self.dim_weights if self.dim_weights else (1,) * n
        base = np.repeat(np.asarray(dw, dtype=np.int32), 2)
        ext = np.repeat([w for _, _, w in self.express], 2).astype(np.int32)
        return np.concatenate([base, ext]).astype(np.int32)

    def hop_table(self, n: int) -> np.ndarray:
        """(P, n) signed label displacement of each port."""
        P = self.num_ports(n)
        hop = np.zeros((P, n), dtype=np.int32)
        hop[np.arange(P), self.port_dims(n)] = \
            self.port_signs(n) * self.port_spans(n)
        return hop

    # -- graph binding ------------------------------------------------------

    def extended_neighbors(self, g) -> np.ndarray:
        """(N, P) neighbour table: base columns are ``g.neighbor_indices``,
        express columns resolved through ``g.label_to_index`` so overlay
        links respect the lattice quotient exactly like base links."""
        self.validate(g.n)
        nbr = np.asarray(g.neighbor_indices, dtype=np.int32)
        if not self.express:
            return nbr
        labels = np.asarray(g.labels)
        cols = [nbr]
        for d, s, _ in self.express:
            step = np.zeros(g.n, dtype=labels.dtype)
            step[d] = s
            fwd = np.asarray(g.label_to_index(labels + step), dtype=np.int32)
            bwd = np.asarray(g.label_to_index(labels - step), dtype=np.int32)
            if (fwd == np.arange(g.order)).any():
                raise ValueError(
                    f"express (dim={d}, span={s}) folds onto a self-loop "
                    "on this lattice — span matches the cycle length")
            cols.append(np.stack([fwd, bwd], axis=1))
        return np.concatenate(cols, axis=1).astype(np.int32)

    def structural_mask(self, g) -> np.ndarray | None:
        """(N, 2n) bool pillar mask, or None when every link exists.

        Node u is a *pillar* iff all label coordinates OTHER than
        ``pillar_dim`` are 0 mod ``pillar_every``; only pillars keep
        their ``pillar_dim`` channels.  The mask is automatically
        symmetric: u and its dim-d neighbour share every non-d
        coordinate, so they are pillars together.
        """
        if not self.has_pillar:
            return None
        self.validate(g.n)
        labels = np.asarray(g.labels)
        other = np.arange(g.n) != self.pillar_dim
        is_pillar = (labels[:, other] % self.pillar_every == 0).all(axis=1)
        mask = np.ones((g.order, 2 * g.n), dtype=bool)
        mask[:, 2 * self.pillar_dim] = is_pillar
        mask[:, 2 * self.pillar_dim + 1] = is_pillar
        return mask
