"""Sharding rules: DP / FSDP / TP / EP / SP partition specs for params and
activations, plus a context-scoped `constrain()` used inside model code.

Logical axes:
  dp    — batch data parallelism = ("pod", "data") on the multi-pod mesh
  fsdp  — parameter/optimizer sharding (ZeRO-3) = "data" (intra-pod only, so
          cross-pod traffic stays pure gradient all-reduce)
  tp    — tensor/expert parallel = "model"

Rules adapt per architecture: a tensor dimension is only sharded when it is
divisible by the axis size (e.g. 8 KV heads on a 16-way model axis stay
replicated, Megatron-style; a 51865-entry vocab stays unsharded).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _rules() -> dict[str, P] | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def activation_rules(rules: dict[str, P] | None, mesh=None,
                     fsdp_axis: str | None = None):
    """Scope activation-sharding rules used by `constrain` inside models.
    When a mesh is supplied, model code may also use explicit shard_map
    regions (expert-parallel MoE dispatch)."""
    prev = getattr(_state, "rules", None)
    prev_mesh = getattr(_state, "mesh", None)
    prev_fsdp = getattr(_state, "fsdp_axis", None)
    _state.rules = rules
    _state.mesh = mesh
    _state.fsdp_axis = fsdp_axis
    try:
        yield
    finally:
        _state.rules = prev
        _state.mesh = prev_mesh
        _state.fsdp_axis = prev_fsdp


def current_mesh():
    return getattr(_state, "mesh", None)


def current_fsdp_axis() -> str | None:
    return getattr(_state, "fsdp_axis", None)


def current_rules() -> dict[str, P] | None:
    return _rules()


def constrain(x, name: str):
    """Apply `with_sharding_constraint` if a rule for `name` is in scope."""
    rules = _rules()
    if rules is None or name not in rules:
        return x
    return jax.lax.with_sharding_constraint(x, rules[name])


# ---------------------------------------------------------------------------
# axis helpers
# ---------------------------------------------------------------------------

def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, name) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


class Axes:
    """Resolved per-(config, mesh) axis assignment."""

    def __init__(self, cfg, mesh, fsdp: bool = True):
        self.cfg = cfg
        self.mesh = mesh
        self.dp = dp_axes(mesh)
        self.dp_size = 1
        for a in self.dp:
            self.dp_size *= axis_size(mesh, a)
        self.tp = axis_size(mesh, "model")
        self.fsdp_axis = "data" if (fsdp and "data" in mesh.axis_names) else None
        self.fsdp_size = axis_size(mesh, "data") if self.fsdp_axis else 1

    def tp_dim(self, dim: int) -> str | None:
        return "model" if _div(dim, self.tp) else None

    def fsdp_dim(self, dim: int) -> str | None:
        if self.fsdp_axis and _div(dim, self.fsdp_size):
            return self.fsdp_axis
        return None

    def batch_dim(self, global_batch: int):
        """Shard batch over dp axes only when divisible."""
        if global_batch % self.dp_size == 0:
            return self.dp
        if "data" in self.dp and global_batch % axis_size(self.mesh, "data") == 0:
            return ("data",)
        return None


# ---------------------------------------------------------------------------
# activation rules per (config, mesh, shape-kind)
# ---------------------------------------------------------------------------

def make_activation_rules(cfg, mesh, kind: str, global_batch: int,
                          fsdp: bool = True, seq_shard: bool = False) -> dict[str, P]:
    ax = Axes(cfg, mesh, fsdp)
    b = ax.batch_dim(global_batch)
    rules: dict[str, P] = {}
    rules["tokens"] = P(b)
    rules["hidden"] = P(b, "model" if seq_shard else None, None)
    rules["attn_heads"] = P(b, None, ax.tp_dim(cfg.num_heads), None)
    rules["kv_heads"] = P(b, None, ax.tp_dim(cfg.num_kv_heads), None)
    rules["ffn_hidden"] = P(b, None, ax.tp_dim(cfg.d_ff))
    rules["logits"] = P(b, None, ax.tp_dim(cfg.vocab_size))
    if cfg.moe is not None:
        ep = ax.tp_dim(cfg.moe.num_experts)
        rules["expert_tokens"] = P(ep, None, None)          # (E, C, D)
    if cfg.ssm is not None:
        from repro.models.ssm import dims as ssm_dims
        _, H, _ = ssm_dims(cfg)
        sh = ax.tp_dim(H)
        rules["ssm_heads"] = P(b, None, sh, None)           # (B, S, H, P)
        rules["ssm_state"] = P(b, sh, None, None)           # (B, H, P, N)
    return rules


# ---------------------------------------------------------------------------
# parameter specs (FSDP over "data" + TP over "model")
# ---------------------------------------------------------------------------

def _attn_specs(cfg, ax: Axes, stacked: bool):
    lead = (None,) if stacked else ()
    hd = cfg.resolved_head_dim
    q_sh = ax.tp_dim(cfg.num_heads * hd) if ax.tp_dim(cfg.num_heads) else None
    kv_sh = ax.tp_dim(cfg.num_kv_heads * hd) if ax.tp_dim(cfg.num_kv_heads) else None
    d_sh = ax.fsdp_dim(cfg.d_model)
    from repro.models.attention import AttnParams
    return AttnParams(
        wq=P(*lead, d_sh, q_sh),
        wk=P(*lead, d_sh, kv_sh),
        wv=P(*lead, d_sh, kv_sh),
        wo=P(*lead, q_sh, d_sh),
        q_norm=P(*lead, None),
        k_norm=P(*lead, None))


def _mlp_specs(cfg, ax: Axes, stacked: bool, d_ff: int | None = None):
    lead = (None,) if stacked else ()
    f = d_ff if d_ff is not None else cfg.d_ff
    f_sh = ax.tp_dim(f)
    d_sh = ax.fsdp_dim(cfg.d_model)
    from repro.models.mlp import MLPParams
    return MLPParams(
        w_gate=P(*lead, d_sh, f_sh),
        w_up=P(*lead, d_sh, f_sh),
        w_down=P(*lead, f_sh, d_sh))


def _moe_specs(cfg, ax: Axes, stacked: bool):
    lead = (None,) if stacked else ()
    mc = cfg.moe
    ep = ax.tp_dim(mc.num_experts)
    d_sh = ax.fsdp_dim(cfg.d_model)
    from repro.models.mlp import MoEParams
    shared = None
    if mc.num_shared_experts:
        fe = (mc.expert_d_ff or cfg.d_ff) * mc.num_shared_experts
        shared = _mlp_specs(cfg, ax, stacked, d_ff=fe)
    return MoEParams(
        router=P(*lead, d_sh, None),
        w_gate=P(*lead, ep, d_sh, None),
        w_up=P(*lead, ep, d_sh, None),
        w_down=P(*lead, ep, None, d_sh),
        shared=shared)


def _mamba_specs(cfg, ax: Axes, stacked: bool):
    lead = (None,) if stacked else ()
    from repro.models.ssm import MambaParams, dims as ssm_dims
    d_inner, H, conv_ch = ssm_dims(cfg)
    d_sh = ax.fsdp_dim(cfg.d_model)
    return MambaParams(
        in_proj=P(*lead, d_sh, None),
        conv_w=P(*lead, None, None),
        conv_b=P(*lead, None),
        A_log=P(*lead, None),
        D_skip=P(*lead, None),
        dt_bias=P(*lead, None),
        out_norm=P(*lead, None),
        out_proj=P(*lead, ax.tp_dim(d_inner), d_sh))


def make_param_specs(cfg, mesh, fsdp: bool = True) -> Any:
    """Pytree of PartitionSpec mirroring `init_params(cfg)` exactly."""
    ax = Axes(cfg, mesh, fsdp)
    vocab_sh = ax.tp_dim(cfg.vocab_size)
    d_sh = ax.fsdp_dim(cfg.d_model)
    specs: dict[str, Any] = {
        "embed": P(vocab_sh, d_sh),
        "final_norm": P(None),
    }
    if cfg.is_encdec:
        enc_layer = {
            "attn": _attn_specs(cfg, ax, stacked=True),
            "ffn": _mlp_specs(cfg, ax, stacked=True),
            "norm1": P(None, None),
            "norm2": P(None, None),
        }
        dec_layer = dict(enc_layer)
        dec_layer["cross"] = _attn_specs(cfg, ax, stacked=True)
        dec_layer["norm3"] = P(None, None)
        specs["encoder"] = {"layers": enc_layer, "final_norm": P(None)}
        specs["layers"] = dec_layer
    elif cfg.family in ("dense", "moe", "vlm"):
        layer: dict[str, Any] = {
            "attn": _attn_specs(cfg, ax, stacked=True),
            "norm1": P(None, None),
            "norm2": P(None, None),
        }
        layer["ffn"] = _moe_specs(cfg, ax, stacked=True) if cfg.moe is not None \
            else _mlp_specs(cfg, ax, stacked=True)
        specs["layers"] = layer
    elif cfg.family in ("ssm", "hybrid"):
        specs["layers"] = {
            "mamba": _mamba_specs(cfg, ax, stacked=True),
            "norm1": P(None, None),
        }
    else:
        raise ValueError(cfg.family)
    if cfg.family == "hybrid":
        specs["shared_attn"] = {
            "attn": _attn_specs(cfg, ax, stacked=False),
            "ffn": _mlp_specs(cfg, ax, stacked=False),
            "norm1": P(None),
            "norm2": P(None),
        }
    if cfg.family == "vlm":
        specs["vision_proj"] = P(d_sh, None)
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(d_sh, vocab_sh)
    return specs


def make_cache_specs(cfg, mesh, global_batch: int, seq_len: int = 0,
                     fsdp: bool = True) -> Any:
    """PartitionSpec tree mirroring `init_cache(cfg, batch, max_len)`.

    KV layout: shard kv-heads over the model axis when divisible; otherwise
    shard the *sequence* dimension (GQA archs with kv < tp, e.g. 8 kv heads
    on a 16-way axis) — this keeps both the cache memory and the decode
    attention FLOPs sharded, at the cost of softmax partial-reductions."""
    ax = Axes(cfg, mesh, fsdp)
    b = ax.batch_dim(global_batch)
    kv_sh = ax.tp_dim(cfg.num_kv_heads)
    seq_sh = None
    if kv_sh is None and seq_len and ax.tp_dim(seq_len):
        seq_sh = "model"
    kv = P(None, b, seq_sh, kv_sh, None)     # (L, B, S, KV, hd)
    if cfg.is_encdec:
        cross_seq = "model" if (kv_sh is None and
                                ax.tp_dim(cfg.encoder_seq_len)) else None
        cross = P(None, b, cross_seq, kv_sh, None)
        return {"k": kv, "v": kv, "cross_k": cross, "cross_v": cross}
    if cfg.family in ("dense", "moe", "vlm"):
        return {"k": kv, "v": kv}
    from repro.models.ssm import MambaCache, dims as ssm_dims
    _, H, _ = ssm_dims(cfg)
    sh = ax.tp_dim(H)
    mamba = MambaCache(
        conv=P(None, b, None, None),          # (L, B, k-1, conv_ch)
        state=P(None, b, sh, None, None))     # (L, B, H, P, N)
    if cfg.family == "ssm":
        return {"mamba": mamba}
    return {"mamba": mamba, "k": kv, "v": kv}


def make_input_specs_tree(cfg, mesh, shape, fsdp: bool = True) -> dict[str, P]:
    ax = Axes(cfg, mesh, fsdp)
    b = ax.batch_dim(shape.global_batch)
    out = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.family == "vlm":
        out["patch_embeds"] = P(b, None, None)
    if cfg.is_encdec:
        out["enc_frames"] = P(b, None, None)
    return out


def named_tree(mesh, spec_tree):
    """PartitionSpec pytree → NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
