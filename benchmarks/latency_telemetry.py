"""Overhead of the in-carry latency histogram (ISSUE 6 acceptance bench).

Times the SAME full batched run with and without `hist_bins=64`,
interleaved best-of-`REPS` (machine noise hits both arms), and records

  * `hist_slots_per_s`  — absolute throughput of the histogram run
    (suffix-gated like every other slots/s row), and
  * `overhead_ratio`    — plain_time / hist_time (≥ 0.9 means the
    histogram costs < 10 %, the ISSUE 6 acceptance bound; gated so the
    telemetry can never silently become expensive).

A second row times the percentile-vs-load curve (`simulate_sweep` with
hist_bins over L load points — one compiled device program) the
tail-latency figures are drawn from.
"""
from __future__ import annotations

import time

from repro.core import SimConfig, Torus
from repro.core.simulation import build_tables, simulate, simulate_sweep

from .util import emit

REPS = 3
BINS = 64


def _best(f, reps=REPS) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        f()
        best = min(best, time.perf_counter() - t0)
    return best


def main(quick: bool = False) -> None:
    g = Torus(8, 8, 4, 2) if quick else Torus(8, 8, 8, 8)
    slots = 192 if quick else 512
    warmup = 48 if quick else 128
    loads = (0.3, 0.6, 1.0) if quick else (0.2, 0.4, 0.6, 0.8, 1.0)
    t = build_tables(g)
    cfg = SimConfig(slots=slots, warmup=warmup, seed=1, tables=t)

    def run(bins):
        return simulate(g, "uniform", 0.6,
                        config=cfg.replace(hist_bins=bins))

    arms = (0, BINS)
    for bins in arms:                               # compile both first
        run(bins)
    best = {bins: float("inf") for bins in arms}
    for _ in range(REPS):
        for bins in arms:
            t0 = time.perf_counter()
            run(bins)
            best[bins] = min(best[bins], time.perf_counter() - t0)
    emit(f"latency/hist/N={g.order}", best[BINS] * 1e6,
         f"hist_slots_per_s={slots / best[BINS]:.1f};"
         f"overhead_ratio={best[0] / best[BINS]:.3f};bins={BINS}")

    # percentile-vs-load curve: L load points, one compile, histograms on
    hcfg = cfg.replace(hist_bins=BINS)
    simulate_sweep(g, "uniform", loads, config=hcfg)         # compile
    dt = _best(lambda: simulate_sweep(g, "uniform", loads, config=hcfg))
    emit(f"latency/p99curve{len(loads)}/N={g.order}", dt * 1e6,
         f"p99curve_loadpoints_per_s={len(loads) / dt:.2f};"
         f"bins={BINS}")


if __name__ == "__main__":
    main()
