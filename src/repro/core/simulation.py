"""Cycle-level interconnection-network simulator (paper §6.2), JAX-vectorised.

Reproduces the INSEE experiments comparing 4D-FCC(8) vs T(16,8,8,8) and
4D-BCC(4) vs T(8,8,8,4) under uniform / antipodal / central-symmetric /
random-pairings traffic.

Router model (simplifications vs INSEE noted in DESIGN.md §10):
  * packet = 16 phits; a link moves one packet per 16-cycle slot
    (virtual cut-through at packet granularity),
  * per-input-port queues of `queue` packets (paper Table 3: 4),
  * DOR over minimal routing records (Algorithms 1–4) with random
    tie-breaking between the two equal-norm records r and −route(−v)
    (Remark 30),
  * bubble flow control: entering a dimension ring (injection or turn)
    requires 2 free slots in the target queue, continuing in-dimension
    requires 1 — the paper's deadlock-avoidance rule,
  * random arbitration per output link; in-transit traffic beats injection
    (the BlueGene congestion-control behaviour noted in §6.2).

Throughput is reported in phits/cycle/node = packets/slot/node.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .lattice import LatticeGraph
from .routing import make_router
from .routing_engine import canonical_reduce

PACKET_PHITS = 16


# ---------------------------------------------------------------------------
# static tables
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SimTables:
    n: int
    N: int
    neighbors: np.ndarray        # (N, 2n) — col 2i: +e_i, 2i+1: −e_i
    records_a: np.ndarray        # (N, n) minimal record per delta index
    records_b: np.ndarray        # (N, n) alternate minimal record (= −route(−v))
    labels: np.ndarray           # (N, n)
    hermite: np.ndarray          # (n, n)
    strides: np.ndarray          # (n,)


def build_tables(g: LatticeGraph, seed: int = 0,
                 backend: str = "auto") -> SimTables:
    """All-pairs record tables via the batched routing engine (the numpy
    oracle remains available with backend='numpy')."""
    router = make_router(g.matrix, backend)
    labels = g.labels
    rec_a = np.asarray(router(labels))
    # −route(−v) is also minimal for v and picks the *other* option on every
    # direction tie (half-ring hops, twin cycle intersections) — per-packet
    # coin between the two implements Remark 30's randomized tie-breaking.
    rec_b = -router(-labels)
    return SimTables(
        n=g.n, N=g.order, neighbors=g.neighbor_indices.astype(np.int32),
        records_a=rec_a.astype(np.int32), records_b=rec_b.astype(np.int32),
        labels=labels.astype(np.int32),
        hermite=g.hermite.astype(np.int32),
        strides=g.strides.astype(np.int32))


def _delta_idx(labels_src, labels_dst, hermite, strides):
    """Vectorised canonical reduction of (dst − src) into a node index."""
    v = canonical_reduce(labels_dst - labels_src, hermite)
    return (v * strides).sum(axis=-1)


# ---------------------------------------------------------------------------
# traffic patterns
# ---------------------------------------------------------------------------

def pattern_table(g: LatticeGraph, pattern: str, seed: int = 0) -> np.ndarray | None:
    """Fixed destination table (N,) for deterministic patterns; None for
    uniform (destination sampled per packet)."""
    N = g.order
    if pattern == "uniform":
        return None
    if pattern == "antipodal":
        d = g.distances_from_origin
        far = g.labels[int(np.argmax(d))]
        dst = g.label_to_index(g.labels + far)
        return dst.astype(np.int32)
    if pattern == "centralsymmetric":
        dst = g.label_to_index(-g.labels)
        return dst.astype(np.int32)
    if pattern == "randompairings":
        rng = np.random.default_rng(seed)
        perm = rng.permutation(N)
        dst = np.empty(N, dtype=np.int32)
        dst[perm[0::2]] = perm[1::2]
        dst[perm[1::2]] = perm[0::2]
        return dst
    raise ValueError(pattern)


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SimResult:
    accepted_load: float      # phits / cycle / node
    avg_latency_cycles: float
    delivered: int
    injected: int
    slots: int


_RUNNER_CACHE: dict = {}


def simulate(g: LatticeGraph, pattern: str, load: float, *,
             slots: int = 512, warmup: int = 128, queue: int = 4,
             seed: int = 0, tables: SimTables | None = None) -> SimResult:
    """Run `slots` packet-slots (16 cycles each) at offered load `load`
    (phits/cycle/node) and measure accepted throughput + latency."""
    t = tables or build_tables(g, seed)
    n, N = t.n, t.N
    P = 2 * n
    Q = queue

    nbr = jnp.asarray(t.neighbors)
    rec_a = jnp.asarray(t.records_a)
    rec_b = jnp.asarray(t.records_b)
    labels = jnp.asarray(t.labels)
    hermite = jnp.asarray(t.hermite)
    strides = jnp.asarray(t.strides)
    dst_np = pattern_table(g, pattern, seed)
    fixed_dst = dst_np is not None
    dst_table = jnp.asarray(dst_np if fixed_dst else np.zeros(N, np.int32))
    opp = [p ^ 1 for p in range(P)]

    def next_port(rec):
        """DOR: first nonzero dimension of the record → output port."""
        nz = jnp.abs(rec) > 0
        dim = jnp.argmax(nz, axis=-1)
        sgn = jnp.take_along_axis(rec, dim[..., None], -1)[..., 0]
        return 2 * dim + (sgn < 0), dim, sgn

    def slot_step(state, key):
        dst, rec, birth = state["dst"], state["rec"], state["birth"]
        slot = state["slot"]
        occ = dst >= 0                                     # (N, P, Q)
        port, dim, sgn = next_port(rec)                    # (N, P, Q)
        port = jnp.where(occ, port, -1)

        # ---- arbitration: one winner packet per (node, out-port) ----
        rand = jax.random.uniform(jax.random.fold_in(key, 1), (N, P, Q))
        flatscore = jnp.where(port[..., None] == jnp.arange(P), rand[..., None], -1.0)
        flat = flatscore.reshape(N, P * Q, P)
        widx = jnp.argmax(flat, axis=1)                    # (N, P) flat pq index
        whas = jnp.take_along_axis(flat, widx[:, None, :], axis=1)[:, 0, :] >= 0.0

        def pick(arr):
            """Gather winner-packet fields per (node, out-port)."""
            flat_arr = arr.reshape(N, P * Q, *arr.shape[3:])
            idx = widx
            if arr.ndim > 3:
                idx = widx[..., None]
            take = jnp.take_along_axis(
                flat_arr, idx[:, :, None] if arr.ndim == 3 else idx[:, :, None, :] if False else idx[:, :, None], axis=1)
            return take

        # simpler explicit gathers
        flat_dst = dst.reshape(N, P * Q)
        flat_rec = rec.reshape(N, P * Q, n)
        flat_birth = birth.reshape(N, P * Q)
        rows = jnp.arange(N)[:, None]
        w_dst = flat_dst[rows, widx]                       # (N, P)
        w_rec = flat_rec[rows, widx]                       # (N, P, n)
        w_birth = flat_birth[rows, widx]
        w_dim = widx  # placeholder; recompute below
        w_port_dim = (jnp.arange(P) // 2)[None, :].repeat(N, 0)

        # the queue (= dimension ring) each winner currently occupies
        w_src_port = widx // Q                             # (N, P)

        # ---- per-link acceptance (each in-queue receives ≤ 1 packet) ----
        delivered = jnp.int32(0)
        lat_sum = jnp.int32(0)
        new_dst, new_rec, new_birth = dst, rec, birth
        for p in range(P):
            d_p = p // 2
            s_p = 1 - 2 * (p % 2)                          # +1 / −1
            u = nbr[:, opp[p]]                             # sender for recv w
            has = whas[u, p]
            pk_dst = w_dst[u, p]
            pk_rec = w_rec[u, p]
            pk_birth = w_birth[u, p]
            pk_src_port = w_src_port[u, p]
            rec_after = pk_rec.at[:, d_p].add(-s_p)
            done = jnp.abs(rec_after).sum(-1) == 0
            will_deliver = has & done
            turning = pk_src_port != p                     # entering this ring
            freeq = (new_dst[:, p] < 0).sum(axis=1)
            ok = has & ~done & (freeq >= jnp.where(turning, 2, 1))
            moved = will_deliver | ok
            # stats
            delivered += will_deliver.sum()
            lat_sum += jnp.where(will_deliver, slot + 1 - pk_birth, 0).sum()
            # clear winner slot at sender
            clr = jnp.where(moved, -1, flat_dst[jnp.arange(N), widx[:, p]])
            sel = widx[:, p]
            fd = new_dst.reshape(N, P * Q)
            fd = fd.at[u, sel[u]].set(jnp.where(moved, -1, fd[u, sel[u]]))
            new_dst = fd.reshape(N, P, Q)
            # write into receiver queue p (first free slot)
            slot_idx = jnp.argmax(new_dst[:, p] < 0, axis=1)
            r_ = jnp.arange(N)
            new_dst = new_dst.at[r_, p, slot_idx].set(
                jnp.where(ok, pk_dst, new_dst[r_, p, slot_idx]))
            new_rec = new_rec.at[r_, p, slot_idx].set(
                jnp.where(ok[:, None], rec_after, new_rec[r_, p, slot_idx]))
            new_birth = new_birth.at[r_, p, slot_idx].set(
                jnp.where(ok, pk_birth, new_birth[r_, p, slot_idx]))

        # ---- injection (after transit: in-flight traffic has priority) ----
        k1, k2, k3 = jax.random.split(jax.random.fold_in(key, 2), 3)
        want_new = jax.random.uniform(k1, (N,)) < state["load"]
        want = want_new | (state["backlog"] > 0)
        if fixed_dst:
            d = state["dst_table"]
        else:
            d = jax.random.randint(k2, (N,), 0, N - 1)
            d = jnp.where(d >= jnp.arange(N), d + 1, d)
        di = _delta_idx(labels[jnp.arange(N)], labels[d], hermite, strides)
        coin = jax.random.uniform(k3, (N,)) < 0.5
        r = jnp.where(coin[:, None], rec_a[di], rec_b[di])
        inj_port, _, _ = next_port(r[:, None, :])
        inj_port = inj_port[:, 0]
        freeq = jnp.take_along_axis(
            (new_dst < 0).sum(axis=2), inj_port[:, None], axis=1)[:, 0]
        can = want & (freeq >= 2) & (jnp.abs(r).sum(-1) > 0)
        r_ = jnp.arange(N)
        slot_idx = jnp.argmax(new_dst[r_, inj_port] < 0, axis=1)
        new_dst = new_dst.at[r_, inj_port, slot_idx].set(
            jnp.where(can, d, new_dst[r_, inj_port, slot_idx]))
        new_rec = new_rec.at[r_, inj_port, slot_idx].set(
            jnp.where(can[:, None], r, new_rec[r_, inj_port, slot_idx]))
        new_birth = new_birth.at[r_, inj_port, slot_idx].set(
            jnp.where(can, slot, new_birth[r_, inj_port, slot_idx]))
        backlog = jnp.clip(state["backlog"] + want_new - can, 0, 1 << 30)

        counted = slot >= warmup
        new_state = dict(
            state, dst=new_dst, rec=new_rec, birth=new_birth,
            backlog=backlog, slot=slot + 1,
            delivered=state["delivered"] + jnp.where(counted, delivered, 0),
            lat_sum=state["lat_sum"] + jnp.where(counted, lat_sum, 0),
            injected=state["injected"] + jnp.where(counted, can.sum(), 0))
        return new_state, None

    state = dict(
        load=jnp.float32(load),
        dst_table=dst_table,
        dst=jnp.full((N, P, Q), -1, dtype=jnp.int32),
        rec=jnp.zeros((N, P, Q, n), dtype=jnp.int32),
        birth=jnp.zeros((N, P, Q), dtype=jnp.int32),
        backlog=jnp.zeros((N,), dtype=jnp.int32),
        slot=jnp.int32(0),
        delivered=jnp.int32(0),
        lat_sum=jnp.int32(0),
        injected=jnp.int32(0))

    cache_key = (t.neighbors.tobytes(), fixed_dst, slots, warmup, Q)
    if cache_key not in _RUNNER_CACHE:
        _RUNNER_CACHE[cache_key] = jax.jit(
            lambda st, ks: jax.lax.scan(slot_step, st, ks)[0])
    keys = jax.random.split(jax.random.PRNGKey(seed + 17), slots)
    out = _RUNNER_CACHE[cache_key](state, keys)
    measured = slots - warmup
    delivered = int(out["delivered"])
    return SimResult(
        accepted_load=delivered / max(measured * N, 1),
        avg_latency_cycles=PACKET_PHITS * float(out["lat_sum"]) / max(delivered, 1),
        delivered=delivered,
        injected=int(out["injected"]),
        slots=slots)


def throughput_curve(g: LatticeGraph, pattern: str, loads, **kw):
    """Accepted-vs-offered load curve (one build of the static tables)."""
    t = kw.pop("tables", None) or build_tables(g, kw.pop("seed", 0))
    return [simulate(g, pattern, float(l), tables=t, **kw) for l in loads]


def peak_throughput(g: LatticeGraph, pattern: str, loads=None, **kw):
    """Max accepted load over an offered-load sweep (the paper's
    'throughput peak')."""
    loads = loads if loads is not None else np.linspace(0.1, 1.0, 10)
    res = throughput_curve(g, pattern, loads, **kw)
    best = max(res, key=lambda r: r.accepted_load)
    return best, res
