"""Version compatibility for the shard_map / mesh API surface.

This container's jax (0.4.37) predates three pieces of API this repo (and
its tests) use, the same flavour of skew `kernels/_compat.py` fixed for
`pltpu.CompilerParams`:

  * ``jax.shard_map`` — still lives at ``jax.experimental.shard_map`` and
    spells the replication-check kwarg ``check_rep`` instead of
    ``check_vma``,
  * ``jax.sharding.AxisType`` — does not exist yet (all mesh axes behave
    as ``Auto``),
  * ``jax.make_mesh(..., axis_types=...)`` — the kwarg does not exist yet,
  * ``Compiled.cost_analysis()`` — returns a one-element list of dicts
    instead of the modern plain dict.

`shard_map`, `AxisType` and `make_mesh` below resolve to the native
objects on new jax and to adapters on old jax.  `install()` additionally
publishes the adapters at their modern locations (``jax.shard_map``,
``jax.sharding.AxisType``, patched ``jax.make_mesh``) so code and tests
written against the modern surface run unchanged — mirroring how
`tests/_propcheck.py` stands in for `hypothesis`.  On a modern jax both
the names here and `install()` are no-ops that use the native API.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax
import jax.sharding
import jax.stages


class _AxisType(enum.Enum):
    """Stand-in for `jax.sharding.AxisType` (pre-explicit-sharding jax
    treats every mesh axis as what is now called Auto)."""
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _adapt_shard_map():
    native = getattr(jax, "shard_map", None)
    if native is not None:
        return native
    from jax.experimental.shard_map import shard_map as legacy

    @functools.wraps(legacy)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                  **kwargs):
        kwargs.setdefault("check_rep", check_vma)
        return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)

    return shard_map


def _adapt_make_mesh():
    native = jax.make_mesh
    if "axis_types" in inspect.signature(native).parameters:
        return native

    @functools.wraps(native)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
        for t in (axis_types or ()):
            if t not in (AxisType.Auto, None):
                raise NotImplementedError(
                    f"axis type {t} needs jax >= 0.5 (this jax treats all "
                    "mesh axes as Auto)")
        return native(axis_shapes, axis_names, **kwargs)

    return make_mesh


shard_map = _adapt_shard_map()
AxisType = getattr(jax.sharding, "AxisType", _AxisType)
make_mesh = _adapt_make_mesh()


def install() -> bool:
    """Publish the adapters at their modern jax locations when absent.
    Returns True when anything was patched (old jax), False on modern jax.
    Idempotent; never overwrites a native attribute."""
    patched = False
    if getattr(jax, "shard_map", None) is None:
        jax.shard_map = shard_map
        patched = True
    if getattr(jax.sharding, "AxisType", None) is None:
        jax.sharding.AxisType = AxisType
        patched = True
    if jax.make_mesh is not make_mesh \
            and "axis_types" not in inspect.signature(
                jax.make_mesh).parameters:
        jax.make_mesh = make_mesh
        patched = True
    compiled = jax.stages.Compiled
    if not getattr(compiled.cost_analysis, "_repro_compat", False):
        legacy_ca = compiled.cost_analysis

        @functools.wraps(legacy_ca)
        def cost_analysis(self):
            out = legacy_ca(self)
            if isinstance(out, (list, tuple)):   # pre-0.5 per-device list
                return out[0] if out else {}
            return out

        cost_analysis._repro_compat = True
        compiled.cost_analysis = cost_analysis
        patched = True
    return patched
