"""Fill EXPERIMENTS.md placeholders from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.experiments_fill
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import REGISTRY, get_config, shapes_for, skipped_shapes_for

from .roofline import ARTIFACTS, analyze, markdown_table

ROOT = Path(__file__).resolve().parents[1]


def latest_artifact(arch: str, shape: str, mesh: str) -> dict | None:
    files = sorted(ARTIFACTS.glob(f"{arch}__{shape}__{mesh}__*.json"),
                   key=lambda f: f.stat().st_mtime)
    if not files:
        return None
    return json.loads(files[-1].read_text())


def dryrun_table() -> str:
    lines = [
        "| arch | shape | 16×16 (256 chips) | 2×16×16 (512 chips) | "
        "coll bytes/dev | mem GiB/chip (scan) |",
        "|---|---|---|---|---|---|",
    ]
    for arch, cfg in REGISTRY.items():
        for sh in shapes_for(cfg):
            single = latest_artifact(arch, sh.name, "pod16x16")
            multi = latest_artifact(arch, sh.name, "pod2x16x16")
            s_ok = "✓ compiled" if single else "—"
            m_ok = "✓ compiled" if multi else "—"
            coll = f"{single['collective']['total_bytes']:.2e}" if single else ""
            mem = ""
            if single:
                m = single["memory"]
                mem = f"{(m['argument_bytes'] + m['temp_bytes'] + m['output_bytes'] - m['alias_bytes'])/2**30:.1f}"
            lines.append(f"| {arch} | {sh.name} | {s_ok} | {m_ok} | {coll} | {mem} |")
        for sh, why in skipped_shapes_for(cfg):
            lines.append(f"| {arch} | {sh.name} | skip | skip | — ({why}) | |")
    return "\n".join(lines)


def main() -> None:
    md = (ROOT / "EXPERIMENTS.md").read_text()
    md = md.replace("<!-- ROOFLINE_TABLE -->",
                    markdown_table("pod16x16"))
    md = md.replace("<!-- DRYRUN_RESULTS -->", dryrun_table())
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
