"""Data pipeline: deterministic synthetic LM streams + sharded host loading.

At 1000-node scale each host feeds only its slice of the global batch; the
pipeline is seeded per (host, shard, step) so any host can recompute any
step's slice — that property is what makes checkpoint-restart and elastic
re-sharding exact (no data loss/duplication on restart) and is also the
straggler-mitigation hook (a reassigned shard is reproducible elsewhere).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLMStream:
    """Markov-ish synthetic token stream with a learnable structure (bigram
    transitions), so a ~100M-param model shows a real falling loss curve."""

    def __init__(self, cfg: DataConfig, num_shards: int = 1, shard: int = 0):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.num_shards = num_shards
        self.shard = shard
        self.batch_per_shard = cfg.global_batch // num_shards
        rng = np.random.default_rng(cfg.seed)
        # sparse bigram table: each token has 8 likely successors
        self.successors = rng.integers(
            0, cfg.vocab_size, size=(cfg.vocab_size, 8), dtype=np.int64)

    def _step_rng(self, step: int) -> np.random.Generator:
        h = hashlib.blake2s(
            f"{self.cfg.seed}/{self.shard}/{step}".encode(),
            digest_size=8).digest()
        return np.random.default_rng(int.from_bytes(h, "little"))

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Deterministic batch for (shard, step): tokens + next-token labels."""
        rng = self._step_rng(step)
        B, S, V = self.batch_per_shard, self.cfg.seq_len, self.cfg.vocab_size
        toks = np.empty((B, S + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, V, size=B)
        choice = rng.integers(0, 8, size=(B, S))
        noise = rng.random((B, S)) < 0.1
        random_tok = rng.integers(0, V, size=(B, S))
        for t in range(S):
            nxt = self.successors[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], random_tok[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def global_batch(self, step: int) -> dict[str, np.ndarray]:
        """Assemble the full global batch (for single-host runs/tests)."""
        shards = [SyntheticLMStream(self.cfg, self.num_shards, s).batch(step)
                  for s in range(self.num_shards)]
        return {k: np.concatenate([sh[k] for sh in shards], axis=0)
                for k in shards[0]}


def reassign_shards(num_shards: int, dead: set[int]) -> dict[int, list[int]]:
    """Straggler/failure mitigation: spread dead hosts' shards round-robin
    over the survivors.  Deterministic, so all hosts agree without
    coordination."""
    alive = [s for s in range(num_shards) if s not in dead]
    if not alive:
        raise RuntimeError("no survivors")
    plan: dict[int, list[int]] = {s: [s] for s in alive}
    for i, d in enumerate(sorted(dead)):
        plan[alive[i % len(alive)]].append(d)
    return plan
