"""Launch-layer unit tests: HLO collective parsing, abstract specs, meshes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config, get_shape, shapes_for
from repro.launch.hlo_analysis import (collective_bytes, collective_stats,
                                       _shape_bytes)
from repro.launch.specs import (abstract_cache, abstract_opt_state,
                                abstract_params, active_param_count,
                                input_specs, model_flops)


# ---------------------------------------------------------------------------
# HLO parsing
# ---------------------------------------------------------------------------

SAMPLE_HLO = """
  %p = f32[128,256]{1,0} parameter(0)
  %ag = f32[2048,256]{1,0} all-gather(f32[128,256]{1,0} %p), replica_groups={}
  %ar = bf16[64,64]{1,0} all-reduce(bf16[64,64]{1,0} %x), to_apply=%add
  %ars = (f32[32]{0}, f32[32]{0}) all-reduce-start(f32[32]{0} %y, f32[32]{0} %z)
  %ard = f32[32]{0} all-reduce-done(f32[32]{0} %ars)
  %rs = f32[16,8]{1,0} reduce-scatter(f32[256,8]{1,0} %w), dimensions={0}
  %a2a = s8[4,4]{1,0} all-to-all(s8[4,4]{1,0} %v), dimensions={0}
  %cp = u32[10]{0} collective-permute(u32[10]{0} %u), source_target_pairs={{0,1}}
"""


def test_shape_bytes():
    assert _shape_bytes("f32", "128,256") == 128 * 256 * 4
    assert _shape_bytes("bf16", "64,64") == 64 * 64 * 2
    assert _shape_bytes("s8", "4,4") == 16
    assert _shape_bytes("pred", "7") == 7
    assert _shape_bytes("unknown99", "4") == 0


def test_collective_stats_parses_ops_and_operands():
    st = collective_stats(SAMPLE_HLO)
    assert st.count_by_op["all-gather"] == 1
    assert st.count_by_op["all-reduce"] == 2          # plain + -start
    assert st.count_by_op["reduce-scatter"] == 1
    assert st.count_by_op["all-to-all"] == 1
    assert st.count_by_op["collective-permute"] == 1
    # all-gather counts its OPERAND bytes (128×256×4), not output
    assert st.bytes_by_op["all-gather"] == 128 * 256 * 4
    # reduce-scatter counts the big operand
    assert st.bytes_by_op["reduce-scatter"] == 256 * 8 * 4
    # -done lines are not double counted
    assert st.bytes_by_op["all-reduce"] == 64 * 64 * 2 + 2 * 32 * 4
    assert collective_bytes(SAMPLE_HLO) == st.total_bytes


def test_collective_stats_empty():
    assert collective_stats("%x = f32[2] add(f32[2] %a, f32[2] %b)").total_count == 0


# ---------------------------------------------------------------------------
# abstract specs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_abstract_params_no_allocation(arch):
    cfg = get_config(arch)
    tree = abstract_params(cfg)
    leaves = jax.tree.leaves(tree)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    n = sum(int(np.prod(l.shape)) for l in leaves)
    assert n > 0


def test_param_counts_match_billing_names():
    """Total params should be in the ballpark of each model's name."""
    import math
    expect = {
        "deepseek-moe-16b": (14e9, 20e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "phi3-mini-3.8b": (3.3e9, 4.5e9),
        "qwen3-4b": (3.5e9, 5.0e9),
        "olmo-1b": (1.0e9, 1.6e9),
        "command-r-plus-104b": (95e9, 115e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "mamba2-2.7b": (2.4e9, 3.2e9),
        "internvl2-2b": (1.6e9, 2.4e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        tree = abstract_params(cfg)
        n = sum(math.prod(l.shape) for l in jax.tree.leaves(tree))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_active_params_moe_much_smaller_than_total():
    import math
    cfg = get_config("deepseek-moe-16b")
    total = sum(math.prod(l.shape)
                for l in jax.tree.leaves(abstract_params(cfg)))
    active = active_param_count(cfg)
    assert active < total / 3
    assert 2e9 < active < 4e9          # ~2.8B active (paper)


def test_model_flops_positive_and_scaled():
    for arch in REGISTRY:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            mf = model_flops(cfg, shape)
            assert mf > 0, (arch, shape.name)
    t = model_flops(get_config("olmo-1b"), get_shape("train_4k"))
    p = model_flops(get_config("olmo-1b"), get_shape("prefill_32k"))
    assert t / p == pytest.approx(3.0, rel=1e-6)      # 6ND vs 2ND, same tokens


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for shape in shapes_for(cfg):
        spec = input_specs(cfg, shape)
        if shape.kind == "decode":
            assert spec["token"].shape == (shape.global_batch, 1)
            cache = abstract_cache(cfg, shape)
            assert jax.tree.leaves(cache), "cache must be non-empty"
        else:
            assert spec["tokens"].shape == (shape.global_batch, shape.seq_len)
        if cfg.family == "vlm" and shape.kind != "decode":
            assert "patch_embeds" in spec
        if cfg.is_encdec and shape.kind != "decode":
            assert "enc_frames" in spec


def test_cache_specs_match_cache_structure():
    from repro.launch.mesh import make_test_mesh
    # needs >1 devices? No: specs are pure PartitionSpec structures
    from repro.parallel.sharding import make_cache_specs
    import jax.sharding as js
    mesh = None
    for arch in ("qwen3-4b", "mamba2-2.7b", "zamba2-1.2b", "whisper-base"):
        cfg = get_config(arch)
        shape = get_shape("decode_32k")
        cache = abstract_cache(cfg, shape)

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        specs = make_cache_specs(cfg, FakeMesh(), shape.global_batch,
                                 seq_len=shape.seq_len)
        jax.tree.map(lambda a, b: None, cache, specs,
                     is_leaf=lambda x: isinstance(x, js.PartitionSpec))


def test_mesh_factories_are_lazy():
    """Importing mesh.py must not touch jax device state."""
    import importlib
    import repro.launch.mesh as m
    importlib.reload(m)
    assert callable(m.make_production_mesh)
