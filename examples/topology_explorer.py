"""Explore the lattice-topology layer: pod comparison, placement, upgrades,
and a small network simulation.

    PYTHONPATH=src python examples/topology_explorer.py
"""
import numpy as np

from repro.core import BCC, FCC, PC, Torus
from repro.core.simulation import simulate
from repro.topology.collective_model import PodOptions, analyze_pod
from repro.topology.placement import best_embedding
from repro.topology.upgrade import migration_stats, upgrade_plan, upgrade_path_names

print("== pod topologies (paper §3.4 at TPU scale) ==")
for name, g, ts in [("BCC(4)/256", BCC(4), None), ("T(8,8,4)", Torus(8, 8, 4), (8, 8, 4)),
                    ("FCC(8)/1024", FCC(8), None), ("T(16,8,8)", Torus(16, 8, 8), (16, 8, 8))]:
    r = analyze_pod(name, g, ts, options=PodOptions(measure_routed=True))
    print(f"  {r.name:12} D={r.diameter:<3} k̄={r.avg_distance:.2f} "
          f"capacity={r.uniform_capacity:.3f} (routed {r.routed_capacity:.3f}) "
          f"phits/cyc/node all-to-all(256MB)={r.alltoall_256MB_ms:.1f} ms")

print("\n== logical 16×16 mesh placement into BCC(4) ==")
be = best_embedding(BCC(4), (16, 16))
print(f"  best: {be['embedding'].name}  axis dilations "
      f"{be['axis0']['avg']:.2f} / {be['axis1']['avg']:.2f}")

print("\n== elastic upgrade path ==")
print("  " + " → ".join(upgrade_path_names(256, 3)))
for chips in (256, 512):
    print(f"  {chips}→{2*chips}:", migration_stats(upgrade_plan(chips)))

print("\n== packet simulation (small): BCC(3) vs T(6,6,3) uniform ==")
for name, g in [("BCC(3)", BCC(3)), ("T(6,6,3)", Torus(6, 6, 3))]:
    r = simulate(g, "uniform", 0.5, slots=256, warmup=64)
    print(f"  {name:9} accepted={r.accepted_load:.3f} phits/cyc/node "
          f"latency={r.avg_latency_cycles:.0f} cyc")
