# Entry points — no PYTHONPATH=src incantations needed (pytest picks up
# src/ via pyproject's pythonpath ini + tests/conftest.py; the benchmark
# driver gets it from this Makefile).
PY ?= python

.PHONY: test test-fast bench bench-quick

test:
	$(PY) -m pytest -q

# skip the slow distributed/simulation modules; covers the routing stack
test-fast:
	$(PY) -m pytest -q tests/test_intmat.py tests/test_lattice.py \
	    tests/test_crystals.py tests/test_routing.py \
	    tests/test_routing_engine.py tests/test_symmetry.py

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

bench-quick:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick

# routing engine throughput only (ISSUE 1 acceptance numbers)
bench-routing:
	PYTHONPATH=src $(PY) -m benchmarks.run --only routing
