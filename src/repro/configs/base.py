"""Config system: architecture + shape + parallelism descriptors.

Every assigned architecture gets a `ModelConfig` in its own module under
`repro.configs`; shapes are the four assigned input-shape cells.  Configs are
plain frozen dataclasses — a launcher builds everything from
(`ModelConfig`, `ShapeSpec`, `MeshSpec`).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    expert_d_ff: int = 0            # per-expert FFN width (fine-grained MoE)
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    state_size: int                 # N
    head_dim: int = 64              # P
    expand: int = 2                 # d_inner = expand * d_model
    conv_kernel: int = 4
    chunk_size: int = 256
    ngroups: int = 1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 → d_model // num_heads
    qk_norm: bool = False
    nonparametric_norm: bool = False   # OLMo-style LN without learned params
    parallel_block: bool = False       # Cohere-style attn ∥ FFN
    use_bias: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid (Zamba2): a single shared attention block reused every k layers
    hybrid_attn_period: int = 0
    # enc-dec (Whisper): encoder depth/length; frontend is a stub
    encoder_layers: int = 0
    encoder_seq_len: int = 0
    # VLM: number of prefix patch-embedding positions (stub frontend)
    num_patch_tokens: int = 0
    norm_eps: float = 1e-5

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic (constant-state) sequence mixing → long_500k runs."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw: dict = dict(
            num_layers=min(self.num_layers, 4 if self.hybrid_attn_period else 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads < self.num_heads else 4,
            d_ff=128,
            vocab_size=256,
            head_dim=16 if self.head_dim else 0,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2,
                expert_d_ff=64 if self.moe.expert_d_ff else 0)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_size=16, head_dim=16, chunk_size=32)
        if self.hybrid_attn_period:
            kw["hybrid_attn_period"] = 2
        if self.encoder_layers:
            kw["encoder_layers"] = 2
            kw["encoder_seq_len"] = 16
        if self.num_patch_tokens:
            kw["num_patch_tokens"] = 4
        return dataclasses.replace(self, name=self.name + "-smoke", **kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> tuple[ShapeSpec, ...]:
    """The shape cells an architecture actually runs.

    long_500k requires sub-quadratic sequence mixing (SSM/hybrid); pure
    full-attention archs skip it (see DESIGN.md §4)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return tuple(out)


def skipped_shapes_for(cfg: ModelConfig) -> tuple[tuple[ShapeSpec, str], ...]:
    if cfg.supports_long_context:
        return ()
    return ((LONG_500K, "full attention: 524k-token KV cache excluded by spec"),)
