"""Lattice graphs G(M) (paper Definition 3) with exact construction and
vectorised distance analysis.

A lattice graph is the Cayley graph of Z^n/MZ^n with generator set {±e_i}.
Nodes are labelled by the Hermite box {x : 0 ≤ x_i < H_ii} (Definition 26),
indexed in mixed radix so that index 0 is the origin.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import cached_property

import numpy as np

from . import intmat


@dataclass(frozen=True)
class LatticeGraph:
    """G(M): |det M| nodes, regular of degree 2n."""

    M: tuple[tuple[int, ...], ...]

    def __init__(self, M):
        A = intmat.as_np(M)
        object.__setattr__(self, "M", tuple(tuple(int(x) for x in row) for row in A))

    # -- basic invariants ---------------------------------------------------
    @cached_property
    def matrix(self) -> np.ndarray:
        return intmat.as_np(self.M)

    @cached_property
    def n(self) -> int:
        return self.matrix.shape[0]

    @cached_property
    def hermite(self) -> np.ndarray:
        return intmat.hermite_normal_form(self.matrix)

    @cached_property
    def order(self) -> int:
        return abs(intmat.det(self.matrix))

    @cached_property
    def degree(self) -> int:
        return 2 * self.n

    @cached_property
    def sides(self) -> np.ndarray:
        """Hermite diagonal: the mixed-radix sizes of the labelling box."""
        return np.diagonal(self.hermite).copy()

    # -- labelling ----------------------------------------------------------
    @cached_property
    def strides(self) -> np.ndarray:
        """Mixed-radix strides: index(v) = Σ v_i · stride_i."""
        s = np.ones(self.n, dtype=np.int64)
        sides = self.sides
        for i in range(self.n - 2, -1, -1):
            s[i] = s[i + 1] * sides[i + 1]
        return s

    @cached_property
    def labels(self) -> np.ndarray:
        """(N, n) array of all node labels in index order."""
        grids = np.meshgrid(*[np.arange(a) for a in self.sides], indexing="ij")
        return np.stack([g.ravel() for g in grids], axis=-1).astype(np.int64)

    def label_to_index(self, v) -> np.ndarray:
        """Map arbitrary integer vectors (..., n) to node indices."""
        lab = intmat.canonical_label(v, self.hermite)
        return (lab * self.strides).sum(axis=-1)

    # -- adjacency ----------------------------------------------------------
    @cached_property
    def neighbor_indices(self) -> np.ndarray:
        """(N, 2n) neighbour index table; column 2i is +e_{i+1}, 2i+1 is −e_{i+1}."""
        labs = self.labels
        cols = []
        eye = np.eye(self.n, dtype=np.int64)
        for i in range(self.n):
            cols.append(self.label_to_index(labs + eye[i]))
            cols.append(self.label_to_index(labs - eye[i]))
        return np.stack(cols, axis=-1)

    def edges(self) -> np.ndarray:
        """(E, 2) undirected edge list (u < v after dedup of parallel edges)."""
        N = self.order
        src = np.repeat(np.arange(N), 2 * self.n)
        dst = self.neighbor_indices.ravel()
        e = np.stack([np.minimum(src, dst), np.maximum(src, dst)], axis=-1)
        return np.unique(e, axis=0)

    # -- distances ----------------------------------------------------------
    @cached_property
    def distances_from_origin(self) -> np.ndarray:
        """Single-source BFS distances.  Because G(M) is vertex-transitive
        (Cayley), the distance profile from node 0 is the profile from any
        node; dist(u, v) = dist(0, v − u)."""
        N = self.order
        dist = np.full(N, -1, dtype=np.int64)
        dist[0] = 0
        frontier = np.array([0], dtype=np.int64)
        d = 0
        nbr = self.neighbor_indices
        while frontier.size:
            d += 1
            nxt = np.unique(nbr[frontier].ravel())
            nxt = nxt[dist[nxt] < 0]
            dist[nxt] = d
            frontier = nxt
        return dist

    @cached_property
    def diameter(self) -> int:
        return int(self.distances_from_origin.max())

    @cached_property
    def average_distance(self) -> float:
        """Mean distance over ordered pairs with distinct endpoints, i.e.
        Σ_v d(0,v) / (N−1) — the convention matching the paper's Table 1."""
        d = self.distances_from_origin
        return float(d.sum()) / (self.order - 1)

    def distance(self, u, v) -> int:
        """d(u, v) via translation invariance."""
        u = np.asarray(u, dtype=np.int64)
        v = np.asarray(v, dtype=np.int64)
        return int(self.distances_from_origin[self.label_to_index(v - u)])

    def distance_distribution(self) -> np.ndarray:
        """hist[k] = #nodes at distance k from any fixed node."""
        return np.bincount(self.distances_from_origin)

    # -- structure ----------------------------------------------------------
    @cached_property
    def side(self) -> int:
        """The side a of the graph (Definition 7): H[n-1, n-1]."""
        return int(self.hermite[self.n - 1, self.n - 1])

    def projection(self) -> "LatticeGraph":
        """Projection over e_n (Definition 7): G(B) for H = [[B, c], [0, a]]."""
        if self.n == 1:
            raise ValueError("cannot project a cycle")
        return LatticeGraph(self.hermite[: self.n - 1, : self.n - 1])

    def order_of(self, x) -> int:
        return intmat.element_order(x, self.matrix)

    def is_connected(self) -> bool:
        return bool((self.distances_from_origin >= 0).all())

    def smith_invariants(self) -> tuple[int, ...]:
        return intmat.smith_invariants(self.matrix)

    def __repr__(self) -> str:  # pragma: no cover
        return f"LatticeGraph(n={self.n}, N={self.order}, M={list(map(list, self.M))})"
