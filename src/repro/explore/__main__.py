"""CLI for the topology explorer.

    PYTHONPATH=src python -m repro.explore [--smoke] [options]

Prints the seeded Pareto front (throughput × p99 × faulted capacity)
with the RTT/FCC/BCC and mixed-radix-torus baselines pinned, then the
acceptance check: does a discovered lattice Pareto-dominate the
same-order torus?  `--require-dominance` turns that check into the
exit status (the CI smoke gate).
"""
from __future__ import annotations

import argparse
import json
import sys

from .evaluate import EvalSettings
from .optimizer import explore
from .pareto import dominates
from .space import SearchSpace


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.explore",
        description="Seeded evolutionary search over cubic-crystal "
                    "lattice topologies.")
    p.add_argument("--generations", type=int, default=12)
    p.add_argument("--population", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--eps", type=float, default=1e-3,
                   help="epsilon-Pareto dominance slack")
    p.add_argument("--mode", choices=("analytic", "sim"),
                   default="analytic",
                   help="p99 objective: closed-form proxy or the "
                        "slot-level simulator")
    p.add_argument("--load", type=float, default=0.30,
                   help="offered load for the p99 objective")
    p.add_argument("--pairs", type=int, default=4096,
                   help="Monte-Carlo pairs per saturation walk")
    p.add_argument("--smoke", action="store_true",
                   help="CI budget: <=8 generations, small population, "
                        "analytic mode")
    p.add_argument("--out", type=str, default=None,
                   help="write the front JSON here")
    p.add_argument("--checkpoint", type=str, default=None,
                   help="JSON checkpoint path (written every generation)")
    p.add_argument("--resume", action="store_true",
                   help="resume from --checkpoint if it exists")
    p.add_argument("--require-dominance", action="store_true",
                   help="exit 1 unless a discovered lattice "
                        "Pareto-dominates the torus baseline")
    return p


def main(argv=None) -> int:
    args = _parser().parse_args(argv)
    if args.smoke:
        args.generations = min(args.generations, 8)
        args.population = min(args.population, 6)
        args.mode = "analytic"
        args.pairs = min(args.pairs, 2048)

    settings = EvalSettings(mode=args.mode, load=args.load,
                            pairs=args.pairs, seed=args.seed)
    space = SearchSpace()

    def progress(gen, archive):
        n = len(archive.discovered())
        print(f"  gen {gen:2d}: front holds {n} discovered candidate"
              f"{'s' if n != 1 else ''}")

    result = explore(space, settings, generations=args.generations,
                     population=args.population, seed=args.seed,
                     eps=args.eps, checkpoint=args.checkpoint,
                     resume=args.resume, progress=progress)
    archive = result.archive

    print(f"\n== Pareto front (seed={args.seed}, mode={args.mode}, "
          f"{result.generations} generations, "
          f"{result.evaluations} evaluations) ==")
    print(f"  {'candidate':26} {'kind':9} {'thr':>6} {'p99':>8} "
          f"{'faulted':>8}")
    for e in archive.front():
        o = e.objectives
        tag = "  [baseline]" if e.baseline else ""
        print(f"  {e.candidate.label():26} {e.candidate.kind:9} "
              f"{o.throughput:6.3f} {o.p99:8.1f} {o.faulted:8.3f}{tag}")

    # -- acceptance: a discovered lattice dominates the same-order torus --
    torus = next(e for e in archive.front()
                 if e.baseline and e.candidate.kind == "baseline"
                 and e.candidate.name.startswith("T("))
    winners = [e for e in archive.discovered()
               if dominates(e.objectives, torus.objectives)]
    if winners:
        best = winners[0]
        print(f"\n{best.candidate.label()} Pareto-dominates "
              f"{torus.candidate.name}: "
              f"thr {best.objectives.throughput:.3f} vs "
              f"{torus.objectives.throughput:.3f}, "
              f"p99 {best.objectives.p99:.1f} vs "
              f"{torus.objectives.p99:.1f}, "
              f"faulted {best.objectives.faulted:.3f} vs "
              f"{torus.objectives.faulted:.3f}")
    else:
        print(f"\nno discovered candidate dominates "
              f"{torus.candidate.name} yet (try more generations)")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(archive.to_json(), f, indent=2)
        print(f"front written to {args.out}")

    if args.require_dominance and not winners:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
