"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU; asserts output shapes and finiteness (no NaNs).

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, get_config, shapes_for, skipped_shapes_for
from repro.models import (decode_step, forward, init_cache, init_params,
                          param_count, prefill)
from repro.models.common import cross_entropy

ARCHS = sorted(REGISTRY)
KEY = jax.random.PRNGKey(0)


def _inputs(r, B, S):
    tokens = jax.random.randint(KEY, (B, S), 0, r.vocab_size)
    kwargs = {}
    if r.family == "vlm":
        kwargs["patch_embeds"] = jax.random.normal(
            KEY, (B, r.num_patch_tokens, r.d_model), jnp.float32)
    if r.is_encdec:
        kwargs["enc_frames"] = jax.random.normal(
            KEY, (B, r.encoder_seq_len, r.d_model), jnp.float32)
    return tokens, kwargs


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    r = get_config(arch).reduced()
    params = init_params(r, KEY)
    B, S = 2, 64
    tokens, kwargs = _inputs(r, B, S)
    logits, aux = jax.jit(lambda p, t: forward(p, r, t, **kwargs))(params, tokens)
    assert logits.shape == (B, S, r.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite_grads(arch):
    r = get_config(arch).reduced()
    params = init_params(r, KEY)
    B, S = 2, 32
    tokens, kwargs = _inputs(r, B, S)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits, aux = forward(p, r, tokens, **kwargs)
        return cross_entropy(logits, labels) + aux

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    # at least the embedding gets a gradient
    assert float(jnp.abs(grads["embed"]).max()) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    r = get_config(arch).reduced()
    params = init_params(r, KEY)
    B, S = 2, 33  # deliberately not chunk-aligned
    tokens, kwargs = _inputs(r, B, S + 1)
    full_logits, _ = forward(params, r, tokens, **kwargs)
    last_logits, cache = prefill(params, r, tokens[:, :S], max_len=S + 8, **kwargs)
    e_prefill = float(jnp.max(jnp.abs(
        full_logits[:, S - 1].astype(jnp.float32) -
        last_logits[:, 0].astype(jnp.float32))))
    dec_logits, cache = decode_step(params, r, tokens[:, S:S + 1], cache,
                                    jnp.int32(S))
    e_decode = float(jnp.max(jnp.abs(
        full_logits[:, S].astype(jnp.float32) -
        dec_logits[:, 0].astype(jnp.float32))))
    assert e_prefill < 0.05, f"prefill mismatch {e_prefill}"
    assert e_decode < 0.05, f"decode mismatch {e_decode}"


@pytest.mark.parametrize("arch", ARCHS)
def test_multi_step_decode_runs(arch):
    r = get_config(arch).reduced()
    params = init_params(r, KEY)
    B, S = 2, 16
    tokens, kwargs = _inputs(r, B, S)
    _, cache = prefill(params, r, tokens, max_len=S + 4, **kwargs)
    step = jax.jit(lambda p, t, c, pos: decode_step(p, r, t, c, pos))
    tok = tokens[:, -1:]
    for i in range(3):
        logits, cache = step(params, tok, cache, jnp.int32(S + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_remat_matches_no_remat(arch):
    r = get_config(arch).reduced()
    params = init_params(r, KEY)
    tokens, kwargs = _inputs(r, 2, 32)
    l1, _ = forward(params, r, tokens, remat="none", **kwargs)
    l2, _ = forward(params, r, tokens, remat="full", **kwargs)
    assert float(jnp.max(jnp.abs(l1.astype(jnp.float32) -
                                 l2.astype(jnp.float32)))) < 1e-3


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (the 10-arch table)."""
    c = get_config("deepseek-moe-16b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (28, 2048, 16, 16, 1408, 102_400)
    assert (c.moe.num_experts, c.moe.top_k, c.moe.num_shared_experts) == (64, 6, 2)
    c = get_config("phi3.5-moe-42b-a6.6b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (32, 4096, 32, 8, 6400, 32_064)
    assert (c.moe.num_experts, c.moe.top_k) == (16, 2)
    c = get_config("phi3-mini-3.8b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (32, 3072, 32, 32, 8192, 32_064)
    c = get_config("qwen3-4b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (36, 2560, 32, 8, 9728, 151_936)
    assert c.qk_norm
    c = get_config("olmo-1b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (16, 2048, 16, 16, 8192, 50_304)
    assert c.nonparametric_norm
    c = get_config("command-r-plus-104b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (64, 12_288, 96, 8, 33_792, 256_000)
    c = get_config("zamba2-1.2b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (38, 2048, 32, 32, 8192, 32_000)
    assert c.ssm.state_size == 64
    c = get_config("mamba2-2.7b")
    assert (c.num_layers, c.d_model, c.vocab_size) == (64, 2560, 50_280)
    assert c.ssm.state_size == 128
    c = get_config("internvl2-2b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (24, 2048, 16, 8, 8192, 92_553)
    c = get_config("whisper-base")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (6, 512, 8, 8, 2048, 51_865)
    assert c.encoder_layers == 6


def test_shape_cell_assignment():
    """40 cells total: 32 live + 8 documented long_500k skips."""
    live = sum(len(shapes_for(c)) for c in REGISTRY.values())
    skipped = sum(len(skipped_shapes_for(c)) for c in REGISTRY.values())
    assert live + skipped == 40
    assert skipped == 8
    assert len(shapes_for(get_config("mamba2-2.7b"))) == 4
    assert len(shapes_for(get_config("zamba2-1.2b"))) == 4
