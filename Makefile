# Entry points — no PYTHONPATH=src incantations needed (pytest picks up
# src/ via pyproject's pythonpath ini + tests/conftest.py; the benchmark
# driver gets it from this Makefile).
#
# CI (.github/workflows/ci.yml) runs: `make test` + `make bench-smoke` on
# the test matrix, `make bench-check` as the perf-regression gate, and
# `make lint` in the lint job.  Policy details: docs/ci.md.
PY ?= python
BENCH_JSON ?= /tmp/bench_current.json
BENCH_TOLERANCE ?= 0.30
# sections whose numbers the regression gate tracks (routing Mrec/s +
# simulator & scenario-engine slots/s); keep in sync with BENCH_baseline.json
BENCH_GATE_SECTIONS = routing,sim,scenarios

.PHONY: test test-fast bench bench-quick bench-routing bench-smoke \
        bench-check bench-baseline lint

# --durations surfaces the slowest tests so suite-time regressions are
# visible in every CI log
test:
	$(PY) -m pytest -q --durations=15

# skip the slow distributed/simulation modules; covers the routing stack
test-fast:
	$(PY) -m pytest -q tests/test_intmat.py tests/test_lattice.py \
	    tests/test_crystals.py tests/test_routing.py \
	    tests/test_routing_engine.py tests/test_symmetry.py

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

bench-quick:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick

# routing engine throughput only (ISSUE 1 acceptance numbers)
bench-routing:
	PYTHONPATH=src $(PY) -m benchmarks.run --only routing

# fast sanity pass CI runs on every matrix entry: cheap analytic sections
# + the quick simulator & scenario-engine benchmarks (covers the fused
# Pallas row, the K-scenario one-compile sweep and the device fault-BFS
# sweep); exercises the whole bench plumbing
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick \
	    --only table1,table2,throughput,sim,scenarios

# perf-regression gate: measure the gated sections twice (quick mode,
# JSON; per-metric best-of — a load spike slows one run, a regression
# slows both) and compare against the committed baseline; >30% fails
bench-check:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick \
	    --only $(BENCH_GATE_SECTIONS) --json $(BENCH_JSON)
	PYTHONPATH=src $(PY) -m benchmarks.run --quick \
	    --only $(BENCH_GATE_SECTIONS) --json $(BENCH_JSON).2
	PYTHONPATH=src $(PY) -m benchmarks.check_regression \
	    --baseline BENCH_baseline.json \
	    --current $(BENCH_JSON) $(BENCH_JSON).2 \
	    --tolerance $(BENCH_TOLERANCE)

# refresh the committed baseline (run on the CI machine class, then commit)
bench-baseline:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick \
	    --only $(BENCH_GATE_SECTIONS) --json BENCH_baseline.json

# ruff config lives in pyproject.toml [tool.ruff]; skips politely when
# ruff isn't installed (offline containers)
lint:
	@command -v ruff >/dev/null 2>&1 \
	    && ruff check src benchmarks tests \
	    || echo "ruff not installed; skipping lint (CI installs it)"
