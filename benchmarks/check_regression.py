"""Bench-regression gate: compare a fresh --json run against the committed
baseline and fail on >tolerance slowdowns of the gated throughput metrics.

    python -m benchmarks.check_regression \
        --baseline BENCH_baseline.json --current /tmp/bench.json \
        [--tolerance 0.30]

Gated metrics are the higher-is-better throughput numbers (routing
Mrec/s, simulator slots/s, sweep points/s) — `GATED_SUFFIXES` below; all
are measured best-of-reps, the robust estimator on shared runners.
Speedup ratios are deliberately NOT gated: they are quotients of two
noisy timings (the slow host-oracle side runs few reps), so they double
the variance instead of cancelling it.  Rows only present on one side
are reported but never fail the gate (sections and sizes may evolve); a
gated metric regressing by more than `tolerance` (default 30%) fails
with exit code 1.  Policy: docs/ci.md.
"""
from __future__ import annotations

import argparse
import json
import sys

# derived-metric keys that are gated (higher is better); matched as key
# SUFFIXES so e.g. scen_sweep_loadpoints_per_s and sweep_loadpoints_per_s
# both fall under the loadpoints marker (the PR 3 suffix-matching fix).
# epochs_per_s covers the transient-engine epoch-stacked BFS rows;
# overhead_ratio gates the latency-histogram cost (plain/hist run time —
# higher is better, 1.0 means the telemetry is free), the VC router's
# V=2-vs-V=1 per-slot price and the hetero section's weighted-vs-trivial
# step cost; _sat_phits gates the VC and hetero sections' accepted
# saturation loads (deterministic given the seed — the gate pins the
# escape-lane delivery and express-overlay wins themselves, not a
# timing); candidates_per_s gates the topology explorer's evaluate-and-
# archive throughput and dominates_torus pins the ISSUE 10 acceptance
# fact (the seeded search still rediscovers a lattice that beats the
# same-order torus — a 1→0 flip is ratio 0, an automatic failure).
GATED_SUFFIXES = ("_Mrec_s", "slots_per_s", "loadpoints_per_s",
                  "scenarios_per_s", "epochs_per_s", "overhead_ratio",
                  "_sat_phits", "candidates_per_s", "dominates_torus")
# dispatch-overhead-dominated micro-rows: reported, never gated (they are
# not the protected quantity and are the noisiest numbers on shared CPUs).
# Matched as a name SUFFIX: a substring test would also swallow the
# /B=100000 rows — the exact metrics the gate exists to protect.
UNGATED_ROW_SUFFIXES = ("/B=1000",)


def _gated(name: str, row: dict) -> dict:
    if name.endswith(UNGATED_ROW_SUFFIXES):
        return {}
    return {k: v for k, v in row.get("derived", {}).items()
            if isinstance(v, (int, float))
            and any(k.endswith(s) for s in GATED_SUFFIXES)}


def merge_best(docs: list[dict]) -> dict:
    """Per-metric max over repeated measurement runs: a load spike slows
    one run, a real regression slows them all."""
    out = json.loads(json.dumps(docs[0]))
    by_name = {r["name"]: r for r in out["rows"]}
    for doc in docs[1:]:
        for row in doc["rows"]:
            tgt = by_name.get(row["name"])
            if tgt is None:
                out["rows"].append(row)
                by_name[row["name"]] = row
                continue
            for k, v in row.get("derived", {}).items():
                cur = tgt["derived"].get(k)
                if isinstance(v, (int, float)) and isinstance(
                        cur, (int, float)):
                    tgt["derived"][k] = max(cur, v)
    return out


def compare(baseline: dict, current: dict, tolerance: float):
    base_rows = {r["name"]: r for r in baseline["rows"]}
    cur_rows = {r["name"]: r for r in current["rows"]}
    failures, notes = [], []
    for name, brow in sorted(base_rows.items()):
        crow = cur_rows.get(name)
        if crow is None:
            notes.append(f"row missing from current run: {name}")
            continue
        cder = crow.get("derived", {})
        for metric, bval in _gated(name, brow).items():
            cval = cder.get(metric)
            if not isinstance(cval, (int, float)):
                notes.append(f"metric missing: {name}:{metric}")
                continue
            if bval <= 0:
                continue
            ratio = cval / bval
            line = (f"{name}:{metric} baseline={bval:.2f} "
                    f"current={cval:.2f} ratio={ratio:.2f}")
            if ratio < 1.0 - tolerance:
                failures.append(line)
            else:
                notes.append("ok " + line)
    for name in sorted(set(cur_rows) - set(base_rows)):
        notes.append(f"new row (not in baseline): {name}")
    return failures, notes


def _die(msg: str) -> None:
    """Infrastructure failure: clean one-line error, exit code 2 — distinct
    from exit code 1, which means a genuine bench regression."""
    print(msg, file=sys.stderr)
    sys.exit(2)


def _load(path: str) -> dict:
    """Read one measurement document, failing with a clean one-line error
    (exit code 2) on unreadable files or malformed/shapeless JSON instead
    of a traceback — the gate's own failures must be unambiguous."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except OSError as e:
        _die(f"check_regression: cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        _die(f"check_regression: invalid JSON in {path}: {e}")
    if not isinstance(doc, dict) or not isinstance(doc.get("rows"), list):
        _die(f"check_regression: {path} has no 'rows' list "
             "(not a benchmarks.run --json document?)")
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True, nargs="+",
                    help="one or more measurement runs; per-metric best "
                         "is compared (re-measuring beats a load spike)")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="max allowed fractional slowdown (default 0.30)")
    args = ap.parse_args()
    baseline = _load(args.baseline)
    current = merge_best([_load(path) for path in args.current])
    failures, notes = compare(baseline, current, args.tolerance)
    for n in notes:
        print(n)
    if failures:
        print(f"\nBENCH REGRESSION (> {args.tolerance:.0%} slowdown):",
              file=sys.stderr)
        for f_ in failures:
            print("  " + f_, file=sys.stderr)
        sys.exit(1)
    print(f"\nbench-check passed ({args.tolerance:.0%} tolerance)")


if __name__ == "__main__":
    main()
