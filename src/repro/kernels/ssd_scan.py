"""Mamba2 SSD intra-chunk kernel (the quadratic hot-spot of the SSD
algorithm).

Grid (BH, num_chunks): each step loads one chunk (Q timesteps) of one
batch·head into VMEM and produces the intra-chunk output y_diag, the chunk's
end-state contribution (P, N), and the chunk's total log-decay.  The cheap
O(nc) inter-chunk recurrence and the rank-1 y_off correction stay in XLA
(see repro.kernels.ops.ssd) — this matches how production SSD kernels split
the work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from ._compat import CompilerParams

NEG_INF = -1e30


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref, sum_ref, *,
                chunk: int):
    x = x_ref[...].astype(jnp.float32)            # (Q, P)
    a = a_ref[...].astype(jnp.float32)            # (1, Q)
    b = b_ref[...].astype(jnp.float32)            # (Q, N)
    c = c_ref[...].astype(jnp.float32)            # (Q, N)

    a_cum = jnp.cumsum(a[0], axis=-1)             # (Q,)
    diff = a_cum[:, None] - a_cum[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.exp(jnp.where(rows >= cols, diff, NEG_INF))

    scores = jax.lax.dot_general(                 # C Bᵀ (Q, Q)
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(                      # (Q, P)
        scores * L, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)

    decay = jnp.exp(a_cum[-1] - a_cum)            # (Q,)
    bx = b * decay[:, None]
    state = jax.lax.dot_general(                  # (P, N) = xᵀ (B·decay)
        x, bx, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    st_ref[...] = state.astype(st_ref.dtype)
    sum_ref[...] = a_cum[-1].reshape(1, 1).astype(sum_ref.dtype)


def ssd_intra_chunk(xdt, Adt, Bm, Cm, *, interpret: bool = True):
    """xdt: (BH, nc, Q, P); Adt: (BH, nc, Q); Bm, Cm: (BH, nc, Q, N).
    Returns (y_diag (BH,nc,Q,P), states (BH,nc,P,N), chunk_sum (BH,nc))."""
    BH, nc, Q, P = xdt.shape
    N = Bm.shape[-1]
    kernel = functools.partial(_ssd_kernel, chunk=Q)
    y, st, s = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((None, None, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, 1, Q), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, None, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, None, Q, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, None, P, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, None, 1, 1), lambda b, c: (b, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, Q, P), xdt.dtype),
            jax.ShapeDtypeStruct((BH, nc, P, N), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, 1, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xdt, Adt, Bm, Cm)
    return y, st, s[..., 0, 0]
