"""Topology-explorer throughput + front quality (ISSUE 10).

Two committed records of the closed-loop search:

  * `explore/loop` — wall-clock candidate throughput of the analytic
    evolutionary loop (`candidates_per_s`, gated): every candidate pays
    three scored objectives (pristine saturation, worst-epoch faulted
    saturation under the canonical schedule, the analytic p99 proxy)
    through the unified surface on the host backend, so this row prices
    the whole evaluate-and-archive path.

  * `explore/front/seed0` — deterministic front quality at the
    committed seed (analytic mode + host BFS + seeded numpy walks ⇒
    bit-stable): the best discovered candidate's saturation and faulted
    capacity carry the `_sat_phits` gate suffix, and `dominates_torus`
    records the acceptance fact itself — a regression here means the
    search stopped rediscovering BCC-class lattices that beat the
    same-order mixed-radix torus, not a timing.
"""
from __future__ import annotations

import time

from repro.explore import EvalSettings, SearchSpace, dominates, explore

from .util import emit

SEED = 0


def main(quick: bool = False) -> None:
    generations, population = (2, 4) if quick else (4, 6)
    settings = EvalSettings(mode="analytic", pairs=1024 if quick else 2048,
                            seed=SEED)
    space = SearchSpace()

    t0 = time.perf_counter()
    result = explore(space, settings, generations=generations,
                     population=population, seed=SEED)
    elapsed = time.perf_counter() - t0
    offered = result.candidates + len(space.baselines())
    emit(f"explore/loop/gen={generations}", elapsed * 1e6 / offered,
         f"candidates_per_s={offered / elapsed:.2f};"
         f"evaluations={result.evaluations};"
         f"mode=analytic")

    archive = result.archive
    torus = next(e for e in archive.entries
                 if e.baseline and e.candidate.name.startswith("T("))
    disc = archive.discovered()
    best = max(disc, key=lambda e: e.objectives.throughput)
    wins = any(dominates(e.objectives, torus.objectives) for e in disc)
    emit(f"explore/front/seed{SEED}", 0.0,
         f"front_best_sat_phits={best.objectives.throughput:.4f};"
         f"front_fault_sat_phits={best.objectives.faulted:.4f};"
         f"torus_sat_phits={torus.objectives.throughput:.4f};"
         f"dominates_torus={int(wins)}")


if __name__ == "__main__":
    main()
