"""Multi-objective bookkeeping: objective vectors, epsilon-Pareto
dominance, and the archive the evolutionary loop selects from.

Objectives (the ROADMAP's deliverable axes): saturation throughput
(phits/cycle/node, maximise), p99 latency at the evaluator's fixed
offered load (cycles, minimise), and faulted capacity — the worst-epoch
saturation under the canonical `FaultSchedule` (maximise).  Internally
every axis is maximised (`p99` is negated); NaN/inf scores clamp to
worst so a broken candidate can never dominate anything.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .space import Candidate


@dataclass(frozen=True)
class Objectives:
    throughput: float          # saturation, phits/cycle/node (higher better)
    p99: float                 # p99 latency at fixed load, cycles (lower)
    faulted: float             # worst-epoch degraded saturation (higher)

    def maximized(self) -> tuple[float, float, float]:
        """All-maximised view with NaN/±inf clamped to worst."""
        def up(x):
            return x if math.isfinite(x) else -math.inf

        def down(x):
            return -x if math.isfinite(x) else -math.inf
        return (up(self.throughput), down(self.p99), up(self.faulted))

    def to_json(self) -> dict:
        return {"throughput": self.throughput, "p99": self.p99,
                "faulted": self.faulted}

    @classmethod
    def from_json(cls, d: dict) -> "Objectives":
        return cls(throughput=float(d["throughput"]), p99=float(d["p99"]),
                   faulted=float(d["faulted"]))

    @classmethod
    def worst(cls) -> "Objectives":
        """The sentinel for candidates whose evaluation failed (e.g. the
        canonical schedule disconnected the graph)."""
        return cls(throughput=0.0, p99=math.inf, faulted=0.0)


def dominates(a: Objectives, b: Objectives, eps: float = 0.0) -> bool:
    """True iff `a` epsilon-Pareto-dominates `b`: a ≥ b − eps on every
    maximised axis and a > b on at least one (strictly, the eps=0
    textbook definition; eps > 0 coarsens acceptance so near-duplicates
    don't flood the archive)."""
    av, bv = a.maximized(), b.maximized()
    ge_all = all(x >= y - eps for x, y in zip(av, bv))
    gt_any = any(x > y for x, y in zip(av, bv))
    return ge_all and gt_any


@dataclass(frozen=True)
class ArchiveEntry:
    candidate: Candidate
    objectives: Objectives
    baseline: bool = False

    def to_json(self) -> dict:
        return {"candidate": self.candidate.to_json(),
                "objectives": self.objectives.to_json(),
                "baseline": self.baseline}

    @classmethod
    def from_json(cls, d: dict) -> "ArchiveEntry":
        return cls(candidate=Candidate.from_json(d["candidate"]),
                   objectives=Objectives.from_json(d["objectives"]),
                   baseline=bool(d["baseline"]))


class ParetoArchive:
    """Epsilon-Pareto archive with pinned baselines.

    `add` keeps the archive mutually non-dominated over the NON-baseline
    members: a newcomer dominated by any member (with `eps` slack) is
    rejected; an accepted newcomer evicts every member it strictly
    dominates.  Baseline entries are reference points — they are never
    evicted and never block a newcomer (a discovered candidate must be
    able to beat them, that is the whole point) but they do appear in
    the front output."""

    def __init__(self, eps: float = 0.0):
        self.eps = float(eps)
        self._entries: list[ArchiveEntry] = []

    # -- membership ---------------------------------------------------------
    def add(self, candidate: Candidate, objectives: Objectives,
            baseline: bool = False) -> bool:
        """Offer one scored candidate; returns True iff it was retained."""
        entry = ArchiveEntry(candidate, objectives, baseline)
        if baseline:
            self._entries.append(entry)
            return True
        key = candidate.key()
        for e in self._entries:
            if not e.baseline and e.candidate.key() == key:
                return False        # identical design point, not progress
            if not e.baseline and dominates(e.objectives, objectives,
                                            self.eps):
                return False
        self._entries = [
            e for e in self._entries
            if e.baseline or not dominates(objectives, e.objectives)]
        self._entries.append(entry)
        return True

    @property
    def entries(self) -> tuple[ArchiveEntry, ...]:
        return tuple(self._entries)

    def front(self) -> tuple[ArchiveEntry, ...]:
        """Archive sorted for stable output: baselines first (in insert
        order), then discovered members by descending throughput."""
        base = [e for e in self._entries if e.baseline]
        rest = sorted((e for e in self._entries if not e.baseline),
                      key=lambda e: (-e.objectives.throughput,
                                     e.objectives.p99,
                                     e.candidate.label()))
        return tuple(base + rest)

    def discovered(self) -> tuple[ArchiveEntry, ...]:
        return tuple(e for e in self._entries if not e.baseline)

    # -- persistence --------------------------------------------------------
    def to_json(self) -> dict:
        # raw insertion order, NOT front() order: `discovered()` drives
        # parent selection, so a resumed archive must replay the exact
        # member order of the uninterrupted run
        return {"eps": self.eps,
                "entries": [e.to_json() for e in self._entries]}

    @classmethod
    def from_json(cls, d: dict) -> "ParetoArchive":
        out = cls(eps=float(d["eps"]))
        out._entries = [ArchiveEntry.from_json(e) for e in d["entries"]]
        return out
