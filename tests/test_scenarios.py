"""Differential test suite for the fault-injection & adaptive-routing
scenario engine (ISSUE 3).

For every (scenario × pattern) cell on T(4,4,4,4) — the acceptance
topology — plus small RTT/FCC/BCC crystal cells, the port-batched
simulator must agree with the per-port reference oracle on the whole
load curve (seed-averaged, ±5 % per point), and every run must satisfy
the exact invariants:

  * conservation — delivered + in-flight + dropped == injected (integer
    equality, warmup=0 so every slot is counted),
  * dead-channel audit — `SimResult.link_use` records every crossing;
    masked channels must show exactly zero,
  * adaptivity dominance — on a faulted graph, minimal-adaptive accepted
    load at saturation ≥ DOR's (which blocks on dead required channels),
  * escape routing — when every productive port is dead the escape
    policy misroutes and still delivers (a ring with a dead link is the
    sharpest case: adaptive wedges, escape goes the long way round),
  * multi-seed axis — same seeds ⇒ bitwise-identical curves; more seeds
    ⇒ tighter CI; the whole (loads × seeds) sweep is ONE device program
    (a single top-level `lax.scan` under the nested vmaps).

Everything is seeded and deterministic — no flaky tolerances.
"""
import numpy as np
import pytest

from repro.core import BCC, FCC, RTT, Scenario, Torus, scenario_connected
from repro.core.simulation import (_RUNNER_CACHE, _sweep_plan, build_tables,
                                   simulate, simulate_sweep)

# acceptance topology: every differential cell runs on T(4,4,4,4)
G = Torus(4, 4, 4, 4)
TABLES = build_tables(G)
LOADS = (0.25, 0.6, 0.95)
SLOTS, SEEDS = 256, 2          # warmup=0: exact conservation every cell

SCENARIOS = {
    "baseline": None,
    "links3/dor": Scenario.random_link_faults(G, 3, seed=1, policy="dor"),
    "links3/adaptive": Scenario.random_link_faults(G, 3, seed=1,
                                                   policy="adaptive"),
    "links3/escape": Scenario.random_link_faults(G, 3, seed=1,
                                                 policy="escape"),
    "nodes2/adaptive": Scenario.random_node_faults(G, 2, seed=2,
                                                   policy="adaptive"),
}
PATTERNS = ("uniform", "centralsymmetric")

_CELLS: dict = {}


def cell(scen_name: str, pattern: str, impl: str):
    """One differential cell: a seed-averaged load curve (cached so the
    invariant tests reuse the differential runs)."""
    key = (scen_name, pattern, impl)
    if key not in _CELLS:
        _CELLS[key] = simulate_sweep(
            G, pattern, LOADS, slots=SLOTS, warmup=0, seed=0, seeds=SEEDS,
            tables=TABLES, impl=impl, scenario=SCENARIOS[scen_name])
    return _CELLS[key]


@pytest.mark.parametrize("pattern", PATTERNS)
@pytest.mark.parametrize("scen_name", sorted(SCENARIOS))
def test_differential_batched_vs_reference(scen_name, pattern):
    """Batched ≡ reference within ±5 % per load point (seed-averaged)."""
    b = cell(scen_name, pattern, "batched").accepted_mean()
    r = cell(scen_name, pattern, "reference").accepted_mean()
    rel = np.abs(b - r) / np.maximum(r, 1e-9)
    assert (np.minimum(rel, np.abs(b - r) / 0.4) <= 0.05).all(), \
        (scen_name, pattern, b, r, rel)


@pytest.mark.parametrize("impl", ("batched", "reference"))
@pytest.mark.parametrize("pattern", PATTERNS)
@pytest.mark.parametrize("scen_name", sorted(SCENARIOS))
def test_conservation_and_dead_link_audit(scen_name, pattern, impl):
    """EXACT accounting on every cell: delivered + in-flight + dropped ==
    injected, and zero crossings of masked channels."""
    scen = SCENARIOS[scen_name]
    for row in cell(scen_name, pattern, impl).results:
        for r in row:
            assert r.delivered + r.in_flight + r.dropped == r.injected, \
                (scen_name, pattern, impl, r)
            if scen is not None:
                assert r.link_use is not None
                assert int(r.link_use[~scen.link_ok(G)].sum()) == 0, \
                    (scen_name, pattern, impl)
                # sanity: the audit actually counted live traffic
                assert int(r.link_use.sum()) > 0


def test_adaptive_dominates_dor_at_saturation():
    """On the faulted graph, minimal-adaptive accepted load at the
    saturating offered loads beats DOR, which blocks on dead channels."""
    for pattern in PATTERNS:
        dor = cell("links3/dor", pattern, "batched").accepted_mean()
        ada = cell("links3/adaptive", pattern, "batched").accepted_mean()
        # compare at the saturating points (offered 0.6 and 0.95)
        assert (ada[1:] >= dor[1:] - 0.005).all(), (pattern, dor, ada)
        assert ada[1:].sum() > dor[1:].sum(), (pattern, dor, ada)


def test_dropped_only_for_dead_fixed_destinations():
    """Uniform traffic samples live destinations (never drops); a fixed
    pattern aimed at a dead node drops — and both conserve exactly."""
    for row in cell("nodes2/adaptive", "uniform", "batched").results:
        assert all(r.dropped == 0 for r in row)
    dropped = [r.dropped
               for row in cell("nodes2/adaptive", "centralsymmetric",
                               "batched").results for r in row]
    assert all(d > 0 for d in dropped), dropped


def test_escape_routes_around_a_wedged_node():
    """Both dim-0 channels of one T(4,4) node dead: a packet sitting there
    with a pure dim-0 record has NO live productive port — minimal-adaptive
    wedges it forever, escape takes an orthogonal non-minimal hop and
    delivers.  Expected ordering: escape > adaptive > dor in delivered
    packets, and escape strands far fewer packets in flight."""
    g = Torus(4, 4)
    t = build_tables(g)
    base = Scenario(dead_links=((5, 0), (5, 1)), policy="adaptive")
    assert scenario_connected(g, base)
    res = {}
    for policy in ("dor", "adaptive", "escape"):
        res[policy] = simulate(g, "uniform", 0.7, slots=384, warmup=0,
                               seed=3, tables=t,
                               scenario=base.with_policy(policy))
        r = res[policy]
        assert r.delivered + r.in_flight + r.dropped == r.injected
        assert int(r.link_use[~base.link_ok(g)].sum()) == 0
    assert res["escape"].delivered > res["adaptive"].delivered > \
        res["dor"].delivered, res
    assert res["escape"].in_flight < res["adaptive"].in_flight, res


def test_ring_escape_livelock_still_conserves():
    """An n=1 ring has no orthogonal escape dimension: a memoryless escape
    policy ping-pongs at the fault (documented livelock).  Even then the
    hard invariants hold — exact conservation, zero dead crossings — and
    the stranded packets show up as in-flight, not as loss."""
    ring = Torus(8)
    t = build_tables(ring)
    scen = Scenario(dead_links=((0, 0),), policy="escape")
    assert scenario_connected(ring, scen)
    r = simulate(ring, "uniform", 0.25, slots=256, warmup=0, seed=3,
                 tables=t, scenario=scen)
    assert r.delivered + r.in_flight + r.dropped == r.injected
    assert int(r.link_use[~scen.link_ok(ring)].sum()) == 0
    assert r.in_flight > 0


@pytest.mark.parametrize("gname,graph", [
    ("RTT3", RTT(3)), ("FCC2", FCC(2)), ("BCC2", BCC(2))])
def test_differential_small_crystals(gname, graph):
    """The (scenario × RTT/FCC/BCC) axis of the differential matrix:
    faulted adaptive cells on the crystal families, batched vs reference,
    seed-averaged (small N ⇒ more seeds, looser per-point noise floor)."""
    t = build_tables(graph)
    scen = Scenario.random_link_faults(graph, 2, seed=4, policy="adaptive")
    acc = {}
    for impl in ("batched", "reference"):
        st = simulate_sweep(graph, "uniform", (0.3, 0.8), slots=320,
                            warmup=0, seed=0, seeds=4, tables=t, impl=impl,
                            scenario=scen)
        for row in st.results:
            for r in row:
                assert r.delivered + r.in_flight + r.dropped == r.injected
                assert int(r.link_use[~scen.link_ok(graph)].sum()) == 0
        acc[impl] = st.accepted_mean()
    diff = np.abs(acc["batched"] - acc["reference"])
    assert (diff <= np.maximum(0.05 * acc["reference"], 0.025)).all(), \
        (gname, acc)


# ---------------------------------------------------------------------------
# multi-seed axis
# ---------------------------------------------------------------------------

def test_multi_seed_bitwise_determinism():
    """Same seeds ⇒ bitwise-identical curves (counters are integers)."""
    g = BCC(2)
    t = build_tables(g)
    kw = dict(slots=160, warmup=40, seed=0, seeds=4, tables=t)
    a = simulate_sweep(g, "uniform", (0.3, 0.8), **kw)
    b = simulate_sweep(g, "uniform", (0.3, 0.8), **kw)
    for ra, rb in zip(
            (r for row in a.results for r in row),
            (r for row in b.results for r in row)):
        assert (ra.delivered, ra.injected, ra.in_flight) == \
               (rb.delivered, rb.injected, rb.in_flight)


def test_multi_seed_slice_equals_single_seed_sweep():
    """Seed-axis slice s of a multi-seed sweep is bitwise the single-seed
    sweep run with seed=seeds[s]."""
    g = BCC(2)
    t = build_tables(g)
    st = simulate_sweep(g, "uniform", (0.3, 0.8), slots=160, warmup=40,
                        seed=0, seeds=(5, 9), tables=t)
    for si, sd in enumerate(st.seeds):
        single = simulate_sweep(g, "uniform", (0.3, 0.8), slots=160,
                                warmup=40, seed=sd, tables=t)
        for li in range(2):
            assert st.results[li][si].delivered == single[li].delivered
            assert st.results[li][si].injected == single[li].injected
    # single-LOAD multi-seed sweeps use the unfolded base keys, so each
    # seed slice equals the plain single run with that seed
    st1 = simulate_sweep(g, "uniform", (0.8,), slots=160, warmup=40,
                         seed=0, seeds=(5, 9), tables=t)
    for si, sd in enumerate(st1.seeds):
        single = simulate(g, "uniform", 0.8, slots=160, warmup=40, seed=sd,
                          tables=t)
        assert st1.results[0][si].delivered == single.delivered
        assert st1.results[0][si].injected == single.injected


def test_fixed_pattern_drop_mask_not_cached_across_patterns():
    """The compiled runner is shared across fixed patterns (the cache key
    only carries fixed-ness), so the pattern-specific dead-destination
    drop mask must travel in the STATE: running pattern A first must not
    poison pattern B's drops."""
    g = Torus(4, 4)
    t = build_tables(g)
    # dead node 6=(1,2): centralsymmetric drops source 14=(3,2), antipodal
    # drops source 12=(3,0) — distinct masks, so cache poisoning is visible
    scen = Scenario(dead_nodes=(6,), policy="adaptive")
    kw = dict(slots=160, warmup=0, seed=2, tables=t, scenario=scen)
    simulate(g, "centralsymmetric", 0.5, **kw)       # primes the runner
    poisoned = simulate(g, "antipodal", 0.5, **kw)
    _RUNNER_CACHE.clear()
    fresh = simulate(g, "antipodal", 0.5, **kw)
    assert (poisoned.delivered, poisoned.injected, poisoned.dropped) == \
           (fresh.delivered, fresh.injected, fresh.dropped)
    assert fresh.dropped > 0


def test_random_link_faults_rejects_infeasible_k():
    g = Torus(2, 2)
    with pytest.raises(ValueError, match="exceeds"):
        Scenario.random_link_faults(g, g.order * g.n + 1)


def test_multi_seed_ci_shrinks_with_k():
    """CI half-width z·s/√k tightens with more seeds (disjoint seed sets;
    fully deterministic, so this is a fixed numerical fact, not a flake):
    expect ≈ 1/√4 = 0.5× going from k=4 to k=16."""
    g = BCC(2)
    t = build_tables(g)
    kw = dict(slots=160, warmup=40, seed=0, tables=t)
    small = simulate_sweep(g, "uniform", (0.5, 0.9), seeds=range(100, 104),
                           **kw)
    big = simulate_sweep(g, "uniform", (0.5, 0.9), seeds=range(200, 216),
                         **kw)
    ci_small = small.accepted_ci().mean()
    ci_big = big.accepted_ci().mean()
    assert ci_big < 0.9 * ci_small, (ci_small, ci_big)
    # and the seed means agree within the (generous) joint CI
    assert np.abs(small.accepted_mean() - big.accepted_mean()).max() \
        < 4 * (ci_small + ci_big)


def test_sweep_is_single_scan_device_program():
    """The (loads × seeds) sweep is ONE device program: exactly one
    top-level lax.scan under the nested vmaps, and re-invoking it does not
    grow the compiled-runner cache."""
    import jax
    g = BCC(2)
    t = build_tables(g)
    runner, state, keys, _, _ = _sweep_plan(
        g, "uniform", [0.3, 0.8], slots=96, warmup=24, queue=4, seed=0,
        seed_list=[0, 1, 2], tables=t, impl="batched", scenario=None)
    jaxpr = jax.make_jaxpr(runner)(state, keys)

    def scans(jx):
        n = 0
        for e in jx.eqns:
            if e.primitive.name == "scan":
                n += 1                 # don't descend: inner fixed-point
            elif "jaxpr" in e.params:  # unwrap pjit/closed calls
                sub = e.params["jaxpr"]
                n += scans(sub.jaxpr if hasattr(sub, "jaxpr") else sub)
        return n

    assert scans(jaxpr.jaxpr) == 1
    kw = dict(slots=96, warmup=24, seed=0, seeds=3, tables=t)
    simulate_sweep(g, "uniform", (0.3, 0.8), **kw)
    n_cache = len(_RUNNER_CACHE)
    simulate_sweep(g, "uniform", (0.3, 0.8), **kw)
    assert len(_RUNNER_CACHE) == n_cache


def test_trivial_scenario_is_bitwise_baseline():
    """Scenario() (no faults, DOR) compiles to the exact baseline program:
    results equal scenario=None bitwise, run for run."""
    g = BCC(2)
    t = build_tables(g)
    a = simulate(g, "uniform", 0.6, slots=160, warmup=40, seed=2, tables=t)
    b = simulate(g, "uniform", 0.6, slots=160, warmup=40, seed=2, tables=t,
                 scenario=Scenario())
    assert (a.delivered, a.injected, a.avg_latency_cycles) == \
           (b.delivered, b.injected, b.avg_latency_cycles)


# ---------------------------------------------------------------------------
# fault-aware analytic rebuilds (distances / channel loads)
# ---------------------------------------------------------------------------

def test_fault_aware_tables_match_bfs_when_pristine():
    """With no faults the rebuilt tables reproduce the BFS distances of
    the vertex-transitive graph, row for row."""
    from repro.core import fault_aware_next_hop
    g = BCC(2)
    scen = Scenario(policy="adaptive")      # no faults
    dist, next_hop = fault_aware_next_hop(g, scen.link_ok(g),
                                          scen.node_ok(g))
    d0 = g.distances_from_origin
    assert np.array_equal(dist[:, 0], d0[g.label_to_index(-g.labels)])
    assert np.array_equal(np.sort(dist[0]), np.sort(d0))
    # next hops step one closer
    u = np.flatnonzero(dist[:, 0] > 0)
    v = g.neighbor_indices[u, next_hop[u, 0]]
    assert np.array_equal(dist[v, 0], dist[u, 0] - 1)


def test_faulted_distances_and_saturation_degrade():
    """Dead links can only lengthen distances and add channel load: the
    degraded k̄/diameter are ≥ pristine and the degraded saturation bound
    is ≤ the pristine measured one (MC noise margin)."""
    from repro.core import (channel_load_stats, distance_stats,
                            faulted_distance_matrix,
                            measured_saturation_throughput, saturation)
    g = Torus(4, 4, 4)
    scen = Scenario.random_link_faults(g, 4, seed=7)
    assert scenario_connected(g, scen)
    dist = faulted_distance_matrix(g, scen)
    assert (dist > 0).any() and (dist[dist > 0] >= 1).all()
    dstats = distance_stats(g, scenario=scen)
    assert dstats["diameter"] >= g.diameter
    assert dstats["average_distance"] >= g.average_distance
    load = channel_load_stats(g, scenario=scen, pairs=4000, seed=1)["load"]
    assert load[~scen.link_ok(g)].sum() == 0
    sat_f = saturation(g, scenario=scen, pairs=4000)
    sat_0 = measured_saturation_throughput(g, pairs=4000)
    assert 0 < sat_f <= sat_0 * 1.05, (sat_f, sat_0)


def test_analyze_pod_reports_faulted_capacity():
    from repro.core import NetworkCondition
    from repro.topology.collective_model import PodOptions, analyze_pod
    g = BCC(2)
    scen = Scenario.random_link_faults(g, 2, seed=3)
    rep = analyze_pod("BCC2", g,
                      condition=NetworkCondition(scenario=scen, pairs=2000))
    assert rep.faulted_capacity is not None and rep.faulted_capacity > 0
    rep0 = analyze_pod("BCC2", g, options=PodOptions(routed_pairs=2000))
    assert rep0.faulted_capacity is None
    # the legacy kwargs survive as a conflict-raising shim
    legacy = analyze_pod("BCC2", g, scenario=scen, routed_pairs=2000)
    assert legacy.faulted_capacity == rep.faulted_capacity
    with pytest.raises(ValueError, match="both condition="):
        analyze_pod("BCC2", g, scenario=scen,
                    condition=NetworkCondition(scenario=scen))
    with pytest.raises(ValueError, match="both options="):
        analyze_pod("BCC2", g, routed_pairs=2000,
                    options=PodOptions(routed_pairs=2000))


def test_dead_node_scenario_masks_everything():
    """A dead node neither injects nor relays: every incident channel
    shows zero crossings in both implementations."""
    g = Torus(4, 4)
    t = build_tables(g)
    scen = Scenario(dead_nodes=(5,), policy="adaptive")
    assert scenario_connected(g, scen)
    for impl in ("batched", "reference"):
        r = simulate(g, "uniform", 0.5, slots=192, warmup=0, seed=1,
                     tables=t, impl=impl, scenario=scen)
        assert r.delivered + r.in_flight + r.dropped == r.injected
        assert int(r.link_use[5].sum()) == 0
        # incoming channels of node 5 are its neighbours' masked ports
        assert int(r.link_use[~scen.link_ok(g)].sum()) == 0
