from . import attention, common, mlp, model, ssm
from .model import (decode_step, forward, init_cache, init_params,
                    param_count, prefill)
