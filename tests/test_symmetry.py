"""Symmetry tests (paper §3, Theorem 12, Theorem 20, Appendix A)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (bcc_lift_is_never_symmetric, bcc_matrix, fcc_matrix,
                        fourd_bcc_matrix, fourd_fcc_matrix,
                        is_linear_automorphism, is_linearly_symmetric,
                        linear_stabilizer, lip_matrix, pc_matrix,
                        signed_permutation_matrices,
                        theorem12_matrix_first_family,
                        theorem12_matrix_second_family, torus_matrix)
from repro.core import intmat


def test_signed_permutation_count():
    assert sum(1 for _ in signed_permutation_matrices(3)) == 48  # 3!·2³ (Table 4)
    for P in signed_permutation_matrices(2):
        assert abs(intmat.det(P)) == 1


@pytest.mark.parametrize("a", [2, 3, 4, 5])
def test_crystals_are_symmetric(a):
    assert is_linearly_symmetric(pc_matrix(a))
    assert is_linearly_symmetric(fcc_matrix(a))
    assert is_linearly_symmetric(bcc_matrix(a))


@pytest.mark.parametrize("sides", [(4, 2, 2), (8, 4, 4), (8, 8, 4), (6, 4, 2)])
def test_mixed_radix_tori_are_not_symmetric(sides):
    assert not is_linearly_symmetric(torus_matrix(*sides))


@pytest.mark.parametrize("a", [2, 3])
def test_4d_lifts_are_symmetric(a):
    """Propositions 17, 18, 19."""
    assert is_linearly_symmetric(fourd_bcc_matrix(a))
    assert is_linearly_symmetric(fourd_fcc_matrix(a))
    assert is_linearly_symmetric(lip_matrix(a))


@given(st.integers(1, 8), st.integers(-6, 6), st.integers(-6, 6))
@settings(max_examples=40, deadline=None)
def test_theorem12_first_family_always_symmetric(a, b, c):
    M = theorem12_matrix_first_family(a, b, c)
    if intmat.det(M) == 0:
        return
    assert is_linearly_symmetric(M)


@given(st.integers(1, 8), st.integers(-6, 6), st.integers(-6, 6))
@settings(max_examples=40, deadline=None)
def test_theorem12_second_family_always_symmetric(a, b, c):
    M = theorem12_matrix_second_family(a, b, c)
    if intmat.det(M) == 0:
        return
    assert is_linearly_symmetric(M)


@pytest.mark.parametrize("a", [1, 2])
def test_theorem20_no_symmetric_bcc_lift(a):
    assert bcc_lift_is_never_symmetric(a)


def test_proposition17_cyclic_shift_is_automorphism_of_4dbcc():
    """The cyclic shift φ(e_i) = e_{i+1 mod n} is an automorphism of 4D-BCC."""
    P = np.array([[0, 0, 0, 1],
                  [1, 0, 0, 0],
                  [0, 1, 0, 0],
                  [0, 0, 1, 0]], dtype=np.int64)
    assert is_linear_automorphism(P, fourd_bcc_matrix(3))


def test_theorem11_projections_isomorphic_for_symmetric_graph():
    """All projections of a symmetric lattice graph are isomorphic: project
    BCC(a) over each e_i (by row/column swap) and compare Hermite forms of
    the resulting 2D matrices via graph invariants."""
    from repro.core import LatticeGraph
    a = 3
    M = bcc_matrix(a)
    base = None
    for i in range(3):
        Mi = M.copy()
        Mi[[i, 2], :] = Mi[[2, i], :]  # move dim i last (automorphic relabel)
        g = LatticeGraph(Mi).projection()
        key = (g.order, g.diameter, round(g.average_distance, 9),
               tuple(g.distance_distribution().tolist()))
        if base is None:
            base = key
        assert key == base


def test_stabilizer_is_group_closed():
    """Sanity: signed-permutation automorphisms are closed under product."""
    auts = linear_stabilizer(bcc_matrix(2))
    keys = {P.tobytes() for P in auts}
    for P in auts[:6]:
        for Q in auts[:6]:
            assert (P @ Q).astype(np.int64).tobytes() in keys
