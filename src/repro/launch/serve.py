"""Serving driver: batched prefill + decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params, param_count
from repro.runtime.steps import make_decode_step, make_prefill_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={param_count(params):,}")

    max_len = args.prompt_len + args.gen
    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg, sample=args.sample,
                                      temperature=args.temperature))

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.num_patch_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        batch["enc_frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        rng = jax.random.fold_in(key, i)
        tok, logits, cache = decode(params, cache, tok,
                                    jnp.int32(args.prompt_len + i), rng) \
            if args.sample else decode(params, cache, tok,
                                       jnp.int32(args.prompt_len + i))
        generated.append(tok)
    toks = jnp.concatenate(generated, axis=1)
    toks.block_until_ready()
    t_decode = time.time() - t0
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill {args.batch}×{args.prompt_len} in {t_prefill:.2f}s; "
          f"decode {args.gen - 1} steps at {tps:.1f} tok/s")
    print("sample generations (token ids):")
    for row in toks[: min(args.batch, 2)]:
        print("  ", row.tolist()[:16], "...")
    return {"tokens": toks, "tok_per_s": tps}


if __name__ == "__main__":
    main()
