"""Elastic pod scaling along the paper's §3.4 upgrade path.

PC(a) → FCC(a) → BCC(a) → PC(2a): each step doubles the machine while
conserving symmetry and "maintaining most of the original connections" (§7).
A very useful structural fact falls out of the Hermite labellings:

    PC(a)  box (a,  a,  a)   ⊂  FCC(a) box (2a, a, a)
    FCC(a) box (2a, a,  a)   ⊂  BCC(a) box (2a, 2a, a)
    BCC(a) box (2a, 2a, a)   ⊂  PC(2a) box (2a, 2a, 2a)

so every old chip's label is a valid label in the upgraded lattice.  The
upgrade plan keeps old shards in place and streams the newly required shard
halves to the new chips; `migration_stats` prices that movement with lattice
distances (the checkpoint layer consumes the plan for resharding).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import LatticeGraph, crystal_for_order


@dataclass(frozen=True)
class UpgradePlan:
    old: LatticeGraph
    new: LatticeGraph
    old_to_new_index: np.ndarray    # (N_old,) index of each old chip in new graph
    new_is_old: np.ndarray          # (N_new,) bool
    source_of_new: np.ndarray       # (N_new,) old-chip index feeding each new chip


def upgrade_plan(old_chips: int) -> UpgradePlan:
    """Plan the next doubling step for a pod of `old_chips` chips."""
    old = crystal_for_order(old_chips)
    new = crystal_for_order(old_chips * 2)
    if not (old.sides <= new.sides).all():
        raise ValueError(f"labelling boxes do not nest: {old.sides} vs {new.sides}")
    old_labels = old.labels                       # valid labels in new graph too
    old_to_new = new.label_to_index(old_labels)
    assert len(np.unique(old_to_new)) == old.order
    new_is_old = np.zeros(new.order, dtype=bool)
    new_is_old[old_to_new] = True
    # each fresh chip pulls its shard from the nearest old chip (in the NEW
    # lattice metric — the wires that actually exist after the upgrade)
    source = np.empty(new.order, dtype=np.int64)
    source[old_to_new] = np.arange(old.order)
    fresh = np.where(~new_is_old)[0]
    dist_from = new.distances_from_origin
    new_labels = new.labels
    for idx in fresh:
        deltas = old_labels - new_labels[idx]
        d = dist_from[new.label_to_index(deltas)]
        source[idx] = int(np.argmin(d))
    return UpgradePlan(old=old, new=new, old_to_new_index=old_to_new,
                       new_is_old=new_is_old, source_of_new=source)


def migration_stats(plan: UpgradePlan) -> dict:
    """Hop statistics of the shard migration the upgrade implies."""
    new = plan.new
    old_pos = plan.old_to_new_index[plan.source_of_new]
    hops = []
    dist = new.distances_from_origin
    for idx in np.where(~plan.new_is_old)[0]:
        delta = new.labels[old_pos[idx]] - new.labels[idx]
        hops.append(int(dist[new.label_to_index(delta)]))
    hops = np.asarray(hops)
    return {
        "fresh_chips": int((~plan.new_is_old).sum()),
        "avg_hops": float(hops.mean()),
        "max_hops": int(hops.max()),
        "diameter_new": new.diameter,
    }


def upgrade_path_names(start: int, steps: int) -> list[str]:
    kinds = {0: "PC", 1: "FCC", 2: "BCC"}
    out = []
    n = start
    for _ in range(steps + 1):
        t = n.bit_length() - 1
        out.append(f"{kinds[t % 3]}({2 ** (t // 3)}) [{n} chips]")
        n *= 2
    return out
