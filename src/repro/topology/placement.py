"""Logical-mesh → physical-lattice placement.

A (data=16, model=16) logical mesh must be laid onto the 256 chips of a pod
whose ICI network is BCC(4) (Hermite box 8×8×4).  Each logical axis becomes a
ring of physical chips; ring collectives run at full link speed only when
consecutive ring members are lattice neighbours (dilation 1).

`embed_mesh` builds a parametric family of embeddings from the projection
hierarchy (Definition 7): the Hermite box is split into per-axis digit
groups, each traversed in Gray order so consecutive logical neighbours move
by one lattice step whenever the box dimension allows it.  `axis_dilation`
measures the result with the paper's distance metric; `best_embedding`
searches the family.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass

import numpy as np

from repro.core import LatticeGraph


def _gray_sequence(size: int) -> np.ndarray:
    """Boustrophedon (snake) order 0..size-1 — adjacent entries differ by 1
    step; used to traverse each lattice dimension."""
    return np.arange(size)


def _mixed_radix_snake(sizes: list[int]) -> np.ndarray:
    """All coordinate tuples of the mixed-radix box in snake order so that
    consecutive tuples differ by ±1 in exactly one digit.  Returns
    (prod(sizes), len(sizes))."""
    total = int(np.prod(sizes))
    out = np.zeros((total, len(sizes)), dtype=np.int64)
    for idx in range(total):
        rem = idx
        digits = []
        for s in reversed(sizes):
            digits.append(rem % s)
            rem //= s
        digits.reverse()
        # snake: reverse digit direction when the prefix parity is odd
        coord = []
        parity = 0
        for d, s in zip(digits, sizes):
            c = s - 1 - d if parity % 2 else d
            coord.append(c)
            parity += d
        out[idx] = coord
    return out


@dataclass(frozen=True)
class Embedding:
    """labels[axis0_index, axis1_index] → physical lattice label."""
    name: str
    coords: np.ndarray            # (size0, size1, n) lattice labels
    axis_sizes: tuple[int, int]


def embed_mesh(g: LatticeGraph, axis_sizes: tuple[int, int],
               dim_split: tuple[tuple[int, ...], tuple[int, ...]]) -> Embedding:
    """Assign logical (i, j) → lattice label by giving each logical axis a
    set of lattice dimensions (dim_split) whose Hermite sides multiply to the
    axis size; each axis traverses its dims in snake order."""
    sides = g.sides
    n = g.n
    s0 = [int(sides[d]) for d in dim_split[0]]
    s1 = [int(sides[d]) for d in dim_split[1]]
    assert int(np.prod(s0)) == axis_sizes[0], (s0, axis_sizes)
    assert int(np.prod(s1)) == axis_sizes[1], (s1, axis_sizes)
    path0 = _mixed_radix_snake(s0)      # (size0, |dims0|)
    path1 = _mixed_radix_snake(s1)
    coords = np.zeros((axis_sizes[0], axis_sizes[1], n), dtype=np.int64)
    for i in range(axis_sizes[0]):
        for j in range(axis_sizes[1]):
            lab = np.zeros(n, dtype=np.int64)
            for d, c in zip(dim_split[0], path0[i]):
                lab[d] = c
            for d, c in zip(dim_split[1], path1[j]):
                lab[d] = c
            coords[i, j] = lab
    return Embedding(
        name=f"dims{dim_split[0]}x{dim_split[1]}",
        coords=coords, axis_sizes=axis_sizes)


def axis_dilation(g: LatticeGraph, emb: Embedding, axis: int) -> dict:
    """Ring dilation stats for one logical axis: lattice distance between
    ring-consecutive chips (including the wrap edge), averaged over the other
    axis."""
    coords = emb.coords if axis == 0 else emb.coords.transpose(1, 0, 2)
    k, other, n = coords.shape
    hops = []
    for j in range(other):
        ring = coords[:, j]
        nxt = np.roll(ring, -1, axis=0)
        d = [g.distance(ring[t], nxt[t]) for t in range(k)]
        hops.append(d)
    hops = np.asarray(hops, dtype=np.float64)
    return {"avg": float(hops.mean()), "max": float(hops.max()),
            "wrap": float(hops[:, -1].mean())}


def enumerate_dim_splits(g: LatticeGraph, axis_sizes: tuple[int, int]):
    """All ways to partition the lattice dimensions into two groups whose
    side products equal the two logical axis sizes."""
    n = g.n
    sides = [int(s) for s in g.sides]
    for r in range(1, n):
        for dims0 in itertools.combinations(range(n), r):
            dims1 = tuple(d for d in range(n) if d not in dims0)
            if int(np.prod([sides[d] for d in dims0])) == axis_sizes[0] and \
               int(np.prod([sides[d] for d in dims1])) == axis_sizes[1]:
                yield (dims0, dims1)


def best_embedding(g: LatticeGraph, axis_sizes: tuple[int, int] = (16, 16)):
    """Search the snake-embedding family; minimize summed average dilation.

    For boxes whose sides don't factor into the axis sizes (e.g. BCC(4)'s
    8×8×4 box for a 16×16 mesh), axes are built from digit *pairs* by
    splitting one dimension across both axes: we extend the search with
    factor-split variants."""
    candidates = []
    for split in enumerate_dim_splits(g, axis_sizes):
        emb = embed_mesh(g, axis_sizes, split)
        d0 = axis_dilation(g, emb, 0)
        d1 = axis_dilation(g, emb, 1)
        candidates.append((d0["avg"] + d1["avg"], emb, d0, d1))
    # factor-split fallback: chop the largest dimension between both axes
    if not candidates:
        candidates.extend(_factor_split_embeddings(g, axis_sizes))
    if not candidates:
        raise ValueError("no embedding found")
    candidates.sort(key=lambda c: c[0])
    score, emb, d0, d1 = candidates[0]
    return {"embedding": emb, "score": score, "axis0": d0, "axis1": d1}


def _factor_split_embeddings(g: LatticeGraph, axis_sizes: tuple[int, int]):
    """Embeddings where one lattice dimension contributes a factor to each
    logical axis (needed when no clean dimension partition exists, e.g.
    8×8×4 → 16×16 uses dims (0) × (1) and splits dim 2 as 2×2)."""
    out = []
    sides = [int(s) for s in g.sides]
    n = g.n
    for split_dim in range(n):
        s = sides[split_dim]
        for f0 in (2, 4, 8):
            if s % f0:
                continue
            f1 = s // f0
            rest = [d for d in range(n) if d != split_dim]
            for r in range(len(rest) + 1):
                for dims0 in itertools.combinations(rest, r):
                    dims1 = tuple(d for d in rest if d not in dims0)
                    p0 = int(np.prod([sides[d] for d in dims0])) * f0
                    p1 = int(np.prod([sides[d] for d in dims1])) * f1
                    if (p0, p1) != axis_sizes:
                        continue
                    emb = _split_embed(g, axis_sizes, dims0, dims1,
                                       split_dim, f0, f1)
                    from_ = axis_dilation(g, emb, 0)
                    to_ = axis_dilation(g, emb, 1)
                    out.append((from_["avg"] + to_["avg"], emb, from_, to_))
    return out


def _split_embed(g, axis_sizes, dims0, dims1, split_dim, f0, f1):
    sides = [int(s) for s in g.sides]
    n = g.n
    s0 = [sides[d] for d in dims0] + [f0]
    s1 = [sides[d] for d in dims1] + [f1]
    path0 = _mixed_radix_snake(s0)
    path1 = _mixed_radix_snake(s1)
    coords = np.zeros((axis_sizes[0], axis_sizes[1], n), dtype=np.int64)
    for i in range(axis_sizes[0]):
        for j in range(axis_sizes[1]):
            lab = np.zeros(n, dtype=np.int64)
            for d, c in zip(dims0, path0[i][:-1]):
                lab[d] = c
            for d, c in zip(dims1, path1[j][:-1]):
                lab[d] = c
            lab[split_dim] = path0[i][-1] * f1 + path1[j][-1]
            coords[i, j] = lab
    return Embedding(
        name=f"dims{dims0}+{f0}|{dims1}+{f1}@d{split_dim}",
        coords=coords, axis_sizes=axis_sizes)
