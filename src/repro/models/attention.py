"""Grouped-query attention with optional qk-norm, RoPE, KV caches.

Three entry points matching the three shape kinds:
  * `attend_train`  — full causal self-attention over a sequence,
  * `attend_prefill` — same, but also returns the KV cache,
  * `attend_decode` — one query token against a cached context.

`impl="xla"` uses the pure-jnp path (what the dry-run lowers, so the roofline
reads dot_general FLOPs); `impl="pallas"` dispatches to the blocked Pallas
kernels in repro.kernels (TPU target, validated in interpret mode).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import apply_rope, cast_compute, rms_norm


class AttnParams(NamedTuple):
    wq: jax.Array          # (D, H * hd)
    wk: jax.Array          # (D, KV * hd)
    wv: jax.Array          # (D, KV * hd)
    wo: jax.Array          # (H * hd, D)
    q_norm: jax.Array      # (hd,) or (0,)
    k_norm: jax.Array      # (hd,) or (0,)


def init_attn(key, cfg) -> AttnParams:
    from .common import dense_init
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    qk = jnp.ones((hd,), jnp.float32) if cfg.qk_norm else jnp.zeros((0,), jnp.float32)
    return AttnParams(
        wq=dense_init(kq, cfg.d_model, cfg.num_heads * hd),
        wk=dense_init(kk, cfg.d_model, cfg.num_kv_heads * hd),
        wv=dense_init(kv, cfg.d_model, cfg.num_kv_heads * hd),
        wo=dense_init(ko, cfg.num_heads * hd, cfg.d_model),
        q_norm=qk, k_norm=qk)


def _project_qkv(p: AttnParams, cfg, x, positions, rope: bool = True):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ cast_compute(p.wq)).reshape(B, S, cfg.num_heads, hd)
    k = (x @ cast_compute(p.wk)).reshape(B, S, cfg.num_kv_heads, hd)
    v = (x @ cast_compute(p.wv)).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p.q_norm, cfg.norm_eps)
        k = rms_norm(k, p.k_norm, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, causal: bool, q_offset=0):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) — GQA broadcast, fp32 softmax."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    groups = H // KV
    qg = q.reshape(B, Sq, KV, groups, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        mask = qpos[:, None] >= jnp.arange(Sk)[None, :]
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, hd)


def _sdpa_chunked(q, k, v, causal: bool, block_q: int = 512):
    """XLA-flash: lax.scan over query blocks so only a (bq × Sk) score slab is
    live at a time instead of the full (Sq × Sk) matrix.  Numerically equal to
    `_sdpa` (each row's softmax still sees its whole key range)."""
    B, Sq, H, hd = q.shape
    block_q = min(block_q, Sq)
    if Sq % block_q:
        return _sdpa(q, k, v, causal)
    nq = Sq // block_q
    qb = q.reshape(B, nq, block_q, H, hd).transpose(1, 0, 2, 3, 4)

    def body(_, args):
        i, qblk = args                                 # qblk: (B, bq, H, hd)
        offset = i * block_q
        out = _sdpa(qblk, k, v, causal, q_offset=offset)
        return None, out

    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qb))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def attend_train(p: AttnParams, cfg, x, positions, causal=True, impl="xla",
                 rope=True):
    B, S, D = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions, rope=rope)
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal)
    elif impl == "chunked":
        out = _sdpa_chunked(q, k, v, causal=causal)
    else:
        out = _sdpa(q, k, v, causal=causal)
    return out.reshape(B, S, -1) @ cast_compute(p.wo)


def attend_prefill(p: AttnParams, cfg, x, positions, impl="xla", rope=True):
    """Returns (output, (k_cache, v_cache)) with cache length = S."""
    B, S, D = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions, rope=rope)
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True)
    elif impl == "chunked":
        out = _sdpa_chunked(q, k, v, causal=True)
    else:
        out = _sdpa(q, k, v, causal=True)
    return out.reshape(B, S, -1) @ cast_compute(p.wo), (k, v)


def attend_decode(p: AttnParams, cfg, x, cache, position, impl="xla",
                  rope=True):
    """x: (B, 1, D); cache: (k, v) each (B, S_max, KV, hd); position: scalar
    int32 index of the new token.  Returns (out, updated cache)."""
    B, one, D = x.shape
    k_cache, v_cache = cache
    pos = jnp.full((B, 1), position, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, pos, rope=rope)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), position, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), position, axis=1)
    S_max = k_cache.shape[1]
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.decode_attention(q, k_cache, v_cache, position)
    else:
        # mask out cache slots beyond `position`
        hd = cfg.resolved_head_dim
        KV = cfg.num_kv_heads
        groups = cfg.num_heads // KV
        qg = q.reshape(B, 1, KV, groups, hd)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_cache).astype(jnp.float32)
        scores = scores / jnp.sqrt(hd).astype(jnp.float32)
        valid = jnp.arange(S_max)[None, None, None, None, :] <= position
        scores = jnp.where(valid, scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskh->bqkgh", w, v_cache).reshape(B, 1, -1)
    return out @ cast_compute(p.wo), (k_cache, v_cache)


def attend_cross(p: AttnParams, cfg, x, enc_kv, impl="xla"):
    """Cross-attention against precomputed encoder K/V (no RoPE, no mask)."""
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ cast_compute(p.wq)).reshape(B, S, cfg.num_heads, hd)
    k, v = enc_kv
    out = _sdpa_chunked(q, k, v, causal=False) if impl == "chunked" \
        else _sdpa(q, k, v, causal=False)
    return out.reshape(B, S, -1) @ cast_compute(p.wo)


def cross_kv(p: AttnParams, cfg, enc_out):
    """Precompute cross-attention K/V from encoder output."""
    B, S, D = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ cast_compute(p.wk)).reshape(B, S, cfg.num_kv_heads, hd)
    v = (enc_out @ cast_compute(p.wv)).reshape(B, S, cfg.num_kv_heads, hd)
    return k, v
