"""Jit'd public wrappers around the Pallas kernels.

These adapt model-layout tensors (GQA heads, chunked SSD) to kernel layouts,
fall back to interpret mode off-TPU (this container is CPU-only; TPU is the
target), and keep the jnp oracles in repro.kernels.ref as ground truth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import rmsnorm as _rms
from . import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) — GQA folded by repeating KV."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out = _fa.flash_attention(
        fold(q), fold(k), fold(v), causal=causal,
        block_q=block_q, block_k=block_k, interpret=not _on_tpu())
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k_cache, v_cache, position, *, block_k: int = 512):
    """q: (B, 1, H, hd); caches: (B, S_max, KV, hd); position scalar int32."""
    B, one, H, hd = q.shape
    S = k_cache.shape[1]
    KV = k_cache.shape[2]
    if KV != H:
        k_cache = jnp.repeat(k_cache, H // KV, axis=2)
        v_cache = jnp.repeat(v_cache, H // KV, axis=2)
    qf = jnp.broadcast_to(
        q.transpose(0, 2, 1, 3).reshape(B * H, 1, hd),
        (B * H, _dec.Q_PAD, hd))                      # pad query to 8 rows
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out = _dec.decode_attention(qf, kf, vf, position, block_k=block_k,
                                interpret=not _on_tpu())
    return out[:, :1, :].reshape(B, H, 1, hd).transpose(0, 2, 1, 3) \
        .reshape(B, 1, H * hd)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(xdt, Adt, Bm, Cm, *, chunk: int = 256):
    """Full SSD using the intra-chunk Pallas kernel + XLA inter-chunk scan.

    xdt: (B, S, H, P); Adt: (B, S, H); Bm, Cm: (B, S, G, N).
    Returns y (B, S, H, P) and final state (B, H, P, N)."""
    B, S, H, P = xdt.shape
    G, N = Bm.shape[2], Bm.shape[3]
    if S % chunk:
        pad = chunk - S % chunk
        padt = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        y, final = ssd(padt(xdt), padt(Adt), padt(Bm), padt(Cm), chunk=chunk)
        return y[:, :S], final
    nc = S // chunk
    rep = H // G
    # fold (B, H) and slice chunks
    xk = xdt.transpose(0, 2, 1, 3).reshape(B * H, nc, chunk, P)
    ak = Adt.transpose(0, 2, 1).reshape(B * H, nc, chunk)
    Bh = jnp.repeat(Bm, rep, axis=2)                  # (B, S, H, N)
    Ch = jnp.repeat(Cm, rep, axis=2)
    bk = Bh.transpose(0, 2, 1, 3).reshape(B * H, nc, chunk, N)
    ck = Ch.transpose(0, 2, 1, 3).reshape(B * H, nc, chunk, N)

    y_diag, states, chunk_sum = _ssd.ssd_intra_chunk(
        xk, ak, bk, ck, interpret=not _on_tpu())

    # inter-chunk recurrence (cheap, O(nc)) in XLA
    decay = jnp.exp(chunk_sum)                        # (BH, nc)

    def step(carry, t):
        st, dec = t
        new = carry * dec[:, None, None] + st.astype(jnp.float32)
        return new, carry

    init = jnp.zeros((B * H, P, N), jnp.float32)
    final, prev = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3), decay.T))
    prev = prev.transpose(1, 0, 2, 3)                 # (BH, nc, P, N)

    # y_off: rank-N correction from the carried-in state
    a_cum = jnp.cumsum(ak.astype(jnp.float32), axis=-1)     # (BH, nc, Q)
    y_off = jnp.einsum("bcqn,bcpn,bcq->bcqp", ck, prev,
                       jnp.exp(a_cum)).astype(xdt.dtype)
    y = (y_diag + y_off).reshape(B, H, nc * chunk, P).transpose(0, 2, 1, 3)
    final = final.reshape(B, H, P, N).astype(xdt.dtype)
    return y, final


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, weight, *, eps: float = 1e-5, block_rows: int = 256):
    return _rms.rmsnorm(x, weight, eps=eps, block_rows=block_rows,
                        interpret=not _on_tpu())
