"""End-to-end training example: ~100M-param dense LM for a few hundred steps
on CPU, with checkpointing and restart.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-100m", action="store_true",
                    help="train the real ~100M config (slow on CPU)")
    args = ap.parse_args()
    if args.full_100m:
        # olmo-1b config cut to ~100M: full d_model/vocab, 2 layers
        argv = ["--arch", "olmo-1b", "--steps", str(args.steps),
                "--batch", "8", "--seq", "512", "--ckpt", "/tmp/repro_100m"]
    else:
        argv = ["--arch", "olmo-1b", "--reduced", "--steps", str(args.steps),
                "--batch", "16", "--seq", "128", "--ckpt", "/tmp/repro_tiny"]
    out = train_main(argv)
    assert out["last_loss"] < out["first_loss"], "loss did not fall!"
    print(f"loss {out['first_loss']:.3f} → {out['last_loss']:.3f}  ✓")
