"""Multi-objective candidate evaluator.

Every score flows through the unified analytic surface
(`repro.core.NetworkCondition` + the `saturation` facade):

  * **throughput** — Monte-Carlo saturation of the pristine (or
    heterogeneous, when the candidate carries a `LinkSpec`) fabric:
    ``saturation(g, NetworkCondition(links=...))``;
  * **faulted capacity** — the WORST-epoch saturation under the
    canonical `FaultSchedule` (k seeded link fault/repair events —
    deterministic per candidate order and seed):
    ``min(saturation(g, NetworkCondition(schedule=...)))``;
  * **p99 latency** at the fixed offered load: in ``mode="sim"`` the
    slot-level simulator's exact bucketed percentile
    (`simulate_sweep` — the whole loads × seeds cell is ONE compiled
    program), in ``mode="analytic"`` a deterministic closed-form proxy
    (p99 pairwise distance inflated by the M/D/1-style queueing factor
    ``1/(1 − load/θ)``) that costs no compilation — the CI-budget and
    property-test path.

Evaluations are memoised by `Candidate.key()` (the HNF equivalence
class + parameters), so re-encountering a candidate across generations
is free, and the memo rides the optimizer checkpoint.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from repro.core import (FaultSchedule, LatticeGraph, NetworkCondition,
                        SimConfig, saturation)
from repro.core.distances import weighted_distance_matrix

from .pareto import Objectives
from .space import Candidate

EVAL_MODES = ("analytic", "sim")


@dataclass(frozen=True)
class EvalSettings:
    """Frozen evaluation protocol — one per explorer run, shared by every
    candidate and baseline so scores are comparable."""

    mode: str = "analytic"
    load: float = 0.30          # offered load for the p99 objective
    pairs: int = 4096           # Monte-Carlo pairs per channel-load walk
    seed: int = 0
    backend: str = "host"       # every candidate is a DISTINCT graph, so
    # the device BFS compile cache never hits; host tables are identical
    # and ~200x cheaper at explorer scale (N <= a few hundred)
    fault_links: int = 4        # canonical-schedule fault/repair events
    slots: int = 256            # schedule horizon + simulator run length
    warmup: int = 64
    hist_bins: int = 24
    sim_seeds: int = 2          # replication axis of the one-compile sweep

    def __post_init__(self):
        if self.mode not in EVAL_MODES:
            raise ValueError(
                f"unknown eval mode {self.mode!r}; expected one of "
                f"{EVAL_MODES}")
        if self.backend not in ("auto", "device", "host"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if not 0 < self.load < 1:
            raise ValueError(f"need 0 < load < 1, got {self.load}")
        if self.pairs <= 0 or self.slots <= 0 or self.sim_seeds <= 0:
            raise ValueError("pairs, slots and sim_seeds must be positive")

    def replace(self, **changes) -> "EvalSettings":
        return replace(self, **changes)

    def to_json(self) -> dict:
        return {"mode": self.mode, "load": self.load, "pairs": self.pairs,
                "seed": self.seed, "backend": self.backend,
                "fault_links": self.fault_links,
                "slots": self.slots, "warmup": self.warmup,
                "hist_bins": self.hist_bins, "sim_seeds": self.sim_seeds}

    @classmethod
    def from_json(cls, d: dict) -> "EvalSettings":
        return cls(mode=d["mode"], load=float(d["load"]),
                   pairs=int(d["pairs"]), seed=int(d["seed"]),
                   backend=d["backend"],
                   fault_links=int(d["fault_links"]), slots=int(d["slots"]),
                   warmup=int(d["warmup"]), hist_bins=int(d["hist_bins"]),
                   sim_seeds=int(d["sim_seeds"]))


def canonical_schedule(g: LatticeGraph,
                       settings: EvalSettings) -> FaultSchedule:
    """The shared resilience workload: `fault_links` seeded link
    fault/repair events over the settings horizon — identical event
    *process* for every candidate (the realised links differ with the
    topology, as they must: the schedule names real channels)."""
    return FaultSchedule.random_events(
        g, settings.fault_links, settings.slots, seed=settings.seed)


class Evaluator:
    """Memoised multi-objective scorer.  `evaluate` returns the
    `Objectives` for one candidate; failures (a schedule that
    disconnects the graph, an invalid feature combination) score
    `Objectives.worst()` rather than killing the search."""

    def __init__(self, settings: EvalSettings | None = None):
        self.settings = settings or EvalSettings()
        self.memo: dict[tuple, Objectives] = {}
        self._memo_cands: list[tuple[Candidate, Objectives]] = []
        self.evaluations = 0        # cache-miss count (the costly ones)

    # -- the three objectives ----------------------------------------------
    def _throughput(self, g: LatticeGraph, cand: Candidate) -> float:
        s = self.settings
        return float(saturation(g, NetworkCondition(
            links=cand.link_spec(), pairs=s.pairs, seed=s.seed,
            backend=s.backend)))

    def _faulted(self, g: LatticeGraph, cand: Candidate) -> float:
        s = self.settings
        sat = saturation(g, NetworkCondition(
            schedule=canonical_schedule(g, s), links=cand.link_spec(),
            slots=s.slots, pairs=s.pairs, seed=s.seed,
            backend=s.backend))
        return float(np.nanmin(np.asarray(sat)))

    def _p99_sim(self, g: LatticeGraph, cand: Candidate) -> float:
        from repro.core.simulation import simulate_sweep
        s = self.settings
        cfg = SimConfig(slots=s.slots, warmup=s.warmup, queue=cand.queue,
                        seed=s.seed, vcs=cand.vcs, credits=cand.credits,
                        hist_bins=s.hist_bins, links=cand.link_spec())
        sweep = simulate_sweep(g, "uniform", [s.load], config=cfg,
                               seeds=s.sim_seeds)
        return float(sweep.latency_percentile(0.99)[0])

    def _p99_analytic(self, g: LatticeGraph, cand: Candidate,
                      throughput: float) -> float:
        """Deterministic proxy: the 99th-percentile pairwise hop/slot
        cost, inflated by the M/D/1-flavoured queueing factor at the
        fixed offered load (utilisation clamped below 1)."""
        s = self.settings
        ls = cand.link_spec()
        if ls is None:
            d = np.asarray(g.distances_from_origin)
        else:
            d = weighted_distance_matrix(g, ls)
        d = d[d > 0]
        if d.size == 0:
            return float("inf")
        hop99 = float(np.percentile(d, 99))
        util = min(s.load / max(throughput, 1e-9), 0.95)
        return hop99 / (1.0 - util)

    # -- entry points -------------------------------------------------------
    def evaluate(self, cand: Candidate) -> Objectives:
        key = cand.key()
        hit = self.memo.get(key)
        if hit is not None:
            return hit
        self.evaluations += 1
        g = cand.graph()
        try:
            throughput = self._throughput(g, cand)
            faulted = self._faulted(g, cand)
            p99 = (self._p99_sim(g, cand) if self.settings.mode == "sim"
                   else self._p99_analytic(g, cand, throughput))
            obj = Objectives(throughput=throughput, p99=p99,
                             faulted=faulted)
        except (ValueError, AssertionError):
            # disconnected under the canonical schedule / no reachable
            # pairs / unsupported feature combination → worst, not fatal
            obj = Objectives.worst()
        self.memo[key] = obj
        self._memo_cands.append((cand, obj))
        return obj

    def evaluate_many(self, cands) -> list[Objectives]:
        """Batch entry point: scores in candidate order (memo makes the
        repeat visits free; distinct graphs still compile separately —
        the one-compile batching lives inside each candidate's
        loads × seeds sweep cell)."""
        return [self.evaluate(c) for c in cands]

    # -- memo persistence (rides the optimizer checkpoint) ------------------
    def memo_to_json(self) -> list:
        return [[c.to_json(), o.to_json()]
                for c, o in self._memo_items()]

    def _memo_items(self):
        # memo keys are Candidate.key() tuples; keep a parallel candidate
        # for serialisation by re-deriving from insertion order
        return self._memo_cands

    def load_memo(self, items: list) -> None:
        for cand_json, obj_json in items:
            cand = Candidate.from_json(cand_json)
            obj = Objectives.from_json(obj_json)
            self.memo[cand.key()] = obj
            self._memo_cands.append((cand, obj))
