"""Mamba2-2.7B [arXiv:2405.21060]: pure SSD (state-space duality), attention
free."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm=SSMConfig(state_size=128),
)
