"""Batched JAX routing engine: cross-validation against the numpy oracle,
the exact CVP bruteforce, BFS distances, and the Remark-30 tie policy.

Contract under test (see repro/core/routing_engine.py):
  * deterministic path is bitwise-equal to the numpy HierarchicalRouter
    (both the tabulated and the unrolled-recursion code paths),
  * every record satisfies r ≡ v (mod M) and |r|₁ = d_G(0, v),
  * keyed path stays norm-minimal and splits exact ties ~50/50,
  * key=None / rng=None paths are deterministic.
"""
import numpy as np
import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (BCC, FCC, RTT, HierarchicalRouter, LatticeGraph,
                        RoutingEngine, bcc_matrix, fcc_matrix, make_router,
                        minimal_record_bruteforce, norm1, rtt_matrix)
from repro.core import routing_engine as eng_mod
from repro.core import routing as routing_np

RNG = np.random.default_rng(11)


def random_pairs(g: LatticeGraph, trials: int):
    s = g.labels[RNG.integers(0, g.order, trials)]
    d = g.labels[RNG.integers(0, g.order, trials)]
    return d - s


def assert_engine_exact(g: LatticeGraph, eng: RoutingEngine, trials=1500):
    v = random_pairs(g, trials)
    r = eng(v)
    assert (g.label_to_index(r) == g.label_to_index(v)).all(), "invalid record"
    dist = g.distances_from_origin[g.label_to_index(v)]
    assert (norm1(r) == dist).all(), "non-minimal record"


# ---------------------------------------------------------------------------
# named graphs: engine ≡ numpy router ≡ BFS, both engine code paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M", [
    rtt_matrix(4), rtt_matrix(5), fcc_matrix(2), fcc_matrix(3),
    bcc_matrix(2), bcc_matrix(3),
    np.array([[4, 0, 0], [0, 4, 2], [0, 0, 4]]),     # Example 10
    np.array([[6, 3, 1], [0, 5, 2], [0, 0, 4]]),     # generic HNF
], ids=["RTT4", "RTT5", "FCC2", "FCC3", "BCC2", "BCC3", "Ex10", "HNF654"])
def test_engine_bitwise_equals_numpy_router(M):
    g = LatticeGraph(M)
    hr = HierarchicalRouter(M)
    eng = RoutingEngine(M)
    v = random_pairs(g, 1200)
    r_np = hr(v)
    assert np.array_equal(eng(v), r_np), "tabulated path diverged"
    assert np.array_equal(eng.route_recursive(v), r_np), "recursion diverged"
    assert_engine_exact(g, eng)


def test_closed_form_jnp_ports_match_numpy():
    v3 = RNG.integers(-30, 30, size=(400, 3))
    for a in (2, 3, 4):
        assert np.array_equal(routing_np.route_fcc(a, v3),
                              np.asarray(eng_mod.route_fcc(a, v3)))
        assert np.array_equal(routing_np.route_bcc(a, v3),
                              np.asarray(eng_mod.route_bcc(a, v3)))
        assert np.array_equal(routing_np.route_rtt(a, v3[:, :2]),
                              np.asarray(eng_mod.route_rtt(a, v3[:, :2])))
        assert np.array_equal(
            routing_np.route_torus((2 * a, a, 3), v3),
            np.asarray(eng_mod.route_torus((2 * a, a, 3), v3)))


def test_make_router_dispatch():
    assert isinstance(make_router(fcc_matrix(2), "numpy"), HierarchicalRouter)
    assert isinstance(make_router(fcc_matrix(2), "jax"), RoutingEngine)
    assert isinstance(make_router(fcc_matrix(2)), RoutingEngine)
    with pytest.raises(ValueError):
        make_router(fcc_matrix(2), "tpu-pod")


# ---------------------------------------------------------------------------
# property tests: ≥10 random Hermite-normal-form matrices
# ---------------------------------------------------------------------------

def hnf_matrices(n: int, max_side: int = 5):
    """Random upper-triangular HNF matrices: positive diagonal d_i ≤ max_side
    and 0 ≤ H[i, j] < H[i, i] for j > i (Definition 8)."""
    def build(flat):
        H = np.zeros((n, n), dtype=np.int64)
        it = iter(flat)
        for i in range(n):
            H[i, i] = 1 + next(it) % max_side
            for j in range(i + 1, n):
                H[i, j] = next(it) % H[i, i]
        return H
    return st.lists(st.integers(0, 10 * max_side),
                    min_size=n * n, max_size=n * n).map(build)


@given(hnf_matrices(3))
@settings(max_examples=25, deadline=None)
def test_engine_on_random_hnf_matches_oracles(H):
    g = LatticeGraph(H)
    eng = RoutingEngine(H)
    hr = HierarchicalRouter(H)
    v = random_pairs(g, 300)
    r = eng(v)
    # bitwise vs numpy reference
    assert np.array_equal(r, hr(v))
    # r ≡ v (mod M) congruence
    assert (g.label_to_index(r) == g.label_to_index(v)).all()
    # norm-minimality vs the exact CVP bruteforce (box from diameter bound)
    sub = v[:40]
    rb = minimal_record_bruteforce(H, sub, box=int(np.abs(sub).max()) + 1)
    assert np.array_equal(norm1(eng(sub)), norm1(rb))


@given(hnf_matrices(2, max_side=7))
@settings(max_examples=25, deadline=None)
def test_engine_on_random_2d_hnf(H):
    g = LatticeGraph(H)
    eng = RoutingEngine(H)
    assert_engine_exact(g, eng, trials=300)


# ---------------------------------------------------------------------------
# Remark 30: randomized tie-breaking balance
# ---------------------------------------------------------------------------

def test_remark30_tie_balance_fcc_antipodal():
    """Over antipodal pairs of FCC(a) with two equal-norm records, the keyed
    router must pick each minimal record ~50% of the time (45–55% over 10k
    samples), and the key-free path must stay deterministic."""
    a = 2
    g = FCC(a)
    dist = g.distances_from_origin
    far = g.labels[dist == dist.max()]
    # keep the pairs whose two closed-form candidates genuinely tie
    v = far
    det = np.asarray(routing_np.route_fcc(a, v))
    samples = 10_000
    vv = np.broadcast_to(v, (samples,) + v.shape).reshape(-1, 3)
    out = np.asarray(eng_mod.route_fcc(a, vv, key=jax.random.PRNGKey(3)))
    out = out.reshape(samples, -1, 3)
    picked_det = (out == det[None]).all(axis=-1)          # (samples, P)
    frac = picked_det.mean(axis=0)
    tied = ~np.isclose(frac, 1.0)                         # pairs with a real tie
    assert tied.any(), "expected at least one antipodal tie in FCC(2)"
    assert (frac[tied] > 0.45).all() and (frac[tied] < 0.55).all(), frac
    # all samples remain minimal records for their difference
    nrm = np.abs(out).sum(-1)
    want = dist[g.label_to_index(v)]
    assert (nrm == want[None, :]).all()


def test_remark30_engine_keyed_hierarchical():
    """The generic engine's keyed path: minimal, congruent, and balanced on
    half-ring ties of a torus block."""
    M = bcc_matrix(2)
    g = LatticeGraph(M)
    eng = RoutingEngine(M)
    v = random_pairs(g, 500)
    dist = g.distances_from_origin[g.label_to_index(v)]
    r = eng(v, key=jax.random.PRNGKey(0))
    assert (norm1(r) == dist).all()
    assert (g.label_to_index(r) == g.label_to_index(v)).all()
    # a half-ring difference in the base torus T(4,4) of BCC(2): both signs
    # minimal; over many keys each direction should appear ~half the time
    v_half = np.tile([2, 0, 0], (10_000, 1))
    rr = eng(v_half, key=jax.random.PRNGKey(7))
    frac = (rr[:, 0] > 0).mean()
    assert 0.45 < frac < 0.55, frac


def test_keyfree_paths_are_deterministic():
    g = FCC(3)
    eng = RoutingEngine(fcc_matrix(3))
    v = random_pairs(g, 400)
    assert np.array_equal(eng(v), eng(v))
    assert np.array_equal(eng.route_recursive(v), eng.route_recursive(v))
    assert np.array_equal(routing_np.route_fcc(3, v),
                          routing_np.route_fcc(3, v))
    # same key → same coins; different key → (almost surely) some difference
    k = jax.random.PRNGKey(5)
    assert np.array_equal(eng(v, key=k), eng(v, key=k))


# ---------------------------------------------------------------------------
# bruteforce clamp regression (ISSUE 1 satellite)
# ---------------------------------------------------------------------------

def test_bruteforce_unclamped_is_exact_for_large_v():
    """Regression: the old silent `box = min(box, 6)` clamp made the oracle
    return 95 − 6·10 = 35 for the ring Z_10 at v = 95; the true minimal
    record has norm 5 (u ∈ {9, 10} lies outside the clamped box)."""
    M = [[10]]
    r = minimal_record_bruteforce(M, np.array([95]))
    assert np.abs(r).sum() == 5
    # 2-D: v = (80, 80) in Z_9 × Z_9 needs u = (9, 9), norm 2 instead of 52
    M2 = [[9, 0], [0, 9]]
    r2 = minimal_record_bruteforce(M2, np.array([80, 80]))
    assert np.abs(r2).sum() == 2


def test_bruteforce_optin_clamp_warns():
    with pytest.warns(UserWarning, match="clamping"):
        r = minimal_record_bruteforce([[10]], np.array([95]), max_box=6)
    assert r.tolist() == [35]          # documented wrong-under-clamp result


def test_bruteforce_agrees_with_engine_inside_box():
    M = fcc_matrix(3)
    g = LatticeGraph(M)
    eng = RoutingEngine(M)
    v = random_pairs(g, 60)
    rb = minimal_record_bruteforce(M, v, box=4)
    assert np.array_equal(norm1(eng(v)), norm1(rb))


# ---------------------------------------------------------------------------
# consumers: build_tables through the engine
# ---------------------------------------------------------------------------

def test_build_tables_engine_matches_numpy_backend():
    from repro.core.simulation import build_tables
    g = BCC(2)
    t_jax = build_tables(g)
    t_np = build_tables(g, backend="numpy")
    assert np.array_equal(t_jax.records_a, t_np.records_a)
    assert np.array_equal(t_jax.records_b, t_np.records_b)


def test_routed_distance_profile_matches_bfs():
    from repro.core.distances import (routed_average_distance,
                                      routed_diameter,
                                      routed_distance_profile)
    for g in (FCC(4), BCC(3), RTT(6)):
        assert np.array_equal(routed_distance_profile(g),
                              g.distance_distribution())
        assert routed_diameter(g) == g.diameter
        assert routed_average_distance(g) == pytest.approx(
            g.average_distance, rel=1e-12)
